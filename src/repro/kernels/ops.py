"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU deployment set ``repro.kernels.ops.INTERPRET = False`` (or pass
explicitly) to run the compiled Mosaic kernels.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import block_copy as _bc
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa

INTERPRET = True


def paged_attention(q, k_pool, v_pool, block_tables, context_lens, scale,
                    pages_per_compute_block: int = 1,
                    interpret: bool | None = None):
    return _pa.paged_attention(q, k_pool, v_pool, block_tables, context_lens,
                               scale,
                               pages_per_compute_block=pages_per_compute_block,
                               interpret=INTERPRET if interpret is None else interpret)


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    interpret: bool | None = None):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k,
                               interpret=INTERPRET if interpret is None else interpret)


def copy_blocks(src_pool, dst_pool, src_blocks, dst_blocks,
                interpret: bool | None = None):
    """Per-block scattered copy (vLLM baseline data plane)."""
    return _bc.block_copy(src_pool, dst_pool,
                          jnp.asarray(src_blocks, jnp.int32),
                          jnp.asarray(dst_blocks, jnp.int32),
                          interpret=INTERPRET if interpret is None else interpret)


def copy_block_runs(src_pool, dst_pool, runs: Sequence[Tuple[int, int]],
                    dst_starts: Sequence[int],
                    interpret: bool | None = None):
    """Grouped copy: runs[i]=(src_start, n_blocks) -> dst_starts[i]."""
    if not runs:
        return dst_pool
    src_starts = jnp.asarray([r[0] for r in runs], jnp.int32)
    lens = jnp.asarray([r[1] for r in runs], jnp.int32)
    dsts = jnp.asarray(list(dst_starts), jnp.int32)
    run_blocks = int(max(r[1] for r in runs))
    return _bc.block_copy_grouped(
        src_pool, dst_pool, src_starts, dsts, lens, run_blocks=run_blocks,
        interpret=INTERPRET if interpret is None else interpret)


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def slab_bucket_blocks(n_blocks: int) -> int:
    """Pow2 slab block count the staged swap kernels are bucketed to —
    the ONE place that defines it, so host-side staging buffers
    (``PagedPools.copy_in_staged``) can never diverge from the size the
    jitted scatter asserts against."""
    return _next_pow2(n_blocks)


def _pad_runs(runs: Sequence[Tuple[int, int]]
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int, int, int]:
    """Bucket one swap's runs for the jitted staged copies: pad the run
    list to a pow2 count (zero-length runs mask off), size the slab and
    the per-run grid extent to pow2s.  Returns (src_starts, slab_offsets,
    lens, n_runs_pad, n_slab, run_blocks) — O(log^2) compiled variants
    over any mix of swap shapes."""
    n_runs = _next_pow2(len(runs))
    src = np.zeros((n_runs,), np.int32)
    dst = np.zeros((n_runs,), np.int32)
    lens = np.zeros((n_runs,), np.int32)
    off = 0
    for i, (start, n) in enumerate(runs):
        src[i] = start
        dst[i] = off
        lens[i] = n
        off += n
    return (src, dst, lens, n_runs, _next_pow2(off),
            _next_pow2(int(max(n for _, n in runs))))


def _gather_swap_body(pool, src_starts, dst_starts, lens, *,
                      n_slab: int, run_blocks: int, interpret: bool):
    """Shared gather body: stages pool runs into a zeroed slab.  The slab
    keeps the (bs, H, D) block element axes SEPARATE (5-D) so the head
    axis survives as the shard axis under the mesh layout — each shard
    flattens only its local heads inside ``block_gather_runs``."""
    L, K, nb, bs, H, D = pool.shape
    slab0 = jnp.zeros((L * K, n_slab, bs, H, D), pool.dtype)
    return _bc.block_gather_runs(pool.reshape(L * K, nb, bs, H, D), slab0,
                                 src_starts, dst_starts, lens,
                                 run_blocks=run_blocks, interpret=interpret)


def _scatter_swap_body(pool, slab, src_starts, dst_starts, lens, *,
                       run_blocks: int, interpret: bool):
    L, K, nb, bs, H, D = pool.shape
    p5 = _bc.block_scatter_runs(slab, pool.reshape(L * K, nb, bs, H, D),
                                src_starts, dst_starts, lens,
                                run_blocks=run_blocks, interpret=interpret)
    return p5.reshape(pool.shape)


_gather_swap = jax.jit(_gather_swap_body,
                       static_argnames=("n_slab", "run_blocks", "interpret"))

_scatter_swap = jax.jit(_scatter_swap_body,
                        static_argnames=("run_blocks", "interpret"),
                        donate_argnums=(0,))


def _gather_swap_sharded_impl(pool, src_starts, dst_starts, lens, *,
                              n_slab: int, run_blocks: int, interpret: bool,
                              mesh):
    """Per-shard staged gather (DESIGN.md §9): the pool's head axis is
    partitioned over ``model``; every shard runs the SAME run-coalesced
    kernel over its local heads, producing a head-sharded slab — the d2h
    leg is then one transfer per shard, each 1/M the single-device
    bytes."""
    from jax.experimental.shard_map import shard_map
    from repro.models.sharding import pool_pspec, rep_pspec, slab_pspec
    body = functools.partial(_gather_swap_body, n_slab=n_slab,
                             run_blocks=run_blocks, interpret=interpret)
    rep = rep_pspec()
    return shard_map(body, mesh=mesh,
                     in_specs=(pool_pspec(), rep, rep, rep),
                     out_specs=slab_pspec(),
                     check_rep=False)(pool, src_starts, dst_starts, lens)


def _scatter_swap_sharded_impl(pool, slab, src_starts, dst_starts, lens, *,
                               run_blocks: int, interpret: bool, mesh):
    from jax.experimental.shard_map import shard_map
    from repro.models.sharding import pool_pspec, rep_pspec, slab_pspec
    body = functools.partial(_scatter_swap_body, run_blocks=run_blocks,
                             interpret=interpret)
    rep = rep_pspec()
    return shard_map(body, mesh=mesh,
                     in_specs=(pool_pspec(), slab_pspec(), rep, rep, rep),
                     out_specs=pool_pspec(),
                     check_rep=False)(pool, slab, src_starts, dst_starts,
                                      lens)


# jitted sharded variants: same donation / bucketing contract as the
# single-device pair (mesh is static — one variant per (mesh, buckets))
_gather_swap_sharded = jax.jit(
    _gather_swap_sharded_impl,
    static_argnames=("n_slab", "run_blocks", "interpret", "mesh"))

_scatter_swap_sharded = jax.jit(
    _scatter_swap_sharded_impl,
    static_argnames=("run_blocks", "interpret", "mesh"),
    donate_argnums=(0,))


def gather_swap_runs(pool, runs: Sequence[Tuple[int, int]],
                     interpret: bool | None = None, mesh=None):
    """Run-coalesced staged swap-out gather: copy the pool blocks named by
    ``runs`` [(start, n_blocks)] into one contiguous device staging slab
    (one grouped kernel over runs), so the d2h leg is a SINGLE transfer
    of the slab instead of N scattered per-block copies.

    pool: (L, 2, nb, bs, Hkv, D) — read only (not donated; the gather
    never invalidates the live pool).  Returns (slab, n_blocks) where
    slab is (L*2, n_slab_pow2, bs, Hkv, D); blocks [n_blocks:] are
    padding.  All shapes are pow2-bucketed so the jit cache stays
    O(log^2).  With ``mesh`` the gather runs per shard under
    ``shard_map`` and the slab comes back head-sharded (one host
    transfer per shard)."""
    assert runs, "gather_swap_runs needs at least one run"
    src, dst, lens, _, n_slab, run_blocks = _pad_runs(runs)
    interp = INTERPRET if interpret is None else interpret
    fn = _gather_swap if mesh is None else functools.partial(
        _gather_swap_sharded, mesh=mesh)
    slab = fn(pool, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(lens),
              n_slab=n_slab, run_blocks=run_blocks, interpret=interp)
    return slab, int(sum(n for _, n in runs))


def scatter_swap_runs(pool, slab, runs: Sequence[Tuple[int, int]],
                      interpret: bool | None = None, mesh=None):
    """Run-coalesced staged swap-in scatter: copy slab blocks [0, total)
    into the pool blocks named by ``runs``.  pool is DONATED — the write
    is in place and the caller MUST rebind its reference to the returned
    array (owner-of-record protocol, DESIGN.md §4.2).  slab: (L*2,
    n_slab_pow2, bs, Hkv, D) as produced by the host staging path —
    head-sharded under ``mesh``, where each shard scatters its local
    heads in place."""
    assert runs, "scatter_swap_runs needs at least one run"
    src, dst, lens, _, n_slab, run_blocks = _pad_runs(runs)
    assert slab.shape[1] == n_slab, (slab.shape, n_slab)
    interp = INTERPRET if interpret is None else interpret
    fn = _scatter_swap if mesh is None else functools.partial(
        _scatter_swap_sharded, mesh=mesh)
    # gather offsets are the slab side here: slab[dst] -> pool[src]
    return fn(pool, slab, jnp.asarray(dst), jnp.asarray(src),
              jnp.asarray(lens), run_blocks=run_blocks, interpret=interp)


def swap_gather_cache_size() -> int:
    """Compiled-variant count of the staged gather, single-device and
    sharded variants combined (bucketing metric)."""
    return int(_gather_swap._cache_size()
               + _gather_swap_sharded._cache_size())


def swap_scatter_cache_size() -> int:
    """Compiled-variant count of the staged scatter, single-device and
    sharded variants combined (bucketing metric)."""
    return int(_scatter_swap._cache_size()
               + _scatter_swap_sharded._cache_size())


@functools.partial(jax.jit, static_argnames=("block_size",),
                   donate_argnums=(0,))
def _insert_prefill(pool, k, v, blocks, *, block_size: int):
    L, T, H, D = k.shape
    P = T // block_size
    kv = jnp.stack([k, v], axis=1).reshape(L, 2, P, block_size, H, D)
    return pool.at[:, :, blocks].set(kv.astype(pool.dtype))


def insert_prefill(pool, k, v, blocks, block_size: int):
    """Scatter block-aligned prefill K/V into the paged pool through a
    block table row — the runner-managed replacement for the host-side
    ``PagedPools.write_tokens`` path.

    pool: (L, 2, nb, bs, Hkv, D) — DONATED; the caller must rebind.
    k, v: (L, T_pad, Hkv, D) with T_pad == len(blocks) * block_size; the
    caller pads the token axis up to the page bucket (pad pages point at
    the trash block, the partial last real page is zero-padded — both
    regions sit beyond the context length and are masked by attention).
    blocks: (P,) int page ids, one per block_size tokens.
    """
    return _insert_prefill(pool, k, v, jnp.asarray(blocks, jnp.int32),
                           block_size=block_size)


def insert_prefill_cache_size() -> int:
    """Compiled-variant count of the prefill scatter (bucketing metric)."""
    return int(_insert_prefill._cache_size())


# ---------------------------------------------------------------------------
# bucketed chunked prefill (DESIGN.md §5)
# ---------------------------------------------------------------------------


def _zeros_carry(shape, mesh):
    """Zeroed prefill carry, head-sharded when ``mesh`` is given."""
    z = jnp.zeros(shape, jnp.bfloat16)
    if mesh is None:
        return z
    from jax.sharding import NamedSharding
    from repro.models.sharding import carry_pspec
    return jax.device_put(z, NamedSharding(mesh, carry_pspec()))


@functools.partial(jax.jit, static_argnames=("n_new",))
def _grow_carry(carry, *, n_new: int):
    """Copy a (L, S_old, H, D) prefill carry into a longer zeroed buffer
    (pow2 token bucket).  Not donated: the output shape differs, so XLA
    could never reuse the old buffer anyway (it would only warn)."""
    return jax.lax.dynamic_update_slice(
        jnp.zeros(carry.shape[:1] + (n_new,) + carry.shape[2:], carry.dtype),
        carry, (0, 0, 0, 0))


@functools.partial(jax.jit, static_argnames=("n",))
def _slice_tokens(kv, start, *, n: int):
    """(L, S_pad, H, D)[:, start:start+n] with a TRACED start so one
    compiled variant serves every chunk offset."""
    return jax.lax.dynamic_slice_in_dim(kv, start, n, axis=1)


def prefill_chunk(params, tokens: Sequence[int], k_carry, v_carry,
                  prefix_len: int, *, cfg, block_size: int, mesh=None):
    """Bucketed wrapper around ``models.paged.prefill_kv_chunk``: pad the
    chunk to a pow2 token bucket (>= one page so the pool insert stays
    block-aligned), grow the carry buffers to a pow2 bucket holding
    ``prefix_len + chunk_pad`` tokens, and run the position-masked chunk
    forward with the real lengths as TRACED scalars — every unique
    (chunk_bucket, carry_bucket) pair is ONE compiled variant, so any mix
    of prompt lengths and chunk sizes compiles O(log^2 max_len) variants
    (mirroring the swap-run wrappers above).

    ``k_carry``/``v_carry``: None to start a prefill, else the buffers
    returned by the previous chunk (DONATED — rebind).  Returns
    (last_logits, k_carry', v_carry', k_chunk, v_chunk) where k_chunk /
    v_chunk are (L, chunk_pad, Hkv, D) ready for ``insert_prefill``.
    With ``mesh`` the chunk forward runs head-sharded under ``shard_map``
    (``prefill_kv_chunk_sharded``) with head-sharded carries — bit-exact
    with the single-device path (DESIGN.md §9)."""
    from repro.models.paged import prefill_kv_chunk, prefill_kv_chunk_sharded
    n = len(tokens)
    assert n > 0, "prefill_chunk needs at least one token"
    c_pad = max(_next_pow2(n), block_size)
    toks = np.zeros((1, c_pad), np.int32)
    toks[0, :n] = tokens
    need = prefix_len + c_pad
    if k_carry is None:
        s_pad = _next_pow2(need)
        shape = (cfg.n_layers, s_pad, cfg.n_kv_heads, cfg.resolved_head_dim)
        k_carry = _zeros_carry(shape, mesh)
        v_carry = _zeros_carry(shape, mesh)
    elif k_carry.shape[1] < need:
        s_pad = _next_pow2(need)
        k_carry = _grow_carry(k_carry, n_new=s_pad)
        v_carry = _grow_carry(v_carry, n_new=s_pad)
    if mesh is None:
        logits, k_carry, v_carry = prefill_kv_chunk(
            params, jnp.asarray(toks), k_carry, v_carry,
            jnp.int32(prefix_len), jnp.int32(n), cfg=cfg)
    else:
        logits, k_carry, v_carry = prefill_kv_chunk_sharded(
            params, jnp.asarray(toks), k_carry, v_carry,
            jnp.int32(prefix_len), jnp.int32(n), cfg=cfg, mesh=mesh)
    start = jnp.int32(prefix_len)
    k_chunk = _slice_tokens(k_carry, start, n=c_pad)
    v_chunk = _slice_tokens(v_carry, start, n=c_pad)
    return logits, k_carry, v_carry, k_chunk, v_chunk


def prefill_chunk_cache_size() -> int:
    """Compiled-variant count of the chunked prefill forward, single-
    device and sharded variants combined (the bucketing metric asserted
    by the prompt-length-sweep test)."""
    from repro.models.paged import prefill_kv_chunk, prefill_kv_chunk_sharded
    return int(prefill_kv_chunk._cache_size()
               + prefill_kv_chunk_sharded._cache_size())


@jax.jit
def _seed_carry(pool, blocks):
    """Gather pool pages into contiguous (L, P_pad*bs, H, D) K/V carry
    buffers.  Specializes on (pool shape, P_pad) — pow2-padded pages,
    O(log) variants."""
    L, K, _, bs, H, D = pool.shape
    kv = pool[:, :, blocks]                     # (L, 2, P_pad, bs, H, D)
    kv = kv.reshape(L, K, blocks.shape[0] * bs, H, D)
    return kv[:, 0], kv[:, 1]


def seed_prefill_carry(pool, block_ids: Sequence[int], start_tokens: int,
                       *, trash: int):
    """Initialize a chunked prefill's carry from KV already RESIDENT in
    the pool — the reuse mechanism's restored prefix — so chunking can
    start at ``start_tokens`` instead of recomputing (and re-billing)
    the prefix.  Pool values are bit-identical to what recomputing would
    produce (DESIGN.md §5.1), so downstream chunks and the emitted
    tokens are unchanged.  ``start_tokens`` must be block-aligned; the
    gathered page list is pow2-padded with trash pages whose junk rows
    sit at positions >= start_tokens — overwritten by the chunk writes
    before any real query can attend them (same invariant as the chunk
    pad tail).  Returns (k_carry, v_carry)."""
    bs = pool.shape[3]
    assert start_tokens > 0 and start_tokens % bs == 0, start_tokens
    nblk = start_tokens // bs
    blocks = np.full((_next_pow2(nblk),), trash, np.int32)
    blocks[:nblk] = list(block_ids)[:nblk]
    return _seed_carry(pool, jnp.asarray(blocks))


def gla_scan_scalar(q, k, v, logw, *, chunk=64, interpret: bool | None = None):
    """Chunked scalar-decay gated linear attention (Mamba2/SSD hot path)."""
    from repro.kernels import gla_scan as _gla
    return _gla.gla_scan_scalar(
        q, k, v, logw, chunk=chunk,
        interpret=INTERPRET if interpret is None else interpret)
