"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU deployment set ``repro.kernels.ops.INTERPRET = False`` (or pass
explicitly) to run the compiled Mosaic kernels.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import block_copy as _bc
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa

INTERPRET = True


def paged_attention(q, k_pool, v_pool, block_tables, context_lens, scale,
                    pages_per_compute_block: int = 1,
                    interpret: bool | None = None):
    return _pa.paged_attention(q, k_pool, v_pool, block_tables, context_lens,
                               scale,
                               pages_per_compute_block=pages_per_compute_block,
                               interpret=INTERPRET if interpret is None else interpret)


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    interpret: bool | None = None):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k,
                               interpret=INTERPRET if interpret is None else interpret)


def copy_blocks(src_pool, dst_pool, src_blocks, dst_blocks,
                interpret: bool | None = None):
    """Per-block scattered copy (vLLM baseline data plane)."""
    return _bc.block_copy(src_pool, dst_pool,
                          jnp.asarray(src_blocks, jnp.int32),
                          jnp.asarray(dst_blocks, jnp.int32),
                          interpret=INTERPRET if interpret is None else interpret)


def copy_block_runs(src_pool, dst_pool, runs: Sequence[Tuple[int, int]],
                    dst_starts: Sequence[int],
                    interpret: bool | None = None):
    """Grouped copy: runs[i]=(src_start, n_blocks) -> dst_starts[i]."""
    if not runs:
        return dst_pool
    src_starts = jnp.asarray([r[0] for r in runs], jnp.int32)
    lens = jnp.asarray([r[1] for r in runs], jnp.int32)
    dsts = jnp.asarray(list(dst_starts), jnp.int32)
    run_blocks = int(max(r[1] for r in runs))
    return _bc.block_copy_grouped(
        src_pool, dst_pool, src_starts, dsts, lens, run_blocks=run_blocks,
        interpret=INTERPRET if interpret is None else interpret)


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def slab_bucket_blocks(n_blocks: int) -> int:
    """Pow2 slab block count the staged swap kernels are bucketed to —
    the ONE place that defines it, so host-side staging buffers
    (``PagedPools.copy_in_staged``) can never diverge from the size the
    jitted scatter asserts against."""
    return _next_pow2(n_blocks)


def _pad_runs(runs: Sequence[Tuple[int, int]]
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int, int, int]:
    """Bucket one swap's runs for the jitted staged copies: pad the run
    list to a pow2 count (zero-length runs mask off), size the slab and
    the per-run grid extent to pow2s.  Returns (src_starts, slab_offsets,
    lens, n_runs_pad, n_slab, run_blocks) — O(log^2) compiled variants
    over any mix of swap shapes."""
    n_runs = _next_pow2(len(runs))
    src = np.zeros((n_runs,), np.int32)
    dst = np.zeros((n_runs,), np.int32)
    lens = np.zeros((n_runs,), np.int32)
    off = 0
    for i, (start, n) in enumerate(runs):
        src[i] = start
        dst[i] = off
        lens[i] = n
        off += n
    return (src, dst, lens, n_runs, _next_pow2(off),
            _next_pow2(int(max(n for _, n in runs))))


@functools.partial(jax.jit,
                   static_argnames=("n_slab", "run_blocks", "interpret"))
def _gather_swap(pool, src_starts, dst_starts, lens, *,
                 n_slab: int, run_blocks: int, interpret: bool):
    L, K, nb, bs, H, D = pool.shape
    p3 = pool.reshape(L * K, nb, bs * H * D)
    slab0 = jnp.zeros((L * K, n_slab, bs * H * D), pool.dtype)
    return _bc.block_gather_runs(p3, slab0, src_starts, dst_starts, lens,
                                 run_blocks=run_blocks, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("run_blocks", "interpret"),
                   donate_argnums=(0,))
def _scatter_swap(pool, slab, src_starts, dst_starts, lens, *,
                  run_blocks: int, interpret: bool):
    L, K, nb, bs, H, D = pool.shape
    p3 = pool.reshape(L * K, nb, bs * H * D)
    p3 = _bc.block_scatter_runs(slab, p3, src_starts, dst_starts, lens,
                                run_blocks=run_blocks, interpret=interpret)
    return p3.reshape(pool.shape)


def gather_swap_runs(pool, runs: Sequence[Tuple[int, int]],
                     interpret: bool | None = None):
    """Run-coalesced staged swap-out gather: copy the pool blocks named by
    ``runs`` [(start, n_blocks)] into one contiguous device staging slab
    (one grouped kernel over runs), so the d2h leg is a SINGLE transfer
    of the slab instead of N scattered per-block copies.

    pool: (L, 2, nb, bs, Hkv, D) — read only (not donated; the gather
    never invalidates the live pool).  Returns (slab, n_blocks) where
    slab is (L*2, n_slab_pow2, bs*Hkv*D); blocks [n_blocks:] are padding.
    All shapes are pow2-bucketed so the jit cache stays O(log^2)."""
    assert runs, "gather_swap_runs needs at least one run"
    src, dst, lens, _, n_slab, run_blocks = _pad_runs(runs)
    slab = _gather_swap(pool, jnp.asarray(src), jnp.asarray(dst),
                        jnp.asarray(lens), n_slab=n_slab,
                        run_blocks=run_blocks,
                        interpret=INTERPRET if interpret is None else interpret)
    return slab, int(sum(n for _, n in runs))


def scatter_swap_runs(pool, slab, runs: Sequence[Tuple[int, int]],
                      interpret: bool | None = None):
    """Run-coalesced staged swap-in scatter: copy slab blocks [0, total)
    into the pool blocks named by ``runs``.  pool is DONATED — the write
    is in place and the caller MUST rebind its reference to the returned
    array (owner-of-record protocol, DESIGN.md §4.2).  slab: (L*2,
    n_slab_pow2, bs*Hkv*D) as produced by the host staging path."""
    assert runs, "scatter_swap_runs needs at least one run"
    src, dst, lens, _, n_slab, run_blocks = _pad_runs(runs)
    assert slab.shape[1] == n_slab, (slab.shape, n_slab)
    # gather offsets are the slab side here: slab[dst] -> pool[src]
    return _scatter_swap(pool, slab, jnp.asarray(dst), jnp.asarray(src),
                         jnp.asarray(lens), run_blocks=run_blocks,
                         interpret=INTERPRET if interpret is None else interpret)


def swap_gather_cache_size() -> int:
    """Compiled-variant count of the staged gather (bucketing metric)."""
    return int(_gather_swap._cache_size())


def swap_scatter_cache_size() -> int:
    """Compiled-variant count of the staged scatter (bucketing metric)."""
    return int(_scatter_swap._cache_size())


@functools.partial(jax.jit, static_argnames=("block_size",),
                   donate_argnums=(0,))
def _insert_prefill(pool, k, v, blocks, *, block_size: int):
    L, T, H, D = k.shape
    P = T // block_size
    kv = jnp.stack([k, v], axis=1).reshape(L, 2, P, block_size, H, D)
    return pool.at[:, :, blocks].set(kv.astype(pool.dtype))


def insert_prefill(pool, k, v, blocks, block_size: int):
    """Scatter block-aligned prefill K/V into the paged pool through a
    block table row — the runner-managed replacement for the host-side
    ``PagedPools.write_tokens`` path.

    pool: (L, 2, nb, bs, Hkv, D) — DONATED; the caller must rebind.
    k, v: (L, T_pad, Hkv, D) with T_pad == len(blocks) * block_size; the
    caller pads the token axis up to the page bucket (pad pages point at
    the trash block, the partial last real page is zero-padded — both
    regions sit beyond the context length and are masked by attention).
    blocks: (P,) int page ids, one per block_size tokens.
    """
    return _insert_prefill(pool, k, v, jnp.asarray(blocks, jnp.int32),
                           block_size=block_size)


def insert_prefill_cache_size() -> int:
    """Compiled-variant count of the prefill scatter (bucketing metric)."""
    return int(_insert_prefill._cache_size())


def gla_scan_scalar(q, k, v, logw, *, chunk=64, interpret: bool | None = None):
    """Chunked scalar-decay gated linear attention (Mamba2/SSD hot path)."""
    from repro.kernels import gla_scan as _gla
    return _gla.gla_scan_scalar(
        q, k, v, logw, chunk=chunk,
        interpret=INTERPRET if interpret is None else interpret)
