"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU deployment set ``repro.kernels.ops.INTERPRET = False`` (or pass
explicitly) to run the compiled Mosaic kernels.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import block_copy as _bc
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa

INTERPRET = True


def paged_attention(q, k_pool, v_pool, block_tables, context_lens, scale,
                    pages_per_compute_block: int = 1,
                    interpret: bool | None = None):
    return _pa.paged_attention(q, k_pool, v_pool, block_tables, context_lens,
                               scale,
                               pages_per_compute_block=pages_per_compute_block,
                               interpret=INTERPRET if interpret is None else interpret)


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    interpret: bool | None = None):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k,
                               interpret=INTERPRET if interpret is None else interpret)


def copy_blocks(src_pool, dst_pool, src_blocks, dst_blocks,
                interpret: bool | None = None):
    """Per-block scattered copy (vLLM baseline data plane)."""
    return _bc.block_copy(src_pool, dst_pool,
                          jnp.asarray(src_blocks, jnp.int32),
                          jnp.asarray(dst_blocks, jnp.int32),
                          interpret=INTERPRET if interpret is None else interpret)


def copy_block_runs(src_pool, dst_pool, runs: Sequence[Tuple[int, int]],
                    dst_starts: Sequence[int],
                    interpret: bool | None = None):
    """Grouped copy: runs[i]=(src_start, n_blocks) -> dst_starts[i]."""
    if not runs:
        return dst_pool
    src_starts = jnp.asarray([r[0] for r in runs], jnp.int32)
    lens = jnp.asarray([r[1] for r in runs], jnp.int32)
    dsts = jnp.asarray(list(dst_starts), jnp.int32)
    run_blocks = int(max(r[1] for r in runs))
    return _bc.block_copy_grouped(
        src_pool, dst_pool, src_starts, dsts, lens, run_blocks=run_blocks,
        interpret=INTERPRET if interpret is None else interpret)


@functools.partial(jax.jit, static_argnames=("block_size",),
                   donate_argnums=(0,))
def _insert_prefill(pool, k, v, blocks, *, block_size: int):
    L, T, H, D = k.shape
    P = T // block_size
    kv = jnp.stack([k, v], axis=1).reshape(L, 2, P, block_size, H, D)
    return pool.at[:, :, blocks].set(kv.astype(pool.dtype))


def insert_prefill(pool, k, v, blocks, block_size: int):
    """Scatter block-aligned prefill K/V into the paged pool through a
    block table row — the runner-managed replacement for the host-side
    ``PagedPools.write_tokens`` path.

    pool: (L, 2, nb, bs, Hkv, D) — DONATED; the caller must rebind.
    k, v: (L, T_pad, Hkv, D) with T_pad == len(blocks) * block_size; the
    caller pads the token axis up to the page bucket (pad pages point at
    the trash block, the partial last real page is zero-padded — both
    regions sit beyond the context length and are masked by attention).
    blocks: (P,) int page ids, one per block_size tokens.
    """
    return _insert_prefill(pool, k, v, jnp.asarray(blocks, jnp.int32),
                           block_size=block_size)


def insert_prefill_cache_size() -> int:
    """Compiled-variant count of the prefill scatter (bucketing metric)."""
    return int(_insert_prefill._cache_size())


def gla_scan_scalar(q, k, v, logw, *, chunk=64, interpret: bool | None = None):
    """Chunked scalar-decay gated linear attention (Mamba2/SSD hot path)."""
    from repro.kernels import gla_scan as _gla
    return _gla.gla_scan_scalar(
        q, k, v, logw, chunk=chunk,
        interpret=INTERPRET if interpret is None else interpret)
