"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, kv_pool, block_tables, context_lens,
                        scale: float) -> jnp.ndarray:
    """Decode attention over a paged KV pool.

    q:            (B, Hq, D)
    kv_pool:      (2, nb, bs, Hkv, D)   (single layer; 0=K, 1=V)
    block_tables: (B, max_blocks) int32 physical block ids
    context_lens: (B,) int32
    Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    _, nb, bs, Hkv, _ = kv_pool.shape
    max_blocks = block_tables.shape[1]
    S = max_blocks * bs
    group = Hq // Hkv

    k = kv_pool[0][block_tables]            # (B, max_blocks, bs, Hkv, D)
    v = kv_pool[1][block_tables]
    k = k.reshape(B, S, Hkv, D)
    v = v.reshape(B, S, Hkv, D)

    qg = q.reshape(B, Hkv, group, D).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None, :] < context_lens[:, None]       # (B, S)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)


def block_copy_ref(src_pool, dst_pool, src_blocks, dst_blocks) -> jnp.ndarray:
    """Copy blocks src_pool[src_blocks[i]] -> dst_pool[dst_blocks[i]].

    src_pool: (nb_src, blk_elems); dst_pool: (nb_dst, blk_elems);
    src_blocks/dst_blocks: (n,) int32.  Returns updated dst_pool.
    """
    return dst_pool.at[dst_blocks].set(src_pool[src_blocks])


def mha_ref(q, k, v, causal: bool = True, scale: float | None = None):
    """Full attention oracle.  q,k,v: (B, T, H, D) (same H: pre-expanded)."""
    B, T, H, D = q.shape
    S = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    logits = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((T, S), bool), k=S - T)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
