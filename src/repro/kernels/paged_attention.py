"""Pallas TPU paged-attention decode kernel.

One query token per sequence attends to a paged KV pool through a block
table (vLLM-style).  TPU adaptation: the block table is scalar-prefetched
so each KV page is DMA'd HBM->VMEM via the BlockSpec index_map (no gather
materialization); online softmax runs on (group x page-tile) tiles so the
MXU sees (group, D) x (D, tile_tokens) matmuls.

``pages_per_compute_block`` (ppcb) streams several KV pages per grid step:
one grid step DMAs ppcb pages (one BlockSpec operand per page, all
resolved through the prefetched block table) and reduces them as a single
(group, ppcb*bs) tile — fewer grid steps and bigger MXU tiles than the
one-page-per-step baseline.  A ragged final tile is padded with page 0 and
masked by the context length (padded token positions are always
>= context_len, so their logits are NEG_INF).

Grid: (B, Hkv, n_tiles); accumulators live in VMEM scratch and the output
page is written on the last grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, ctx_ref, q_ref, *refs,
            bs: int, scale: float, n_tiles: int, ppcb: int):
    k_refs = refs[:ppcb]
    v_refs = refs[ppcb:2 * ppcb]
    o_ref = refs[2 * ppcb]
    m_ref, l_ref, acc_ref = refs[2 * ppcb + 1:2 * ppcb + 4]
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = ctx_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)                 # (group, D)
    # ppcb pages fused into one (ppcb*bs, D) KV tile
    k = jnp.concatenate([r[0, :, 0, :] for r in k_refs],
                        axis=0).astype(jnp.float32)
    v = jnp.concatenate([r[0, :, 0, :] for r in v_refs],
                        axis=0).astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    tile = ppcb * bs
    token_ids = i * tile + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
    mask = token_ids < ctx                               # (1, tile)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...][:, 0]                            # (group,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                      # (group, tile)
    l_new = l_ref[...][:, 0] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]

    @pl.when(i == n_tiles - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)               # guard ctx == 0
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale",
                                             "pages_per_compute_block",
                                             "interpret"))
def paged_attention(q, k_pool, v_pool, block_tables, context_lens,
                    scale: float, pages_per_compute_block: int = 1,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, D); k_pool/v_pool: (nb, bs, Hkv, D);
    block_tables: (B, n_pages) int32; context_lens: (B,) int32.
    ``pages_per_compute_block``: KV pages streamed per grid step.
    Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    nb, bs, Hkv, _ = k_pool.shape
    n_pages = block_tables.shape[1]
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, D)

    ppcb = max(1, min(pages_per_compute_block, n_pages))
    n_tiles = -(-n_pages // ppcb)
    pad = n_tiles * ppcb - n_pages
    if pad:
        # pad with page 0; padded positions are >= context_len so masked
        block_tables = jnp.pad(block_tables, ((0, 0), (0, pad)))
    npp = n_tiles * ppcb
    flat_bt = block_tables.reshape(-1).astype(jnp.int32)

    def q_map(b, h, i, bt, ctx):
        return (b, h, 0, 0)

    def kv_map(j):
        def index_map(b, h, i, bt, ctx):
            return (bt[b * npp + i * ppcb + j], 0, h, 0)
        return index_map

    def o_map(b, h, i, bt, ctx):
        return (b, h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_tiles),
        in_specs=(
            [pl.BlockSpec((1, 1, group, D), q_map)]
            + [pl.BlockSpec((1, bs, 1, D), kv_map(j)) for j in range(ppcb)]
            + [pl.BlockSpec((1, bs, 1, D), kv_map(j)) for j in range(ppcb)]
        ),
        out_specs=pl.BlockSpec((1, 1, group, D), o_map),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs, scale=scale, n_tiles=n_tiles,
                          ppcb=ppcb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        interpret=interpret,
    )(flat_bt, context_lens.astype(jnp.int32), qg,
      *([k_pool] * ppcb), *([v_pool] * ppcb))
    return out.reshape(B, Hq, D)
