"""Pallas TPU tiled causal flash attention (prefill hot path).

Grid (B, H, n_q_tiles, n_k_tiles); online softmax across the k-tile axis
with VMEM accumulators.  MXU-aligned (block_q x block_k) score tiles;
causal masking skips nothing structurally (masked tiles contribute zero)
— tile-level early-exit is a recorded §Perf candidate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_q: int, block_k: int, scale: float, n_k: int,
            causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_ref[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = (l_ref[...][:, 0] * alpha + jnp.sum(p, axis=1))[:, None]
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new[:, None]

    @pl.when(ki == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q, k, v: (B, H, T, D) (GQA pre-expanded).  Returns (B, H, T, D)."""
    B, H, T, D = q.shape
    S = k.shape[2]
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    assert T % block_q == 0 and S % block_k == 0
    n_q, n_k = T // block_q, S // block_k
    scale = D ** -0.5

    def q_map(b, h, qi, ki):
        return (b, h, qi, 0)

    def k_map(b, h, qi, ki):
        return (b, h, ki, 0)

    def o_map(b, h, qi, ki):
        return (b, h, qi, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          scale=scale, n_k=n_k, causal=causal),
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), q_map),
            pl.BlockSpec((1, 1, block_k, D), k_map),
            pl.BlockSpec((1, 1, block_k, D), k_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), o_map),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, H, T, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out
