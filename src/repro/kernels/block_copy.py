"""Pallas block-copy kernels — the swap data plane.

``block_copy``: scatter/gather copy of individual KV blocks through an
index list (the vLLM per-block baseline).  ``block_copy_grouped`` copies
*runs* of contiguous blocks; on real TPU each run lowers to one large DMA
(the Dynamic Block Group Manager's whole point — fewer descriptors, full
bandwidth), expressed here by blocking the grid over runs with the run
extent as the second block dimension.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(src_idx_ref, dst_idx_ref, d_ref, s_ref, o_ref):
    o_ref[...] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_copy(src_pool, dst_pool, src_blocks, dst_blocks,
               interpret: bool = True) -> jnp.ndarray:
    """Copy src_pool[src_blocks[i]] -> dst_pool[dst_blocks[i]].

    src_pool: (nb_src, E); dst_pool: (nb_dst, E); indices: (n,) int32.
    Returns the updated dst pool (dst aliased in-place on TPU).
    """
    n = src_blocks.shape[0]
    E = src_pool.shape[1]

    def s_map(i, src_idx, dst_idx):
        return (src_idx[i], 0)

    def o_map(i, src_idx, dst_idx):
        return (dst_idx[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, E), o_map),     # aliased dst (unread)
                  pl.BlockSpec((1, E), s_map)],
        out_specs=pl.BlockSpec((1, E), o_map),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        input_output_aliases={2: 0},      # dst_pool (3rd operand) -> output
        interpret=interpret,
    )(src_blocks.astype(jnp.int32), dst_blocks.astype(jnp.int32),
      dst_pool, src_pool)


def _copy_run_kernel(src_idx_ref, dst_idx_ref, len_ref, d_ref, s_ref, o_ref):
    r = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j < len_ref[r])
    def _copy():
        o_ref[...] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("run_blocks", "interpret"))
def block_copy_grouped(src_pool, dst_pool, src_starts, dst_starts, run_lens,
                       run_blocks: int, interpret: bool = True) -> jnp.ndarray:
    """Copy contiguous runs: src_pool[s:s+l] -> dst_pool[d:d+l] per run.

    Grid is (n_runs, run_blocks); inside a run the block index advances with
    unit stride so consecutive grid steps touch *adjacent* HBM — the Mosaic
    pipeline coalesces these into streaming DMA (one descriptor chain per
    run), unlike the scattered per-block baseline above.
    ``run_blocks`` is the static max run extent; shorter runs mask off.
    """
    n_runs = src_starts.shape[0]
    nb_src = src_pool.shape[0]
    nb_dst = dst_pool.shape[0]
    E = src_pool.shape[1]

    def s_map(r, j, src, dst, lens):
        return (jnp.minimum(src[r] + j, nb_src - 1), 0)

    def o_map(r, j, src, dst, lens):
        return (jnp.minimum(dst[r] + j, nb_dst - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_runs, run_blocks),
        in_specs=[pl.BlockSpec((1, E), o_map),   # aliased dst (unread)
                  pl.BlockSpec((1, E), s_map)],
        out_specs=pl.BlockSpec((1, E), o_map),
    )
    return pl.pallas_call(
        _copy_run_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(src_starts.astype(jnp.int32), dst_starts.astype(jnp.int32),
      run_lens.astype(jnp.int32), dst_pool, src_pool)


def _gather_run_kernel(src_idx_ref, dst_idx_ref, len_ref, d_ref, s_ref, o_ref):
    r = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j < len_ref[r])
    def _copy():
        o_ref[...] = s_ref[...]


def _copy_runs_3d(src, dst, src_starts, dst_starts, run_lens,
                  run_blocks: int, interpret: bool) -> jnp.ndarray:
    """Shared runs-copy over 3-D block pools: src[:, s:s+l] ->
    dst[:, d:d+l] per run, grid (n_runs, run_blocks), masked steps and
    pad blocks keep dst's content through the output alias.  NOT jitted
    here: the jitted (bucketed, donating) wrappers live in
    ``kernels/ops.py``."""
    n_runs = src_starts.shape[0]
    C, n_src, E = src.shape
    n_dst = dst.shape[1]

    def s_map(r, j, srcs, dsts, lens):
        return (0, jnp.minimum(srcs[r] + j, n_src - 1), 0)

    def o_map(r, j, srcs, dsts, lens):
        return (0, jnp.minimum(dsts[r] + j, n_dst - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_runs, run_blocks),
        in_specs=[pl.BlockSpec((C, 1, E), o_map),    # aliased dst (unread)
                  pl.BlockSpec((C, 1, E), s_map)],
        out_specs=pl.BlockSpec((C, 1, E), o_map),
    )
    return pl.pallas_call(
        _gather_run_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, src.dtype),
        input_output_aliases={3: 0},       # dst (4th operand) -> output
        interpret=interpret,
    )(src_starts.astype(jnp.int32), dst_starts.astype(jnp.int32),
      run_lens.astype(jnp.int32), dst, src)


def block_gather_runs(pool, slab0, src_starts, dst_starts, run_lens,
                      run_blocks: int, interpret: bool = True) -> jnp.ndarray:
    """Gather contiguous pool runs into a contiguous staging slab:
    pool[:, s:s+l] -> slab[:, d:d+l] per run (the d2h half of the staged
    swap path — one streaming DMA chain per run, then the whole slab
    moves host-ward as ONE transfer instead of N scattered block copies).

    pool: (C, nb, E) — the KV pool with leading (layer, k/v) dims
    collapsed — or (C, nb, bs, H, D) with the SHARD AXIS (KV heads)
    kept separate: under the mesh-sharded serving layout (DESIGN.md §9)
    H is partitioned over ``model`` and this function runs per shard
    inside ``shard_map``, flattening each shard's LOCAL heads into the
    block element dim; the slab it stages therefore stays head-sharded
    and crosses the host link as one transfer PER SHARD.
    slab0: (C, n_slab, ...) matching pool's trailing layout, aliased
    into the output."""
    shape = slab0.shape
    if pool.ndim > 3:
        pool = pool.reshape(pool.shape[0], pool.shape[1], -1)
        slab0 = slab0.reshape(shape[0], shape[1], -1)
    out = _copy_runs_3d(pool, slab0, src_starts, dst_starts, run_lens,
                        run_blocks, interpret)
    return out.reshape(shape)


def block_scatter_runs(slab, pool, src_starts, dst_starts, run_lens,
                       run_blocks: int, interpret: bool = True) -> jnp.ndarray:
    """Scatter a contiguous staging slab back into pool runs:
    slab[:, s:s+l] -> pool[:, d:d+l] per run (the h2d half of the staged
    swap path).  pool is aliased into the output — callers jit this with
    the pool DONATED (see ``kernels/ops.py``) so the write is in place,
    never an un-donated full-pool ``.at[].set`` copy.  Accepts the same
    3-D collapsed or (C, nb, bs, H, D) shard-axis layouts as
    ``block_gather_runs`` (slab and pool must match)."""
    shape = pool.shape
    if pool.ndim > 3:
        slab = slab.reshape(slab.shape[0], slab.shape[1], -1)
        pool = pool.reshape(shape[0], shape[1], -1)
    out = _copy_runs_3d(slab, pool, src_starts, dst_starts, run_lens,
                        run_blocks, interpret)
    return out.reshape(shape)


def runs_to_indices(runs: List[Tuple[int, int]]) -> List[int]:
    """Expand [(start, n)] runs to ONE flat per-block index list."""
    idx: List[int] = []
    for start, n in runs:
        idx.extend(range(start, start + n))
    return idx


def trim_runs(runs: List[Tuple[int, int]], n_blocks: int
              ) -> List[Tuple[int, int]]:
    """First ``n_blocks`` blocks of [(start, n)] runs (a partially backed
    transfer: the CPU copy may be shorter than the GPU allocation when
    contamination capped the reuse increment)."""
    out: List[Tuple[int, int]] = []
    for start, n in runs:
        if n_blocks <= 0:
            break
        take = min(n, n_blocks)
        out.append((start, take))
        n_blocks -= take
    return out


def split_runs(runs: List[Tuple[int, int]], chunk_blocks: int
               ) -> List[List[Tuple[int, int]]]:
    """Split [(start, n)] runs into chunks of <= chunk_blocks blocks each
    (a run crossing a chunk boundary is cut).  ``chunk_blocks <= 0``
    disables chunking.  The engine dispatches one swap task per chunk so
    a long transfer interleaves with decode steps instead of serializing
    behind the pool lock."""
    if chunk_blocks <= 0:
        return [list(runs)] if runs else []
    chunks: List[List[Tuple[int, int]]] = []
    cur: List[Tuple[int, int]] = []
    room = chunk_blocks
    for start, n in runs:
        while n > 0:
            take = min(n, room)
            cur.append((start, take))
            start += take
            n -= take
            room -= take
            if room == 0:
                chunks.append(cur)
                cur = []
                room = chunk_blocks
    if cur:
        chunks.append(cur)
    return chunks
