"""Pallas block-copy kernels — the swap data plane.

``block_copy``: scatter/gather copy of individual KV blocks through an
index list (the vLLM per-block baseline).  ``block_copy_grouped`` copies
*runs* of contiguous blocks; on real TPU each run lowers to one large DMA
(the Dynamic Block Group Manager's whole point — fewer descriptors, full
bandwidth), expressed here by blocking the grid over runs with the run
extent as the second block dimension.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(src_idx_ref, dst_idx_ref, d_ref, s_ref, o_ref):
    o_ref[...] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_copy(src_pool, dst_pool, src_blocks, dst_blocks,
               interpret: bool = True) -> jnp.ndarray:
    """Copy src_pool[src_blocks[i]] -> dst_pool[dst_blocks[i]].

    src_pool: (nb_src, E); dst_pool: (nb_dst, E); indices: (n,) int32.
    Returns the updated dst pool (dst aliased in-place on TPU).
    """
    n = src_blocks.shape[0]
    E = src_pool.shape[1]

    def s_map(i, src_idx, dst_idx):
        return (src_idx[i], 0)

    def o_map(i, src_idx, dst_idx):
        return (dst_idx[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, E), o_map),     # aliased dst (unread)
                  pl.BlockSpec((1, E), s_map)],
        out_specs=pl.BlockSpec((1, E), o_map),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        input_output_aliases={2: 0},      # dst_pool (3rd operand) -> output
        interpret=interpret,
    )(src_blocks.astype(jnp.int32), dst_blocks.astype(jnp.int32),
      dst_pool, src_pool)


def _copy_run_kernel(src_idx_ref, dst_idx_ref, len_ref, d_ref, s_ref, o_ref):
    r = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j < len_ref[r])
    def _copy():
        o_ref[...] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("run_blocks", "interpret"))
def block_copy_grouped(src_pool, dst_pool, src_starts, dst_starts, run_lens,
                       run_blocks: int, interpret: bool = True) -> jnp.ndarray:
    """Copy contiguous runs: src_pool[s:s+l] -> dst_pool[d:d+l] per run.

    Grid is (n_runs, run_blocks); inside a run the block index advances with
    unit stride so consecutive grid steps touch *adjacent* HBM — the Mosaic
    pipeline coalesces these into streaming DMA (one descriptor chain per
    run), unlike the scattered per-block baseline above.
    ``run_blocks`` is the static max run extent; shorter runs mask off.
    """
    n_runs = src_starts.shape[0]
    nb_src = src_pool.shape[0]
    nb_dst = dst_pool.shape[0]
    E = src_pool.shape[1]

    def s_map(r, j, src, dst, lens):
        return (jnp.minimum(src[r] + j, nb_src - 1), 0)

    def o_map(r, j, src, dst, lens):
        return (jnp.minimum(dst[r] + j, nb_dst - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_runs, run_blocks),
        in_specs=[pl.BlockSpec((1, E), o_map),   # aliased dst (unread)
                  pl.BlockSpec((1, E), s_map)],
        out_specs=pl.BlockSpec((1, E), o_map),
    )
    return pl.pallas_call(
        _copy_run_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(src_starts.astype(jnp.int32), dst_starts.astype(jnp.int32),
      run_lens.astype(jnp.int32), dst_pool, src_pool)


def runs_to_indices(runs: List[Tuple[int, int]]) -> List[int]:
    """Expand [(start, n)] runs to ONE flat per-block index list."""
    idx: List[int] = []
    for start, n in runs:
        idx.extend(range(start, start + n))
    return idx
