"""Pallas TPU chunked gated-linear-attention kernel (Mamba2/SSD scalar
decay) — the SSM-family hot spot (zamba2 backbone; rwkv6 uses per-channel
decay and keeps the jnp chunked path, see models/gla.py).

Grid (B, H, n_chunks); the recurrent state S (N, P) lives in VMEM scratch
and carries across the sequential chunk axis.  Within a chunk everything
is MXU matmuls on (C, N)/(C, P) tiles:

    y      = (q * exp(L)) @ S  +  tril((q @ k^T) * exp(L_i - L_j)) @ v
    S_next = exp(L_C) * S + (k * exp(L_C - L))^T @ v

with L the inclusive cumsum of the per-step log-decay (<= 0, so every
exponent is <= 0 after clamping — numerically stable, cf. models/gla.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, lw_ref, y_ref, s_out_ref, s_ref,
            *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (C, N)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)              # (C, P)
    lw = lw_ref[0, 0].astype(jnp.float32)            # (C, 1)
    L = jnp.cumsum(lw[:, 0])                         # (C,) inclusive

    # inter-chunk: read carried state with decay exp(L_i)
    q_dec = q * jnp.exp(L)[:, None]
    y_inter = jax.lax.dot_general(q_dec, s_ref[...],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # intra-chunk: A_ij = (q_i . k_j) * exp(L_i - L_j), j <= i
    A = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    dl = jnp.minimum(L[:, None] - L[None, :], 0.0)   # clamp masked region
    A = A * jnp.exp(dl)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(j_idx <= i_idx, A, 0.0)
    y = y_inter + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S' = exp(L_C) S + (k * exp(L_C - L))^T v
    L_tot = L[-1]
    k_scaled = k * jnp.exp(L_tot - L)[:, None]
    s_ref[...] = jnp.exp(L_tot) * s_ref[...] + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == n_chunks - 1)
    def _final():
        s_out_ref[0, 0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def gla_scan_scalar(q, k, v, logw, *, chunk: int = 64,
                    interpret: bool = True):
    """q, k: (B, H, T, N); v: (B, H, T, P); logw: (B, H, T) scalar decay
    (<= 0).  Returns (y: (B, H, T, P), S: (B, H, N, P) fp32)."""
    B, H, T, N = q.shape
    P = v.shape[-1]
    assert T % chunk == 0, f"T={T} % chunk={chunk}"
    nc = T // chunk
    lw = logw[..., None]                             # (B, H, T, 1)

    def tile_map(b, h, ci):
        return (b, h, ci, 0)

    def s_map(b, h, ci):
        return (b, h, 0, 0)

    y, s_out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=nc),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, N), tile_map),
            pl.BlockSpec((1, 1, chunk, N), tile_map),
            pl.BlockSpec((1, 1, chunk, P), tile_map),
            pl.BlockSpec((1, 1, chunk, 1), tile_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), tile_map),
            pl.BlockSpec((1, 1, N, P), s_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, P), q.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(q, k, v, lw)
    return y, s_out
