from repro.train import checkpoint, optimizer  # noqa: F401
