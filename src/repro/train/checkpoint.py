"""Flat-file checkpointing: pytree <-> .npz with path-encoded keys."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx)
            if hasattr(p, "idx") else str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a pytree with the same paths)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(
            str(q.key) if hasattr(q, "key") else str(q.idx)
            if hasattr(q, "idx") else str(q) for q in p)
        arr = data[key]
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
