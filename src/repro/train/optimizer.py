"""Minimal AdamW implemented in JAX (no external deps)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_update(grads, state: AdamWState, params, *, lr=3e-4, b1=0.9,
                 b2=0.95, eps=1e-8, weight_decay=0.01):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)
