from repro.models import (  # noqa: F401
    attention,
    gla,
    layers,
    mamba2,
    moe,
    params,
    rwkv6,
    sharding,
    steps,
    transformer,
)
