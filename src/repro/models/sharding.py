"""Sharding rules: map param/cache pytree paths to PartitionSpecs.

Scheme (see DESIGN.md §5): 2-D sharding —
  * tensor-parallel over ``model``: attention heads (via the fused head dim),
    FFN hidden dim, MoE experts, vocab;
  * ZeRO-style over ``data`` for the other matrix dim (d_model),
    falling back to replication when not divisible;
  * batch over (``pod``, ``data``) for activations;
  * decode KV caches over batch x kv-heads (replicated heads when
    kv_heads % model_axis != 0); long-context (batch=1) caches shard the
    sequence dim over ``data``.

Divisibility is checked against the actual mesh; any non-divisible axis
falls back to None (replicated) so every (arch x mesh) lowers.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _fit(dim: int, mesh: Mesh, axis):
    """Return axis if dim divisible by its size else None."""
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def param_spec(path: str, leaf, cfg: ModelConfig, mesh: Mesh,
               replicate_data: bool = False) -> P:
    """Heuristic name-based rules.  ``path`` is '/'.joined tree path; leaves
    may be stacked with leading scan axes (we only shard the trailing dims).

    ``replicate_data``: drop the ZeRO-style `data`-axis sharding (pure
    tensor parallelism).  For decode steps of small models this removes the
    per-step weight all-gather over the data axis (§Perf hillclimb)."""
    shape = leaf.shape
    nd = len(shape)

    def spec(*trailing):
        """Pad with None for leading (scan-stacked) axes."""
        lead = nd - len(trailing)
        return P(*([None] * lead + list(trailing)))

    # 1-D (norm scales, biases): replicate.
    if nd == 0 or shape[-1] <= 8:
        return P()
    name = path.split("/")[-1]

    # Embedding / LM head: (V, d) -> vocab over model, d over data.
    if name == "table":
        return spec(_fit(shape[-2], mesh, "model"), _fit(shape[-1], mesh, "data"))
    if path.endswith("mm_proj/w"):
        return spec(_fit(shape[-2], mesh, "data"), None)

    # MoE expert weights: (..., E, d, f) / (..., E, f, d): experts over model.
    if (cfg.moe is not None and name in ("w_gate", "w_up", "w_down")
            and nd >= 3 and shape[-3] == cfg.moe.n_experts):
        e_ax = _fit(shape[-3], mesh, "model")
        return spec(e_ax, _fit(shape[-2], mesh, "data"), None)
    if name == "router":
        return spec(_fit(shape[-2], mesh, "data"), None)

    # Attention projections: output dim = heads*hd -> model; input -> data.
    if name in ("wq", "wk", "wv", "w_uq", "w_uk", "w_uv", "w_krope",
                "w_dq", "w_dkv"):
        return spec(_fit(shape[-2], mesh, "data"), _fit(shape[-1], mesh, "model"))
    if name in ("wo", "w_o", "w_out"):
        return spec(_fit(shape[-2], mesh, "model"), _fit(shape[-1], mesh, "data"))
    if name in ("bq", "bk", "bv"):
        return spec(_fit(shape[-1], mesh, "model"))

    # Dense MLP: hidden dim over model.
    if name in ("w_gate", "w_up", "w_in"):
        return spec(_fit(shape[-2], mesh, "data"), _fit(shape[-1], mesh, "model"))
    if name == "w_down":
        return spec(_fit(shape[-2], mesh, "model"), _fit(shape[-1], mesh, "data"))
    if name in ("b_in",):
        return spec(_fit(shape[-1], mesh, "model"))
    if name in ("b_out", "b_out_mlp"):
        return spec()

    # RWKV square mixing matrices / mamba in-proj: (d, d') -> data x model.
    if name in ("w_r", "w_k", "w_v", "w_g", "cm_wr", "cm_wk", "w_in_rwkv",
                "lora_A", "decay_lora_A"):
        return spec(_fit(shape[-2], mesh, "data"), _fit(shape[-1], mesh, "model"))
    if name in ("cm_wv",):
        return spec(_fit(shape[-2], mesh, "model"), _fit(shape[-1], mesh, "data"))

    # Fallback for 2-D+ weights: shard the two trailing dims data x model
    # when divisible.
    if nd >= 2 and min(shape[-1], shape[-2]) >= 64:
        return spec(_fit(shape[-2], mesh, "data"), _fit(shape[-1], mesh, "model"))
    return P()


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape: Any,
                    replicate_data: bool = False):
    """Build a NamedSharding pytree matching ``params_shape``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat:
        path_str = "/".join(
            p.key if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx") else str(p)
            for p in path)
        spec = param_spec(path_str, leaf, cfg, mesh)
        if replicate_data:
            spec = P(*[None if ax == "data"
                       else tuple(a for a in ax if a != "data") or None
                       if isinstance(ax, tuple) else ax
                       for ax in spec])
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Activations / inputs / caches
# ---------------------------------------------------------------------------

def tokens_spec(mesh: Mesh, batch: int) -> P:
    ba = batch_axes(mesh)
    if batch % _axis_size(mesh, ba) == 0:
        return P(ba)
    if batch % _axis_size(mesh, "data") == 0:
        return P("data")
    return P()


def cache_spec(cfg: ModelConfig, mesh: Mesh, batch: int, leaf_ndim: int,
               *, seq_axis: Optional[int] = None, heads_axis: Optional[int] = None,
               long_context: bool = False) -> P:
    """Spec for a (L, B, S, H, D)-like cache leaf.

    Default: B over (pod,data), H over model when divisible.
    long_context (batch=1): S over data instead (flash-decoding style).
    """
    spec = [None] * leaf_ndim
    ba = batch_axes(mesh)
    if batch % _axis_size(mesh, ba) == 0:
        spec[1] = ba
    elif batch % _axis_size(mesh, "data") == 0:
        spec[1] = "data"
    elif long_context and seq_axis is not None:
        spec[seq_axis] = "data"
    if heads_axis is not None:
        spec[heads_axis] = "model"
    return P(*spec)


def shard_params(params, shardings):
    return jax.tree.map(jax.device_put, params, shardings)


# ---------------------------------------------------------------------------
# Serving-engine tensor parallelism (mesh-sharded decode/prefill/swap)
# ---------------------------------------------------------------------------
# Bit-exactness contract (DESIGN.md §9): the serving layout only ever
# shards OUTPUT-CHANNEL dims — wq/wk/wv columns (the fused head dim), the
# KV-pool / carry / slab head axis, and the per-head attention that reads
# them.  No contraction dim is split, so no cross-shard psum re-orders a
# float reduction: every shard computes a bit-identical slice of the
# single-device activations, the head-concat all_gather is a pure layout
# op, and wo / MLP / norms / unembed / sampling run REPLICATED.  That is
# deliberately more conservative than ``param_spec`` above (whose
# wo=P("model","data") splits the wo contraction — fine for the
# distributed dry-run, NOT for token-stream parity).

_SERVING_SHARDED_PARAMS = ("wq", "wk", "wv", "bq", "bk", "bv")


def serving_param_pspecs(params) -> Any:
    """PartitionSpec pytree for the serving decode/prefill shard_map:
    attention q/k/v projections (and their biases) sharded over
    ``model`` on the LAST axis (= the fused ``heads * head_dim`` output
    dim, also under leading scan-stacked layer axes); every other leaf
    replicated."""
    def leaf_spec(path, leaf):
        last = path[-1]
        name = last.key if hasattr(last, "key") else str(last)
        if name in _SERVING_SHARDED_PARAMS:
            return P(*([None] * (leaf.ndim - 1) + ["model"]))
        return P()
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def pool_pspec() -> P:
    """Paged KV pool (L, 2, num_blocks, block_size, Hkv, D): head-sharded
    over ``model``; blocks / block tables stay shard-global."""
    return P(None, None, None, None, "model", None)


def slab_pspec() -> P:
    """Swap staging slab (L*2, n_slab, block_size, Hkv, D): head-sharded
    like the pool, so a staged swap is one host transfer PER SHARD."""
    return P(None, None, None, "model", None)


def carry_pspec() -> P:
    """Chunked-prefill KV carry (L, S_pad, Hkv, D): head-sharded."""
    return P(None, None, "model", None)


def rep_pspec() -> P:
    """Replicated leaf (block tables, tokens, lens, keys, sampling...)."""
    return P()


# ---------------------------------------------------------------------------
# Activation sharding constraints (set by the launcher at trace time)
# ---------------------------------------------------------------------------
# GSPMD propagation alone double-books the `model` axis (TP weights vs
# batch) and can replicate the batch through attention (observed: 205 GiB
# per-device temps).  Layer bodies therefore anchor the residual stream and
# the KV sequence dim explicitly through this context.

import contextvars
from typing import NamedTuple as _NamedTuple


class ActivationCtx(_NamedTuple):
    mesh: Mesh
    batch_axes: Any            # axes for the batch dim of activations
    kv_seq_axis: Optional[str]  # axis for K/V sequence dim (prefill/decode)
    moe_cap_shard: bool = False  # shard MoE capacity over `data` (§Perf)


_ACT_CTX: "contextvars.ContextVar[Optional[ActivationCtx]]" = \
    contextvars.ContextVar("repro_activation_sharding", default=None)


def set_activation_ctx(ctx: Optional[ActivationCtx]):
    return _ACT_CTX.set(ctx)


def reset_activation_ctx(token) -> None:
    _ACT_CTX.reset(token)


def constrain_batch(x, batch_dim: int = 0):
    """Anchor an activation's batch dim to the context's batch axes."""
    ctx = _ACT_CTX.get()
    if ctx is None or x is None:
        return x
    if x.shape[batch_dim] % _axis_size(ctx.mesh, ctx.batch_axes) != 0:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = ctx.batch_axes
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def constrain_kv_seq(x, seq_dim: int = 1, batch_dim: int = 0):
    """Anchor K/V (B, S, H, D) with the sequence dim over kv_seq_axis."""
    ctx = _ACT_CTX.get()
    if ctx is None or x is None or ctx.kv_seq_axis is None:
        return x
    spec = [None] * x.ndim
    if x.shape[batch_dim] % _axis_size(ctx.mesh, ctx.batch_axes) == 0:
        spec[batch_dim] = ctx.batch_axes
    if x.shape[seq_dim] % _axis_size(ctx.mesh, ctx.kv_seq_axis) == 0:
        spec[seq_dim] = ctx.kv_seq_axis
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def constrain_experts(x, expert_dim: int = 0):
    """Anchor an (E, C, d) MoE buffer to expert-parallel over `model`.

    With ctx.moe_cap_shard (the "moe-cap-shard" §Perf variant) the capacity
    dim also shards over `data` — without it the expert compute is
    REPLICATED across the data axis (observed: olmoe prefill useful-flops
    ratio 0.04, i.e. ~16x redundant expert matmuls on a 16x16 mesh)."""
    ctx = _ACT_CTX.get()
    if ctx is None or x is None:
        return x
    if x.shape[expert_dim] % ctx.mesh.shape["model"] != 0:
        return x
    spec = [None] * x.ndim
    spec[expert_dim] = "model"
    if (ctx.moe_cap_shard and x.ndim > expert_dim + 1
            and x.shape[expert_dim + 1] % ctx.mesh.shape["data"] == 0):
        spec[expert_dim + 1] = "data"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))
