"""Model assembly for all assigned architectures.

Layer stacks are built as *stacked* param pytrees and executed with
``jax.lax.scan`` so the lowered HLO stays small at 512-device dry-run scale.
Heterogeneous layer patterns scan over *periods*:

  * uniform (mistral-nemo, qwen2, llama3.2, llava, olmoe):
      one scan over n_layers (MoE FFN handled inside the body);
  * deepseek-v2: dense layer 0 (skip_first MoE) + scan over layers 1..L-1;
  * gemma3 (local_global:R): scan over periods of (R local + 1 global);
      local layers use a sliding window and a *ring* decode cache of size W;
  * zamba2: scan over periods of (every-1 mamba2 + 1 SHARED attention
      block) + a remainder mamba-only scan — attention params are a single
      shared block (zamba2's defining trick);
  * rwkv: one scan over n_layers of RWKV6 blocks (constant-size state);
  * whisper: encoder scan (bidirectional) + decoder scan (self + cross).

Public entry points (all pure functions of (params, inputs)):
  init_params(cfg, key)
  forward_train(params, cfg, tokens, extra_embeds=None) -> (logits, aux_loss)
  prefill(params, cfg, tokens, extra_embeds=None, cache_len=S) -> (logits, caches)
  decode_step(params, cfg, caches, token, pos) -> (logits, caches)
  init_caches(cfg, batch, cache_len) -> caches pytree (zeros)
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba2, moe, rwkv6
from repro.models.attention import MLACache

CD = L.COMPUTE_DTYPE


# ---------------------------------------------------------------------------
# Cache pytrees
# ---------------------------------------------------------------------------

class KVCaches(NamedTuple):
    k: jnp.ndarray            # (L, B, S, Hkv, D)
    v: jnp.ndarray


class MLACaches(NamedTuple):
    latent: jnp.ndarray       # (L, B, S, r)
    k_rope: jnp.ndarray       # (L, B, S, rope_dim)


class Gemma3Caches(NamedTuple):
    local_k: jnp.ndarray      # (P, R, B, W, Hkv, D)  ring buffers
    local_v: jnp.ndarray
    global_k: jnp.ndarray     # (P, B, S, Hkv, D)
    global_v: jnp.ndarray


class Zamba2Caches(NamedTuple):
    conv_p: jnp.ndarray       # (P, R, B, K-1, C)
    ssm_p: jnp.ndarray        # (P, R, B, H, N, Pd)
    conv_rem: jnp.ndarray     # (rem, B, K-1, C)
    ssm_rem: jnp.ndarray      # (rem, B, H, N, Pd)
    attn_k: jnp.ndarray       # (P, B, S, Hkv, D)
    attn_v: jnp.ndarray


class RWKVCaches(NamedTuple):
    shift_tm: jnp.ndarray     # (L, B, d)
    shift_cm: jnp.ndarray     # (L, B, d)
    S: jnp.ndarray            # (L, B, H, N, N) fp32


class WhisperCaches(NamedTuple):
    self_k: jnp.ndarray       # (L, B, S, Hkv, D)
    self_v: jnp.ndarray
    cross_k: jnp.ndarray      # (L, B, S_enc, Hkv, D)
    cross_v: jnp.ndarray


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _stack_init(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _pattern(cfg: ModelConfig) -> str:
    lp = cfg.layer_pattern
    if lp.startswith("local_global"):
        return "gemma3"
    return lp  # uniform | zamba2 | rwkv


def _gemma3_ratio(cfg: ModelConfig) -> int:
    return int(cfg.layer_pattern.split(":")[1])


def _moe_layer(cfg: ModelConfig) -> bool:
    return cfg.moe is not None


# ---------------------------------------------------------------------------
# Uniform decoder layer (dense / MoE / MLA)
# ---------------------------------------------------------------------------

def _init_uniform_layer(cfg: ModelConfig, use_moe: bool):
    def init(key):
        k1, k2 = jax.random.split(key)
        p = {"ln1": L.init_rmsnorm(cfg.d_model), "ln2": L.init_rmsnorm(cfg.d_model)}
        if cfg.mla is not None:
            p["attn"] = attn.init_mla(k1, cfg)
        else:
            p["attn"] = attn.init_gqa(k1, cfg)
        if use_moe:
            p["ffn"] = moe.init_moe(k2, cfg)
        else:
            p["ffn"] = L.init_swiglu(k2, cfg.d_model, cfg.d_ff)
        return p
    return init


def _uniform_layer_seq(lp, x, cfg: ModelConfig, positions, use_moe: bool,
                       window=None):
    """Sequence mode; returns (x, cache_kv, aux)."""
    from repro.models.sharding import constrain_batch
    x = constrain_batch(x)
    h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, cache = attn.mla_forward(lp["attn"], h, cfg, positions)
    else:
        a, cache = attn.gqa_forward(lp["attn"], h, cfg, positions, window=window)
    x = x + a
    h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if use_moe:
        f, aux = moe.moe_forward(lp["ffn"], h, cfg)
    else:
        f, aux = L.swiglu(lp["ffn"], h), jnp.float32(0.0)
    return x + f, cache, aux


def _uniform_layer_decode(lp, x, cfg: ModelConfig, cache, pos, use_moe: bool,
                          window=None, ring: bool = False):
    from repro.models.sharding import constrain_batch
    x = constrain_batch(x)
    h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a, cache = attn.mla_decode(lp["attn"], h, cfg, cache, pos)
    else:
        ck, cv = cache
        a, ck, cv = attn.gqa_decode(lp["attn"], h, cfg, ck, cv, pos,
                                    window=window, ring=ring)
        cache = (ck, cv)
    x = x + a
    h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if use_moe:
        f, _ = moe.moe_forward(lp["ffn"], h, cfg)
    else:
        f = L.swiglu(lp["ffn"], h)
    return x + f, cache


# ---------------------------------------------------------------------------
# init_params
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Any:
    keys = jax.random.split(key, 8)
    pat = _pattern(cfg)
    params: dict = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "table": jax.random.normal(keys[1], (cfg.vocab_size, cfg.d_model),
                                       jnp.float32) * 0.02}

    if cfg.encoder_decoder:
        params.update(_init_whisper(cfg, keys[2]))
        return params

    if pat == "uniform":
        use_moe = _moe_layer(cfg)
        if cfg.moe is not None and cfg.moe.layer_pattern == "skip_first":
            params["layer0"] = _init_uniform_layer(cfg, use_moe=False)(keys[3])
            params["layers"] = _stack_init(
                keys[2], cfg.n_layers - 1, _init_uniform_layer(cfg, use_moe))
        else:
            params["layers"] = _stack_init(
                keys[2], cfg.n_layers, _init_uniform_layer(cfg, use_moe))
    elif pat == "gemma3":
        R = _gemma3_ratio(cfg)
        period = R + 1
        assert cfg.n_layers % period == 0, \
            f"gemma3 pattern needs n_layers % {period} == 0"
        n_periods = cfg.n_layers // period
        init_one = _init_uniform_layer(cfg, use_moe=False)

        def init_period(key):
            ks = jax.random.split(key, period)
            return {"local": jax.vmap(init_one)(ks[:R]),
                    "global": init_one(ks[R])}
        params["periods"] = _stack_init(keys[2], n_periods, init_period)
    elif pat == "zamba2":
        every = cfg.hybrid_attn_every
        R = every - 1                       # mamba layers per period
        n_periods = cfg.n_layers // every
        rem = cfg.n_layers % every

        def init_mamba_layer(key):
            k1, k2 = jax.random.split(key)
            return {"ln": L.init_rmsnorm(cfg.d_model),
                    "mamba": mamba2.init_mamba2_block(k1, cfg)}

        def init_period(key):
            ks = jax.random.split(key, R)
            return jax.vmap(init_mamba_layer)(ks)

        params["mamba_p"] = _stack_init(keys[2], n_periods, init_period)
        if rem:
            params["mamba_rem"] = _stack_init(keys[3], rem, init_mamba_layer)
        params["attn_shared"] = {
            "ln1": L.init_rmsnorm(cfg.d_model),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "attn": attn.init_gqa(keys[4], cfg),
            "ffn": L.init_swiglu(keys[5], cfg.d_model, cfg.d_ff),
        }
    elif pat == "rwkv":
        def init_layer(key):
            return {"ln1": L.init_rmsnorm(cfg.d_model),
                    "block": rwkv6.init_rwkv_block(key, cfg)}
        params["layers"] = _stack_init(keys[2], cfg.n_layers, init_layer)
    else:
        raise ValueError(f"unknown layer pattern {cfg.layer_pattern}")

    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        # projector stub: frontend embeddings arrive pre-projected at d_embed;
        # a single linear maps them into the LM (identity-shaped when equal).
        params["mm_proj"] = {
            "w": jax.random.normal(keys[6], (cfg.frontend.d_embed, cfg.d_model),
                                   jnp.float32) * (cfg.frontend.d_embed ** -0.5)}
    return params


def _init_whisper(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)

    def init_enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": L.init_layernorm(cfg.d_model),
                "attn": attn.init_gqa(k1, cfg),
                "ln2": L.init_layernorm(cfg.d_model),
                "mlp": L.init_gelu_mlp(k2, cfg.d_model, cfg.d_ff)}

    def init_dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": L.init_layernorm(cfg.d_model),
                "self_attn": attn.init_gqa(k1, cfg),
                "ln2": L.init_layernorm(cfg.d_model),
                "cross_attn": attn.init_cross_attention(k2, cfg),
                "ln3": L.init_layernorm(cfg.d_model),
                "mlp": L.init_gelu_mlp(k3, cfg.d_model, cfg.d_ff)}

    return {
        "enc_layers": _stack_init(ks[0], cfg.n_encoder_layers, init_enc_layer),
        "enc_norm": L.init_layernorm(cfg.d_model),
        "dec_layers": _stack_init(ks[1], cfg.n_layers, init_dec_layer),
    }


# ---------------------------------------------------------------------------
# Whisper encoder / decoder
# ---------------------------------------------------------------------------

def _sinusoid_pos(T: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(T)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    angles = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], -1)


def whisper_encode(params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S_enc, d) precomputed conv-frontend embeddings (stub)."""
    x = frames.astype(CD) + _sinusoid_pos(frames.shape[1], cfg.d_model).astype(CD)

    def body(x, lp):
        from repro.models.sharding import constrain_batch
        x = constrain_batch(x)
        h = L.layernorm(lp["ln1"], x, cfg.norm_eps)
        x = x + _bidir_attn(lp["attn"], h, cfg)     # bidirectional, no mask
        h = L.layernorm(lp["ln2"], x, cfg.norm_eps)
        return x + L.gelu_mlp(lp["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layernorm(params["enc_norm"], x, cfg.norm_eps)


def _bidir_attn(p, h, cfg: ModelConfig):
    B, T, _ = h.shape
    q, k, v = attn._project_qkv(p, h, cfg)
    scale = 1.0 / (cfg.resolved_head_dim ** 0.5)
    out = attn._sdpa(q, k, v, None, scale)
    return out.reshape(B, T, -1) @ p["wo"].astype(h.dtype)


def whisper_decode_seq(params, cfg: ModelConfig, tokens, enc_out,
                       last_only: bool = False, return_hidden: bool = False):
    """Teacher-forced decoder pass.  Returns (logits, caches-as-(k,v) stacks)."""
    B, T = tokens.shape
    x = L.embed(params["embed"], tokens)
    x = x + _sinusoid_pos(T, cfg.d_model).astype(CD)
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    def body(x, lp):
        from repro.models.sharding import constrain_batch
        x = constrain_batch(x)
        h = L.layernorm(lp["ln1"], x, cfg.norm_eps)
        a, (k, v) = attn.gqa_forward(lp["self_attn"], h, cfg, positions)
        x = x + a
        h = L.layernorm(lp["ln2"], x, cfg.norm_eps)
        ckv = attn.project_cross_kv(lp["cross_attn"], enc_out, cfg)
        x = x + attn.cross_attention(lp["cross_attn"], h, ckv, cfg)
        h = L.layernorm(lp["ln3"], x, cfg.norm_eps)
        return x + L.gelu_mlp(lp["mlp"], h), (k, v, ckv[0], ckv[1])

    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    if return_hidden:
        return x, caches
    if last_only:
        x = x[:, -1:]
    logits = L.unembed(params["embed"], x)   # whisper ties embeddings
    return logits, caches


def whisper_decode_step(params, cfg: ModelConfig, caches: WhisperCaches,
                        token, pos):
    B = token.shape[0]
    x = L.embed(params["embed"], token[:, None])
    pe = jax.lax.dynamic_slice_in_dim(
        _sinusoid_pos(caches.self_k.shape[2], cfg.d_model), pos, 1, 0)
    x = x + pe.astype(CD)

    def body(x, xs):
        lp, sk, sv, ck, cv = xs
        h = L.layernorm(lp["ln1"], x, cfg.norm_eps)
        a, sk, sv = attn.gqa_decode(lp["self_attn"], h, cfg, sk, sv, pos)
        x = x + a
        h = L.layernorm(lp["ln2"], x, cfg.norm_eps)
        x = x + attn.cross_attention(lp["cross_attn"], h, (ck, cv), cfg)
        h = L.layernorm(lp["ln3"], x, cfg.norm_eps)
        return x + L.gelu_mlp(lp["mlp"], h), (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        body, x, (params["dec_layers"], caches.self_k, caches.self_v,
                  caches.cross_k, caches.cross_v))
    logits = L.unembed(params["embed"], x[:, 0])
    return logits, WhisperCaches(self_k=sk, self_v=sv,
                                 cross_k=caches.cross_k, cross_v=caches.cross_v)


# ---------------------------------------------------------------------------
# Sequence-mode forward (train / prefill) for decoder-only stacks
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, tokens, extra_embeds):
    """tokens: (B, T_text); extra_embeds: (B, T_img, d_embed) or None.
    VLM: image embeds are projected and *prepended* to the text tokens."""
    x = L.embed(params["embed"], tokens)
    if extra_embeds is not None and "mm_proj" in params:
        img = extra_embeds.astype(CD) @ params["mm_proj"]["w"].astype(CD)
        x = jnp.concatenate([img, x], axis=1)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    return x, positions


def forward_seq(params, cfg: ModelConfig, tokens, extra_embeds=None,
                remat: bool = False, encoder_frames=None,
                last_only: bool = False, return_hidden: bool = False):
    """Full-sequence forward.  Returns (logits, caches_stacked, aux_loss).

    caches_stacked layouts match the per-pattern decode caches but with
    S == T (prefill length); ``init_caches``+``write`` extends them.
    """
    if cfg.encoder_decoder:
        enc_out = whisper_encode(params, cfg, encoder_frames)
        logits, caches = whisper_decode_seq(params, cfg, tokens, enc_out,
                                            last_only=last_only,
                                            return_hidden=return_hidden)
        return logits, caches, jnp.float32(0.0)

    pat = _pattern(cfg)
    x, positions = _embed_inputs(params, cfg, tokens, extra_embeds)
    aux_total = jnp.float32(0.0)
    caches = None

    if pat == "uniform":
        use_moe = _moe_layer(cfg)
        skip_first = cfg.moe is not None and cfg.moe.layer_pattern == "skip_first"

        def body(carry, lp):
            x, aux = carry
            x, cache, a = _uniform_layer_seq(lp, x, cfg, positions, use_moe)
            return (x, aux + a), cache

        bodyf = jax.checkpoint(body) if remat else body
        if skip_first:
            x, c0, a0 = _uniform_layer_seq(params["layer0"], x, cfg,
                                           positions, use_moe=False)
            aux_total += a0
        (x, aux_total2), caches = jax.lax.scan(bodyf, (x, aux_total),
                                               params["layers"])
        aux_total = aux_total2
        if skip_first:
            caches = {"first": c0, "rest": caches}
    elif pat == "gemma3":
        R = _gemma3_ratio(cfg)

        def body(x, lp):
            local_caches = []
            for i in range(R):
                lpi = jax.tree.map(lambda a: a[i], lp["local"])
                x, c, _ = _uniform_layer_seq(lpi, x, cfg, positions,
                                             use_moe=False,
                                             window=cfg.sliding_window)
                local_caches.append(c)
            x, cg, _ = _uniform_layer_seq(lp["global"], x, cfg, positions,
                                          use_moe=False)
            lk = jnp.stack([c[0] for c in local_caches])
            lv = jnp.stack([c[1] for c in local_caches])
            return x, (lk, lv, cg[0], cg[1])

        bodyf = jax.checkpoint(body) if remat else body
        x, caches = jax.lax.scan(bodyf, x, params["periods"])
    elif pat == "zamba2":
        x, caches = _zamba2_forward_seq(params, cfg, x, positions,
                                        remat=remat)
    elif pat == "rwkv":
        B = x.shape[0]
        def body(x, lp):
            from repro.models.sharding import constrain_batch
            x = constrain_batch(x)
            h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
            st = rwkv6.init_rwkv_state(cfg, B, dtype=x.dtype)
            h, new_st = rwkv6.rwkv_block_forward(lp["block"], h, cfg, st)
            return x + h, new_st

        bodyf = jax.checkpoint(body) if remat else body
        x, states = jax.lax.scan(bodyf, x, params["layers"])
        caches = states
    else:
        raise ValueError(pat)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, caches, aux_total
    if last_only:
        x = x[:, -1:]          # avoid materializing (B, T, V) logits
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(head, x)
    return logits, caches, aux_total


def _zamba2_forward_seq(params, cfg: ModelConfig, x, positions, remat=False):
    every = cfg.hybrid_attn_every
    R = every - 1
    B = x.shape[0]

    def mamba_apply(lp, x):
        from repro.models.sharding import constrain_batch
        x = constrain_batch(x)
        h = L.rmsnorm(lp["ln"], x, cfg.norm_eps)
        st = mamba2.init_mamba_state(cfg, B, dtype=x.dtype)
        h, new_st = mamba2.mamba2_block_forward(lp["mamba"], h, cfg, st)
        return x + h, new_st

    ap = params["attn_shared"]

    def period_body(x, lp):
        convs, ssms = [], []
        for i in range(R):
            lpi = jax.tree.map(lambda a: a[i], lp)
            x, st = mamba_apply(lpi, x)
            convs.append(st.conv)
            ssms.append(st.S)
        h = L.rmsnorm(ap["ln1"], x, cfg.norm_eps)
        a, (k, v) = attn.gqa_forward(ap["attn"], h, cfg, positions)
        x = x + a
        h = L.rmsnorm(ap["ln2"], x, cfg.norm_eps)
        x = x + L.swiglu(ap["ffn"], h)
        return x, (jnp.stack(convs), jnp.stack(ssms), k, v)

    bodyf = jax.checkpoint(period_body) if remat else period_body
    x, (conv_p, ssm_p, ak, av) = jax.lax.scan(bodyf, x, params["mamba_p"])

    conv_rem = ssm_rem = None
    if "mamba_rem" in params:
        def rem_body(x, lp):
            x, st = mamba_apply(lp, x)
            return x, (st.conv, st.S)
        x, (conv_rem, ssm_rem) = jax.lax.scan(rem_body, x, params["mamba_rem"])

    return x, (conv_p, ssm_p, conv_rem, ssm_rem, ak, av)


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, cache_len: int,
                kv_dtype=None):
    """Zero-initialized decode caches (pytree of arrays).  ``kv_dtype``
    overrides the KV storage dtype for attention caches (e.g. jnp.int8 for
    the quantized-cache §Perf variant); recurrent/MLA states keep their
    native dtypes."""
    CDkv = kv_dtype if kv_dtype is not None else CD  # attention KV only
    hd = cfg.resolved_head_dim
    Hkv = cfg.n_kv_heads
    pat = _pattern(cfg)
    if cfg.encoder_decoder:
        return WhisperCaches(
            self_k=jnp.zeros((cfg.n_layers, batch, cache_len, Hkv, hd), CDkv),
            self_v=jnp.zeros((cfg.n_layers, batch, cache_len, Hkv, hd), CDkv),
            cross_k=jnp.zeros((cfg.n_layers, batch, cfg.n_encoder_tokens, Hkv, hd), CD),
            cross_v=jnp.zeros((cfg.n_layers, batch, cfg.n_encoder_tokens, Hkv, hd), CD),
        )
    if cfg.mla is not None:
        m = cfg.mla
        lat = jnp.zeros((cfg.n_layers, batch, cache_len, m.kv_lora_rank), CD)
        kr = jnp.zeros((cfg.n_layers, batch, cache_len, m.rope_head_dim), CD)
        return MLACaches(latent=lat, k_rope=kr)
    if pat == "uniform":
        return KVCaches(
            k=jnp.zeros((cfg.n_layers, batch, cache_len, Hkv, hd), CDkv),
            v=jnp.zeros((cfg.n_layers, batch, cache_len, Hkv, hd), CDkv))
    if pat == "gemma3":
        R = _gemma3_ratio(cfg)
        P = cfg.n_layers // (R + 1)
        W = min(cfg.sliding_window, cache_len)
        return Gemma3Caches(
            local_k=jnp.zeros((P, R, batch, W, Hkv, hd), CDkv),
            local_v=jnp.zeros((P, R, batch, W, Hkv, hd), CDkv),
            global_k=jnp.zeros((P, batch, cache_len, Hkv, hd), CDkv),
            global_v=jnp.zeros((P, batch, cache_len, Hkv, hd), CDkv))
    if pat == "zamba2":
        every = cfg.hybrid_attn_every
        R = every - 1
        P = cfg.n_layers // every
        rem = cfg.n_layers % every
        s, d_inner, H, conv_ch = mamba2._dims(cfg)
        K = s.conv_kernel
        return Zamba2Caches(
            conv_p=jnp.zeros((P, R, batch, K - 1, conv_ch), CD),
            ssm_p=jnp.zeros((P, R, batch, H, s.state_dim, s.head_dim), jnp.float32),
            conv_rem=jnp.zeros((max(rem, 1), batch, K - 1, conv_ch), CD),
            ssm_rem=jnp.zeros((max(rem, 1), batch, H, s.state_dim, s.head_dim), jnp.float32),
            attn_k=jnp.zeros((P, batch, cache_len, Hkv, hd), CDkv),
            attn_v=jnp.zeros((P, batch, cache_len, Hkv, hd), CDkv))
    if pat == "rwkv":
        r = cfg.rwkv
        return RWKVCaches(
            shift_tm=jnp.zeros((cfg.n_layers, batch, cfg.d_model), CD),
            shift_cm=jnp.zeros((cfg.n_layers, batch, cfg.d_model), CD),
            S=jnp.zeros((cfg.n_layers, batch, cfg.n_heads, r.head_dim,
                         r.head_dim), jnp.float32))
    raise ValueError(pat)


# ---------------------------------------------------------------------------
# decode_step
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, caches, token, pos):
    """One decode step.  token: (B,) int32; pos: scalar int32 (tokens so far).
    Returns (logits (B, V), new caches)."""
    if cfg.encoder_decoder:
        return whisper_decode_step(params, cfg, caches, token, pos)

    pat = _pattern(cfg)
    x = L.embed(params["embed"], token[:, None])   # (B, 1, d)

    if pat == "uniform":
        use_moe = _moe_layer(cfg)
        skip_first = cfg.moe is not None and cfg.moe.layer_pattern == "skip_first"
        if cfg.mla is not None:
            def body(x, xs):
                lp, lat, kr = xs
                x, c = _uniform_layer_decode(lp, x, cfg, MLACache(lat, kr),
                                             pos, use_moe)
                return x, (c.latent, c.k_rope)
            lat_all, kr_all = caches.latent, caches.k_rope
            if skip_first:
                c0 = MLACache(lat_all[0], kr_all[0])
                x, c0 = _uniform_layer_decode(params["layer0"], x, cfg, c0,
                                              pos, use_moe=False)
                x, (lat_r, kr_r) = jax.lax.scan(
                    body, x, (params["layers"], lat_all[1:], kr_all[1:]))
                lat_new = jnp.concatenate([c0.latent[None], lat_r])
                kr_new = jnp.concatenate([c0.k_rope[None], kr_r])
            else:
                x, (lat_new, kr_new) = jax.lax.scan(
                    body, x, (params["layers"], lat_all, kr_all))
            new_caches = MLACaches(latent=lat_new, k_rope=kr_new)
        else:
            def body(x, xs):
                lp, ck, cv = xs
                x, (ck, cv) = _uniform_layer_decode(lp, x, cfg, (ck, cv),
                                                    pos, use_moe)
                return x, (ck, cv)
            x, (k_new, v_new) = jax.lax.scan(
                body, x, (params["layers"], caches.k, caches.v))
            new_caches = KVCaches(k=k_new, v=v_new)
    elif pat == "gemma3":
        R = _gemma3_ratio(cfg)
        W = caches.local_k.shape[3]

        def body(x, xs):
            lp, lk, lv, gk, gv = xs
            lks, lvs = [], []
            for i in range(R):
                lpi = jax.tree.map(lambda a: a[i], lp["local"])
                x, (cki, cvi) = _uniform_layer_decode(
                    lpi, x, cfg, (lk[i], lv[i]), pos, use_moe=False,
                    window=W, ring=True)
                lks.append(cki)
                lvs.append(cvi)
            x, (gk, gv) = _uniform_layer_decode(lp["global"], x, cfg,
                                                (gk, gv), pos, use_moe=False)
            return x, (jnp.stack(lks), jnp.stack(lvs), gk, gv)

        x, (lk, lv, gk, gv) = jax.lax.scan(
            body, x, (params["periods"], caches.local_k, caches.local_v,
                      caches.global_k, caches.global_v))
        new_caches = Gemma3Caches(lk, lv, gk, gv)
    elif pat == "zamba2":
        x, new_caches = _zamba2_decode(params, cfg, caches, x, pos)
    elif pat == "rwkv":
        def body(x, xs):
            lp, stm, scm, S = xs
            h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
            st = rwkv6.RWKVState(stm, scm, S)
            h, st = rwkv6.rwkv_block_decode(lp["block"], h, cfg, st)
            return x + h, (st.shift_tm, st.shift_cm, st.S)
        x, (stm, scm, S) = jax.lax.scan(
            body, x, (params["layers"], caches.shift_tm, caches.shift_cm,
                      caches.S))
        new_caches = RWKVCaches(stm, scm, S)
    else:
        raise ValueError(pat)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(head, x[:, 0])
    return logits, new_caches


def _zamba2_decode(params, cfg: ModelConfig, caches: Zamba2Caches, x, pos):
    every = cfg.hybrid_attn_every
    R = every - 1
    ap = params["attn_shared"]

    def mamba_apply(lp, x, conv, S):
        h = L.rmsnorm(lp["ln"], x, cfg.norm_eps)
        st = mamba2.MambaState(conv=conv, S=S)
        h, st = mamba2.mamba2_block_decode(lp["mamba"], h, cfg, st)
        return x + h, st

    def body(x, xs):
        lp, conv, S, ak, av = xs
        convs, ssms = [], []
        for i in range(R):
            lpi = jax.tree.map(lambda a: a[i], lp)
            x, st = mamba_apply(lpi, x, conv[i], S[i])
            convs.append(st.conv)
            ssms.append(st.S)
        h = L.rmsnorm(ap["ln1"], x, cfg.norm_eps)
        a, ak, av = attn.gqa_decode(ap["attn"], h, cfg, ak, av, pos)
        x = x + a
        h = L.rmsnorm(ap["ln2"], x, cfg.norm_eps)
        x = x + L.swiglu(ap["ffn"], h)
        return x, (jnp.stack(convs), jnp.stack(ssms), ak, av)

    x, (conv_p, ssm_p, ak, av) = jax.lax.scan(
        body, x, (params["mamba_p"], caches.conv_p, caches.ssm_p,
                  caches.attn_k, caches.attn_v))

    conv_rem, ssm_rem = caches.conv_rem, caches.ssm_rem
    if "mamba_rem" in params:
        def rem_body(x, xs):
            lp, conv, S = xs
            x, st = mamba_apply(lp, x, conv, S)
            return x, (st.conv, st.S)
        x, (conv_rem, ssm_rem) = jax.lax.scan(
            rem_body, x, (params["mamba_rem"], caches.conv_rem,
                          caches.ssm_rem))

    return x, Zamba2Caches(conv_p, ssm_p, conv_rem, ssm_rem, ak, av)
