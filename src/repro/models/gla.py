"""Chunked gated linear attention — the shared recurrence engine for RWKV6
(per-channel data-dependent decay) and Mamba2/SSD (per-head scalar decay).

Recurrence (per batch, head):
    S_t = diag(w_t) @ S_{t-1} + k_t v_t^T          S: (N, P)
    mamba read:  y_t = q_t @ S_t                    (current token decayed-in)
    rwkv  read:  y_t = q_t @ (S_{t-1} + diag(u) k_t v_t^T)

Both are expressed through one chunked pass.  With L = inclusive cumsum of
log w along time, the contribution of j<=i is  (q_i * exp(L_i - L_j)) . k_j:
  * mamba mode: j <= i, diagonal coefficient exp(0)=1
  * rwkv  mode: strictly j < i with weight exp(L_{i-1}-L_j)
    = exp(L_i - L_j) * exp(-logw_i)  (absorbed into q), plus the u-bonus
    diagonal term (q_i * u) . k_i.

All exponents are <= 0 within a chunk (log w <= 0), so the chunked form is
numerically stable without sub-chunking.

Shapes: q, k: (B, H, T, N); v: (B, H, T, P); logw: (B, H, T, N) (broadcast
from (B, H, T, 1) for scalar decay).  Returns y: (B, H, T, P) and the final
state (B, H, N, P).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def gla_scan_ref(q, k, v, logw, u: Optional[jnp.ndarray] = None,
                 mode: str = "mamba", initial_state=None):
    """O(T) sequential oracle (per-token scan).  Used by tests and decode."""
    B, H, T, N = q.shape
    P = v.shape[-1]
    w = jnp.exp(logw.astype(jnp.float32))
    q32, k32, v32 = (x.astype(jnp.float32) for x in (q, k, v))
    S0 = (jnp.zeros((B, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(S, inp):
        qt, kt, vt, wt = inp                      # (B,H,N),(B,H,N),(B,H,P),(B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,N,P)
        if mode == "rwkv":
            read = S + u[None, :, :, None] * kv if u is not None else S + kv
            y = jnp.einsum("bhn,bhnp->bhp", qt, read)
            S = wt[..., None] * S + kv
        else:  # mamba
            S = wt[..., None] * S + kv
            y = jnp.einsum("bhn,bhnp->bhp", qt, S)
        return S, y

    xs = (jnp.moveaxis(q32, 2, 0), jnp.moveaxis(k32, 2, 0),
          jnp.moveaxis(v32, 2, 0), jnp.moveaxis(w, 2, 0))
    S, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(q.dtype), S


@partial(jax.jit, static_argnames=("mode", "chunk", "scalar_decay"))
def gla_chunked(q, k, v, logw, u: Optional[jnp.ndarray] = None,
                mode: str = "mamba", chunk: int = 64,
                initial_state=None,
                scalar_decay: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunk-parallel gated linear attention.

    Scans over T//chunk chunks; inside a chunk everything is batched einsum.
    Per-channel decay uses an (i, j, n) materialization per chunk — exact and
    stable; the scalar-decay (mamba) path uses pure matmuls.
    """
    B, H, T, N = q.shape
    P = v.shape[-1]
    f32 = jnp.float32
    logw = jnp.broadcast_to(logw.astype(f32), (B, H, T, N))
    T_orig = T
    pad = (-T) % chunk
    if pad:
        # zero-pad the tail: k=0 contributes nothing to the state and
        # logw=0 (decay 1) leaves the carried state unchanged.
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
        q, k, v, logw = zpad(q), zpad(k), zpad(v), zpad(logw)
        T = T + pad
    C, nc = chunk, T // chunk
    qc = q.astype(f32).reshape(B, H, nc, C, N)
    kc = k.astype(f32).reshape(B, H, nc, C, N)
    vc = v.astype(f32).reshape(B, H, nc, C, P)
    lw = logw.reshape(B, H, nc, C, N)
    L = jnp.cumsum(lw, axis=3)                       # inclusive, (B,H,nc,C,N)

    if mode == "rwkv":
        q_eff = qc * jnp.exp(-lw)                    # shift read to S_{t-1}
        strict = True
    else:
        q_eff = qc
        strict = False

    # Intra-chunk term.
    i_idx = jnp.arange(C)[:, None]
    j_idx = jnp.arange(C)[None, :]
    mask = (j_idx < i_idx) if strict else (j_idx <= i_idx)

    def chunk_body(S, xs):
        q_e, k_e, v_e, L_e, lw_e = xs                # (B,H,C,*)
        # inter-chunk: read carried state with decay exp(L_i)
        y_inter = jnp.einsum("bhcn,bhnp->bhcp", q_e * jnp.exp(L_e), S)
        # intra-chunk
        # NOTE: clamp the decay exponent at 0 — for masked (j > i) entries
        # L_i - L_j > 0 can overflow exp; the overflowed values are masked
        # in the forward pass but poison the backward (0 * inf = NaN).
        # Valid (j <= i) entries always have exponent <= 0, so clamping is
        # exact.
        if scalar_decay:
            Ls = L_e[..., 0]                         # (B,H,C)
            A = jnp.einsum("bhin,bhjn->bhij", q_e, k_e)
            A = A * jnp.exp(jnp.minimum(
                Ls[..., :, None] - Ls[..., None, :], 0.0))
        else:
            # per-channel decay: (B,H,C,C,N) materialization, exact
            D = jnp.exp(jnp.minimum(
                L_e[..., :, None, :] - L_e[..., None, :, :], 0.0))  # i,j,n
            A = jnp.einsum("bhin,bhijn,bhjn->bhij", q_e, D, k_e)
        A = jnp.where(mask[None, None], A, 0.0)
        y_intra = jnp.einsum("bhij,bhjp->bhip", A, v_e)
        y = y_inter + y_intra
        # state update: S' = exp(L_C) * S + sum_j exp(L_C - L_j) k_j v_j
        L_tot = L_e[..., -1, :]                      # (B,H,N)
        k_scaled = k_e * jnp.exp(L_tot[..., None, :] - L_e)
        S = jnp.exp(L_tot)[..., :, None] * S + jnp.einsum(
            "bhcn,bhcp->bhnp", k_scaled, v_e)
        return S, y

    # NOTE: the rwkv u-bonus diagonal is handled outside the scan body
    # (vectorized over T below).
    S0 = (jnp.zeros((B, H, N, P), f32) if initial_state is None
          else initial_state.astype(f32))
    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (q_eff, kc, vc, L, lw))
    S_final, ys = jax.lax.scan(chunk_body, S0, xs)
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, T, P)

    if mode == "rwkv" and u is not None:
        diag = jnp.einsum("bhtn,hn,bhtn->bht",
                          q.astype(f32), u.astype(f32), k.astype(f32))
        y = y + (diag[..., None] * v.astype(f32)).astype(y.dtype)

    return y[:, :, :T_orig].astype(q.dtype), S_final


def gla_decode_step(q, k, v, logw, S, u=None, mode: str = "mamba"):
    """Single-token decode: q,k: (B,H,N); v: (B,H,P); logw: (B,H,N) or (B,H,1).
    Returns (y: (B,H,P), S')."""
    f32 = jnp.float32
    w = jnp.exp(jnp.broadcast_to(logw.astype(f32), q.shape))
    kv = k.astype(f32)[..., :, None] * v.astype(f32)[..., None, :]
    if mode == "rwkv":
        read = S + (u[None, :, :, None] * kv if u is not None else kv)
        y = jnp.einsum("bhn,bhnp->bhp", q.astype(f32), read)
        S = w[..., None] * S + kv
    else:
        S = w[..., None] * S + kv
        y = jnp.einsum("bhn,bhnp->bhp", q.astype(f32), S)
    return y.astype(q.dtype), S
