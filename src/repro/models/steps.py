"""Step functions lowered by the launcher: train_step / prefill / serve_step.

These are the pure pjit-able functions the multi-pod dry-run compiles for
every (arch x input shape).  They operate on contiguous decode caches
(``transformer.init_caches``); the serving engine's *paged* decode path
lives in ``repro.core.engine`` / ``repro.kernels``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.train.optimizer import AdamWState, adamw_init, adamw_update

AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# Loss / train step
# ---------------------------------------------------------------------------

CE_CHUNK = 512


def chunked_ce_loss(hidden, head_table, labels, chunk: int = CE_CHUNK):
    """Cross-entropy without materializing the full (B, T, V) fp32 logits:
    scans over sequence chunks — peak memory (B, chunk, V) per step."""
    B, T, d = hidden.shape
    pad = (-T) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nt = hidden.shape[1] // chunk
    hs = jnp.moveaxis(hidden.reshape(B, nt, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nt, chunk), 1, 0)
    w = head_table.astype(jnp.float32)

    def body(carry, xs):
        total, count = carry
        hc, lc = xs
        logits = hc.astype(jnp.float32) @ w.T                  # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.maximum(lc, 0)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        total = total + jnp.sum((lse - ll) * mask)
        count = count + jnp.sum(mask)
        return (total, count), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ls))
    return total / jnp.maximum(count, 1.0)


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            remat: bool = True) -> jnp.ndarray:
    tokens = batch["tokens"]
    labels = batch["labels"]
    hidden, _, aux = T.forward_seq(
        params, cfg, tokens,
        extra_embeds=batch.get("extra_embeds"),
        encoder_frames=batch.get("encoder_frames"),
        remat=remat, return_hidden=True)
    # VLM: image tokens are prepended; only score the text positions.
    if hidden.shape[1] != labels.shape[1]:
        hidden = hidden[:, hidden.shape[1] - labels.shape[1]:]
    head = params["embed"] if (cfg.tie_embeddings or "lm_head" not in params) \
        else params["lm_head"]
    loss = chunked_ce_loss(hidden, head["table"], labels)
    return loss + AUX_LOSS_WEIGHT * aux


def train_step(params, opt_state: AdamWState, batch, *, cfg: ModelConfig,
               lr: float = 3e-4, remat: bool = True):
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, batch, remat=remat))(params)
    new_params, new_opt = adamw_update(grads, opt_state, params, lr=lr)
    return new_params, new_opt, loss


def make_train_step(cfg: ModelConfig, lr: float = 3e-4, remat: bool = True):
    return functools.partial(train_step, cfg=cfg, lr=lr, remat=remat)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, tokens, extra_embeds=None,
            encoder_frames=None):
    """Returns (last_token_logits, caches_prefill, n_prefill_positions).

    caches are sized to the prompt length; ``extend_caches`` grows them to a
    decode budget and converts layout where needed.
    """
    logits, caches, _ = T.forward_seq(params, cfg, tokens,
                                      extra_embeds=extra_embeds,
                                      encoder_frames=encoder_frames,
                                      remat=False, last_only=True)
    return logits[:, -1], caches


def make_prefill(cfg: ModelConfig):
    return functools.partial(prefill, cfg=cfg)


def caches_from_prefill(cfg: ModelConfig, raw, batch: int, cache_len: int):
    """Convert forward_seq's stacked per-layer cache collection into the
    decode cache pytree, padded to ``cache_len``."""
    pat = T._pattern(cfg)
    full = T.init_caches(cfg, batch, cache_len)

    def put(dst, src, axis):
        """Write src into dst at offset 0 along `axis` (both stacked)."""
        sl = [slice(None)] * dst.ndim
        sl[axis] = slice(0, src.shape[axis])
        return dst.at[tuple(sl)].set(src.astype(dst.dtype))

    if cfg.encoder_decoder:
        sk, sv, ck, cv = raw
        return T.WhisperCaches(
            self_k=put(full.self_k, sk, 2), self_v=put(full.self_v, sv, 2),
            cross_k=ck.astype(full.cross_k.dtype),
            cross_v=cv.astype(full.cross_v.dtype))
    if cfg.mla is not None:
        if isinstance(raw, dict):               # skip_first (deepseek)
            c0, rest = raw["first"], raw["rest"]
            lat = jnp.concatenate([c0.latent[None], rest.latent])
            kr = jnp.concatenate([c0.k_rope[None], rest.k_rope])
        else:
            lat, kr = raw.latent, raw.k_rope
        return T.MLACaches(latent=put(full.latent, lat, 2),
                           k_rope=put(full.k_rope, kr, 2))
    if pat == "uniform":
        if isinstance(raw, dict):               # skip_first
            c0, rest = raw["first"], raw["rest"]
            k = jnp.concatenate([c0[0][None], rest[0]])
            v = jnp.concatenate([c0[1][None], rest[1]])
        else:
            k, v = raw
        return T.KVCaches(k=put(full.k, k, 2), v=put(full.v, v, 2))
    if pat == "gemma3":
        lk, lv, gk, gv = raw               # lk: (P, R, B, T, H, D)
        W = full.local_k.shape[3]
        Tp = lk.shape[3]
        if Tp >= W:
            # keep the last W tokens; ring slot for position p is p % W.
            tail = lk[:, :, :, Tp - W:], lv[:, :, :, Tp - W:]
            # roll so that token at absolute position p lands in slot p % W
            shift = (Tp - W) % W
            lk_w = jnp.roll(tail[0], shift=shift, axis=3)
            lv_w = jnp.roll(tail[1], shift=shift, axis=3)
            out_lk = full.local_k.at[...].set(lk_w.astype(full.local_k.dtype))
            out_lv = full.local_v.at[...].set(lv_w.astype(full.local_v.dtype))
        else:
            out_lk = put(full.local_k, lk, 3)
            out_lv = put(full.local_v, lv, 3)
        return T.Gemma3Caches(local_k=out_lk, local_v=out_lv,
                              global_k=put(full.global_k, gk, 2),
                              global_v=put(full.global_v, gv, 2))
    if pat == "zamba2":
        conv_p, ssm_p, conv_rem, ssm_rem, ak, av = raw
        out = T.Zamba2Caches(
            conv_p=conv_p.astype(full.conv_p.dtype),
            ssm_p=ssm_p.astype(full.ssm_p.dtype),
            conv_rem=(conv_rem.astype(full.conv_rem.dtype)
                      if conv_rem is not None else full.conv_rem),
            ssm_rem=(ssm_rem.astype(full.ssm_rem.dtype)
                     if ssm_rem is not None else full.ssm_rem),
            attn_k=put(full.attn_k, ak, 2),
            attn_v=put(full.attn_v, av, 2))
        return out
    if pat == "rwkv":
        return T.RWKVCaches(shift_tm=raw.shift_tm.astype(full.shift_tm.dtype),
                            shift_cm=raw.shift_cm.astype(full.shift_cm.dtype),
                            S=raw.S)
    raise ValueError(pat)


# ---------------------------------------------------------------------------
# Serve (decode) step
# ---------------------------------------------------------------------------

def serve_step(params, caches, token, pos, *, cfg: ModelConfig):
    """One new token for every sequence against a ``pos``-token cache.
    Returns (next_token (B,), logits (B, V), new caches) — greedy."""
    logits, new_caches = T.decode_step(params, cfg, caches, token, pos)
    next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_token, logits, new_caches


def make_serve_step(cfg: ModelConfig):
    return functools.partial(serve_step, cfg=cfg)


def init_train_state(cfg: ModelConfig, key):
    params = T.init_params(cfg, key)
    return params, adamw_init(params)
