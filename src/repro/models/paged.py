"""Paged-KV decode step for the serving engine (real-model mode).

Runs a uniform-pattern GQA transformer one token per sequence against the
paged GPU pool via block tables, using the Pallas paged-attention kernel.
The engine pads the batch to a fixed size; padding rows point their block
table at a reserved trash block and are masked by the caller.

Hot-path contract (see DESIGN.md §3):
  * ``paged_decode_step`` / ``paged_decode_step_device`` DONATE the pool
    operand — the per-layer KV write is an in-place scatter, not a
    full-pool copy per token.  Callers must rebind their pool reference to
    the returned array; the donated input buffer is invalid afterwards.
  * ``paged_decode_step_device`` additionally donates and returns the
    context-length and last-token arrays so steady-state decode keeps its
    entire per-step state device-resident (the DecodeRunner threads it).
  * Sampling (temperature / top-k / top-p) is fused into the device step;
    the parameters are traced scalars, so greedy (temperature == 0) and
    sampled runs share ONE compiled variant and greedy stays bit-exact
    argmax.  The per-row PRNG-key array holds position-independent BASE
    keys: it is read-only (neither donated nor returned — never rebind
    it per step); the step folds each row's position in on device.
  * Shapes (batch, n_pages) must be bucketed by the caller — every unique
    shape is one XLA compilation.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod


def supports_paged(cfg: ModelConfig) -> bool:
    return (cfg.layer_pattern == "uniform" and cfg.mla is None
            and not cfg.encoder_decoder)


def page_tile(n_pages: int) -> int:
    """Pages streamed per attention grid step: the largest of {4, 2, 1}
    dividing n_pages (bucketed page counts are powers of two, so steady
    state always gets the 4-page tile)."""
    for p in (4, 2):
        if n_pages % p == 0 and n_pages >= p:
            return p
    return 1


def _decode_hidden(params, pool, block_tables, context_lens, tokens,
                   cfg: ModelConfig, axis_name=None):
    """Shared decode body up to the final norm: one token per row
    through the paged pool.  Returns (x_last (B, d), new_pool) — the
    unembed is left to the caller so the mesh step can vocab-shard it.

    ``axis_name`` is the tensor-parallel mesh axis when this body runs
    under ``shard_map`` (DESIGN.md §9): ``cfg`` then describes the
    LOCAL head counts, the per-layer attention runs over this shard's
    heads only (per-head compute is independent, so every shard's
    output is bit-identical to the corresponding head slice of the
    single-device run), and the head outputs are all-gathered —
    a pure concatenation, no float reduction — before the replicated
    ``wo`` matmul.  ``axis_name=None`` is the unsharded path,
    byte-for-byte the pre-mesh code."""
    assert supports_paged(cfg), cfg.name
    B = tokens.shape[0]
    bs = pool.shape[3]
    n_pages = block_tables.shape[1]
    x = L.embed(params["embed"], tokens[:, None])          # (B, 1, d)
    positions = context_lens[:, None]                      # rope positions
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    use_moe = cfg.moe is not None
    barange = jnp.arange(B)
    ppcb = page_tile(n_pages)

    def body(x, xs):
        lp, pool_l = xs                                    # pool_l: (2,nb,bs,H,D)
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn._project_qkv(lp["attn"], h, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        blk = block_tables[barange, context_lens // bs]
        off = context_lens % bs
        pool_l = pool_l.at[0, blk, off].set(k[:, 0].astype(pool_l.dtype))
        pool_l = pool_l.at[1, blk, off].set(v[:, 0].astype(pool_l.dtype))
        a = ops.paged_attention(q[:, 0], pool_l[0], pool_l[1],
                                block_tables, context_lens + 1, scale,
                                pages_per_compute_block=ppcb)
        if axis_name is not None:
            # concat this shard's head outputs with the others' (device
            # order == head order, bit-exact) ahead of the replicated wo
            a = jax.lax.all_gather(a, axis_name, axis=1, tiled=True)
        x = x + (a.reshape(B, 1, -1) @ lp["attn"]["wo"].astype(x.dtype))
        h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if use_moe:
            f, _ = moe_mod.moe_forward(lp["ffn"], h, cfg)
        else:
            f = L.swiglu(lp["ffn"], h)
        return x + f, pool_l

    x, new_pool = jax.lax.scan(body, x, (params["layers"], pool))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x[:, 0], new_pool


def _decode_core(params, pool, block_tables, context_lens, tokens,
                 cfg: ModelConfig, axis_name=None):
    """Legacy full-logits decode body (hidden body + replicated unembed).
    Returns (next_tokens, logits, new_pool)."""
    x_last, new_pool = _decode_hidden(params, pool, block_tables,
                                      context_lens, tokens, cfg,
                                      axis_name=axis_name)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(head, x_last)
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tokens, logits, new_pool


def sample_tokens(logits, keys, ctx, sampling):
    """Fused temperature / top-k / top-p sampling, stateless per step.

    The per-row draw key is derived ON DEVICE as ``fold_in(keys[i],
    ctx[i])`` — ``keys`` holds each row's position-independent base key
    (folded from (seed, rid) at registration), so the random stream is a
    pure function of (seed, rid, position): reproducible under any
    preemption order, row re-registration or bucket rebuild, with no key
    state to thread between steps.

    ``sampling`` is a PER-ROW traced (B, 3) float32 array of
    ``[temperature, top_k, top_p]`` columns, so every request carries
    its own configuration while ONE compiled variant per batch bucket
    serves any mix (the array's shape follows the bucket, never the
    values).  Rows with ``temperature <= 0`` take bit-exact greedy
    argmax; an all-greedy batch skips the sort/softmax/Gumbel machinery
    entirely through a batch-level ``lax.cond`` — the greedy hot path
    stays argmax-only at runtime.

    logits: (B, V); keys: (B, 2) uint32 threefry key data; ctx: (B,)
    i32 positions; sampling: (B, 3) f32 per-row [temperature, top_k,
    top_p] (top_k column 0 = disabled; stored as float, exact for any
    realistic k).  Returns tokens (B,) i32.
    """
    B, V = logits.shape
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    temp = sampling[:, 0]
    top_k = sampling[:, 1].astype(jnp.int32)
    top_p = sampling[:, 2]

    def _sampled(_):
        scaled = logits / jnp.maximum(temp, 1e-6)[:, None]
        sorted_lg = jnp.sort(scaled, axis=-1)[:, ::-1]
        k_eff = jnp.clip(jnp.where(top_k > 0, top_k, V), 1,
                         V).astype(jnp.int32)
        kth = jnp.take_along_axis(sorted_lg, (k_eff - 1)[:, None],
                                  axis=-1)
        probs = jax.nn.softmax(sorted_lg, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # nucleus: keep the smallest prefix whose mass reaches top_p (the
        # mass BEFORE an index must be < top_p; index 0 is always kept)
        keep = (cum - probs) < top_p[:, None]
        pth = jnp.min(jnp.where(keep, sorted_lg, jnp.inf), axis=-1,
                      keepdims=True)
        masked = jnp.where(scaled >= jnp.maximum(kth, pth), scaled,
                           -jnp.inf)

        def one_row(key, pos, row_logits):
            g = jax.random.gumbel(jax.random.fold_in(key, pos), (V,),
                                  jnp.float32)
            return jnp.argmax(row_logits + g).astype(jnp.int32)

        drawn = jax.vmap(one_row)(keys, ctx, masked)
        return jnp.where(temp > 0.0, drawn, greedy)

    return jax.lax.cond(jnp.any(temp > 0.0), _sampled, lambda _: greedy,
                        None)


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1,))
def paged_decode_step(params, pool, block_tables, context_lens, tokens,
                      *, cfg: ModelConfig):
    """pool: (L, 2, nb, bs, Hkv, D) — DONATED (in-place KV write);
    block_tables: (B, n_pages) int32; context_lens: (B,) tokens already
    cached; tokens: (B,) int32 current input tokens.
    Returns (next_tokens, logits, new_pool)."""
    return _decode_core(params, pool, block_tables, context_lens, tokens, cfg)


def _device_step_core(params, pool, block_tables, context_lens, tokens,
                      active, keys, sampling, cfg: ModelConfig,
                      axis_name=None, n_shards=1):
    """Body shared by the single-device and mesh-sharded device steps.

    Under the mesh (``axis_name`` set, ``n_shards > 1``, vocab divisible)
    the unembed is VOCAB-SHARDED: each shard matmuls only its (V/n, d)
    row slice of the head table and the greedy winner is combined from a
    tiny all-gathered (n, B) candidate pair — per-shard max value plus
    global argmax index — instead of every shard redundantly computing
    the full (B, V) logits.  ``jnp.argmax`` picks the FIRST maximum and
    shard order equals vocab order, so taking the lowest shard among
    value ties reproduces the replicated argmax bit-exactly.  Batches
    with any sampled row fall back (one ``lax.cond`` branch, same
    compiled variant) to all-gathering the full logits, which a tiled
    concat makes bit-identical to the replicated unembed."""
    x_last, new_pool = _decode_hidden(params, pool, block_tables,
                                      context_lens, tokens, cfg,
                                      axis_name=axis_name)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if axis_name is None or n_shards <= 1 \
            or cfg.vocab_size % n_shards != 0:
        logits = L.unembed(head, x_last)
        nxt = sample_tokens(logits, keys, context_lens, sampling)
    else:
        B = tokens.shape[0]
        Vs = cfg.vocab_size // n_shards
        shard = jax.lax.axis_index(axis_name)
        w_local = jax.lax.dynamic_slice_in_dim(
            head["table"], shard * Vs, Vs, axis=0)
        local = L.unembed(head, x_last, table=w_local)     # (B, V/n)
        vals = jnp.max(local, axis=-1)
        idxs = (jnp.argmax(local, axis=-1)
                + shard * Vs).astype(jnp.int32)
        all_vals = jax.lax.all_gather(vals, axis_name)     # (n, B)
        all_idxs = jax.lax.all_gather(idxs, axis_name)     # (n, B)
        best = jnp.argmax(all_vals, axis=0)                # first max wins
        greedy = jnp.take_along_axis(all_idxs, best[None, :], axis=0)[0]

        def _sampled(_):
            full = jax.lax.all_gather(local, axis_name, axis=1,
                                      tiled=True)          # (B, V)
            return sample_tokens(full, keys, context_lens, sampling)

        nxt = jax.lax.cond(jnp.any(sampling[:, 0] > 0.0), _sampled,
                           lambda _: greedy, None)
    new_ctx = jnp.where(active, context_lens + 1, context_lens)
    new_tok = jnp.where(active, nxt, tokens)
    return nxt, new_pool, new_ctx, new_tok


@functools.partial(jax.jit, static_argnames=("cfg",),
                   donate_argnums=(1, 3, 4))
def paged_decode_step_device(params, pool, block_tables, context_lens,
                             tokens, active, keys, sampling, *,
                             cfg: ModelConfig):
    """Device-resident variant for the DecodeRunner: pool, context_lens
    and tokens are DONATED and threaded step to step without host
    round-trips.  ``active``: (B,) bool — rows decoding this step.
    Inactive rows keep their state and their (masked, trash-directed)
    compute is discarded.  ``keys``: (B, 2) uint32 per-row POSITION-
    INDEPENDENT base PRNG keys (the step folds the position in — see
    ``sample_tokens``); ``sampling``: (B, 3) f32 per-row traced
    [temperature, top_k, top_p] (temperature 0 is greedy).
    Returns (next_tokens, new_pool, new_ctx, new_tokens)."""
    return _device_step_core(params, pool, block_tables, context_lens,
                             tokens, active, keys, sampling, cfg)


def shard_local_config(cfg: ModelConfig, n_shards: int) -> ModelConfig:
    """The per-shard view of ``cfg`` under ``n_shards``-way tensor
    parallelism over heads: q and kv head counts divide by the shard
    count (GQA grouping preserved); ``head_dim`` is pinned to the
    resolved value so the division never changes it."""
    import dataclasses
    if n_shards == 1:
        return cfg
    assert shardable_heads(cfg, n_shards), (cfg.name, n_shards)
    return dataclasses.replace(
        cfg, n_heads=cfg.n_heads // n_shards,
        n_kv_heads=cfg.n_kv_heads // n_shards,
        head_dim=cfg.resolved_head_dim)


def shardable_heads(cfg: ModelConfig, n_shards: int) -> bool:
    """True when ``cfg``'s heads divide evenly over ``n_shards`` model-
    parallel shards (the head-sharded serving layout's precondition)."""
    return (n_shards >= 1 and cfg.n_heads % n_shards == 0
            and cfg.n_kv_heads % n_shards == 0)


def _sharded_device_step(params, pool, block_tables, context_lens,
                         tokens, active, keys, sampling, *,
                         cfg: ModelConfig, mesh):
    """Mesh-sharded decode step: tensor-parallel over the ``"model"``
    axis with the KV pool head-sharded (DESIGN.md §9).  Per-shard
    compute covers that shard's heads only; head outputs are
    all-gathered (pure concat) before the replicated ``wo`` and the MLP
    runs replicated, while the unembed is VOCAB-SHARDED with a tiny
    per-shard greedy-candidate gather (see ``_device_step_core``) — no
    float reduction ever crosses shards, so the token stream is
    bit-identical to the single-device step (mesh (1,1) degenerates to
    it exactly).
    """
    from jax.experimental.shard_map import shard_map
    from repro.models.sharding import (pool_pspec, rep_pspec,
                                       serving_param_pspecs)
    n = mesh.shape["model"]
    local_cfg = shard_local_config(cfg, n)
    body = functools.partial(_device_step_core, cfg=local_cfg,
                             axis_name="model", n_shards=n)
    rep = rep_pspec()
    return shard_map(
        body, mesh=mesh,
        in_specs=(serving_param_pspecs(params), pool_pspec(), rep, rep,
                  rep, rep, rep, rep),
        out_specs=(rep, pool_pspec(), rep, rep),
        check_rep=False,       # pallas_call has no replication rule
    )(params, pool, block_tables, context_lens, tokens, active, keys,
      sampling)


# the jitted sharded step: donation and static-arg layout mirror
# ``paged_decode_step_device`` exactly (fslint FS001/FS002 see through
# the ``jax.jit(shard_map-wrapping-fn)`` assignment form)
paged_decode_step_device_sharded = jax.jit(
    _sharded_device_step, static_argnames=("cfg", "mesh"),
    donate_argnums=(1, 3, 4))


@functools.partial(jax.jit, static_argnames=("cfg",))
def prefill_kv(params, tokens, *, cfg: ModelConfig):
    """Full-context prefill returning per-layer K/V for pool insertion.
    tokens: (1, T).  Returns (last_logits (V,), k, v: (L, T, Hkv, D)).

    Exact-shape legacy path (one compiled variant per prompt length);
    the engine's runner prefills through the bucketed chunked forward
    (``prefill_kv_chunk``) instead — this survives as the parity
    reference and for one-shot tools."""
    from repro.models import transformer as T
    logits, caches, _ = T.forward_seq(params, cfg, tokens, remat=False)
    k, v = caches                                          # (L, 1, T, H, D)
    return logits[0, -1], k[:, 0], v[:, 0]


def _prefill_chunk_core(params, tokens, k_carry, v_carry, prefix_len,
                        chunk_len, cfg: ModelConfig, axis_name=None):
    """Body shared by the single-device and mesh-sharded chunk forwards
    (``axis_name`` semantics as in ``_decode_core``: local heads +
    head-concat all-gather before the replicated ``wo``)."""
    assert supports_paged(cfg), cfg.name
    B, C_pad = tokens.shape
    S_pad = k_carry.shape[1]
    x = L.embed(params["embed"], tokens)                   # (1, C_pad, d)
    positions = prefix_len + jnp.arange(C_pad)[None, :]
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    use_moe = cfg.moe is not None
    # query i (absolute position prefix_len + i) attends keys [0, abs_i]
    mask = (jnp.arange(S_pad)[None, :]
            <= positions[0][:, None])[None, None]          # (1,1,C_pad,S_pad)

    def body(x, xs):
        lp, kc, vc = xs                                    # kc: (S_pad, H, D)
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn._project_qkv(lp["attn"], h, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k[0], (prefix_len, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v[0], (prefix_len, 0, 0))
        a = attn._sdpa(q, kc[None], vc[None], mask, scale)
        if axis_name is not None:
            a = jax.lax.all_gather(a, axis_name, axis=2, tiled=True)
        x = x + (a.reshape(B, C_pad, -1) @ lp["attn"]["wo"].astype(x.dtype))
        h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if use_moe:
            f, _ = moe_mod.moe_forward(lp["ffn"], h, cfg)
        else:
            f = L.swiglu(lp["ffn"], h)
        return x + f, (kc, vc)

    x, (k_carry, v_carry) = jax.lax.scan(
        body, x, (params["layers"], k_carry, v_carry))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    # unembed ONLY the last real position (row-wise matmul is bitwise
    # independent of the batch of rows, so this equals slicing the full
    # (C_pad, V) logits at (C_pad - 1)x the flops)
    x_last = jax.lax.dynamic_index_in_dim(x[0], chunk_len - 1, axis=0,
                                          keepdims=True)  # (1, d)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.unembed(head, x_last)[0], k_carry, v_carry


@functools.partial(jax.jit, static_argnames=("cfg",),
                   donate_argnums=(2, 3))
def prefill_kv_chunk(params, tokens, k_carry, v_carry, prefix_len,
                     chunk_len, *, cfg: ModelConfig):
    """One chunk of a position-masked chunked prefill (DESIGN.md §5).

    The chunk's K/V is written into the per-layer carry buffers at
    ``prefix_len`` BEFORE attention runs, so every query attends one
    contiguous key buffer whose valid keys occupy exactly positions
    ``[0, prefix_len + q_rel]`` — the same masked-tail layout the
    monolithic causal forward sees.  Masked keys contribute exactly-zero
    probability terms, which keeps the chunked forward BIT-EXACT with
    the monolithic ``prefill_kv`` for any chunking (asserted by
    tests/test_chunked_prefill.py); greedy decode parity therefore
    survives the chunked admission path unchanged.

    tokens: (1, C_pad) int32 — chunk tokens, zero-padded to the pow2
      chunk bucket (pad positions are masked: no real query attends a
      key at position >= prefix_len + chunk_len);
    k_carry, v_carry: (L, S_pad, Hkv, D) — DONATED carry buffers sized
      by the caller to S_pad >= prefix_len + C_pad (pow2-bucketed);
      rows [0, prefix_len) hold the previous chunks' K/V;
    prefix_len, chunk_len: traced i32 scalars — real tokens already in
      the carry / real tokens in this chunk.

    Returns (last_logits (V,) — position prefix_len + chunk_len - 1,
    k_carry', v_carry').  Every unique (C_pad, S_pad) pair is one XLA
    compilation: O(log^2 max_len) variants over any mix of prompt
    lengths and chunk sizes (the ``kernels.ops.prefill_chunk`` wrapper
    owns the bucketing)."""
    return _prefill_chunk_core(params, tokens, k_carry, v_carry,
                               prefix_len, chunk_len, cfg)


def _sharded_prefill_chunk(params, tokens, k_carry, v_carry, prefix_len,
                           chunk_len, *, cfg: ModelConfig, mesh):
    """Mesh-sharded chunk forward (DESIGN.md §9): the carries are
    head-sharded over ``"model"``, per-shard attention covers local
    heads only, and the head-concat all-gather before the replicated
    ``wo`` keeps the logits bit-identical to ``prefill_kv_chunk``."""
    from jax.experimental.shard_map import shard_map
    from repro.models.sharding import (carry_pspec, rep_pspec,
                                       serving_param_pspecs)
    local_cfg = shard_local_config(cfg, mesh.shape["model"])
    body = functools.partial(_prefill_chunk_core, cfg=local_cfg,
                             axis_name="model")
    rep = rep_pspec()
    return shard_map(
        body, mesh=mesh,
        in_specs=(serving_param_pspecs(params), rep, carry_pspec(),
                  carry_pspec(), rep, rep),
        out_specs=(rep, carry_pspec(), carry_pspec()),
        check_rep=False,
    )(params, tokens, k_carry, v_carry, prefix_len, chunk_len)


prefill_kv_chunk_sharded = jax.jit(
    _sharded_prefill_chunk, static_argnames=("cfg", "mesh"),
    donate_argnums=(2, 3))
