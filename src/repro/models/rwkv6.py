"""RWKV6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Faithful structure (arXiv:2404.05892):
  * token-shift with data-dependent linear interpolation (ddlerp, LoRA-based)
    for the r/k/v/w/g branches;
  * per-channel decay  w_t = exp(-exp(w0 + lora_w(x_w)))  in (0, 1);
  * recurrence  S_t = diag(w_t) S_{t-1} + k_t^T v_t,
    read  o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)  (u = per-channel bonus);
  * group-norm over heads, silu(g) gate, output projection;
  * channel-mix: r = sigmoid(W_r x_r), k = relu(W_k x_k)^2, out = r * W_v k.

Sequence mode uses the chunked GLA engine; decode mode is the O(1) state
update.  State = (token_shift_tm, token_shift_cm, S) per layer.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RWKVConfig
from repro.models.gla import gla_chunked, gla_decode_step
from repro.models.layers import _dense_init


class RWKVState(NamedTuple):
    shift_tm: jnp.ndarray   # (B, d) last token seen by time-mix
    shift_cm: jnp.ndarray   # (B, d) last token seen by channel-mix
    S: jnp.ndarray          # (B, H, N, N) recurrence state (fp32)


def init_rwkv_block(key, cfg: ModelConfig):
    r: RWKVConfig = cfg.rwkv
    d = cfg.d_model
    H = cfg.n_heads
    N = r.head_dim
    assert H * N == d, f"rwkv requires n_heads*head_dim == d_model ({H}*{N} != {d})"
    ks = jax.random.split(key, 16)
    # decay init: spread across channels like the reference impl
    decay_speed = -6.0 + 5.0 * (jnp.arange(d) / max(d - 1, 1)) ** 0.9
    p = {
        # ddlerp base mixes (mu) for x,r,k,v,w,g and LoRA for the 5 branches
        "mu_base": 0.5 * jnp.ones((5, d), jnp.float32),  # r,k,v,w,g
        "lora_A": _dense_init(ks[0], (d, 5 * r.decay_lora_rank), scale=0.01),
        "lora_B": _dense_init(ks[1], (5, r.decay_lora_rank, d), scale=0.01),
        "w_r": _dense_init(ks[2], (d, d)),
        "w_k": _dense_init(ks[3], (d, d)),
        "w_v": _dense_init(ks[4], (d, d)),
        "w_g": _dense_init(ks[5], (d, d)),
        "w_o": _dense_init(ks[6], (d, d)),
        "decay_base": decay_speed,                       # w0, (d,)
        "decay_lora_A": _dense_init(ks[7], (d, r.decay_lora_rank), scale=0.01),
        "decay_lora_B": _dense_init(ks[8], (r.decay_lora_rank, d), scale=0.01),
        "u_bonus": 0.5 * jnp.ones((H, N), jnp.float32),
        "gn_scale": jnp.ones((d,), jnp.float32),
        "gn_bias": jnp.zeros((d,), jnp.float32),
        # channel mix
        "cm_mu": 0.5 * jnp.ones((2, d), jnp.float32),    # r, k
        "cm_wr": _dense_init(ks[9], (d, d)),
        "cm_wk": _dense_init(ks[10], (d, cfg.d_ff)),
        "cm_wv": _dense_init(ks[11], (cfg.d_ff, d)),
    }
    return p


def _ddlerp(p, x, x_prev):
    """Data-dependent lerp between current and shifted token for 5 branches.
    x, x_prev: (B, T, d).  Returns tuple of 5 mixed tensors."""
    dt = x.dtype
    delta = x_prev - x
    base = x + delta * p["mu_base"][:, None, None, :].astype(dt)   # (5,B,T,d)
    lora = jnp.tanh(x @ p["lora_A"].astype(dt))                    # (B,T,5R)
    R = p["lora_B"].shape[1]
    lora = lora.reshape(*lora.shape[:-1], 5, R)
    adj = jnp.einsum("btfr,frd->fbtd", lora, p["lora_B"].astype(dt))
    return base + adj * delta[None]


def _group_norm(x, scale, bias, n_heads, eps=1e-5):
    """x: (B, T, d) grouped by head."""
    B, T, d = x.shape
    xg = x.reshape(B, T, n_heads, d // n_heads).astype(jnp.float32)
    mu = jnp.mean(xg, -1, keepdims=True)
    var = jnp.var(xg, -1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(B, T, d) * scale + bias).astype(x.dtype)


def _time_mix_qkvwg(p, x, x_prev, cfg: ModelConfig):
    r: RWKVConfig = cfg.rwkv
    B, T, d = x.shape
    H, N = cfg.n_heads, r.head_dim
    dt = x.dtype
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    rr = (xr @ p["w_r"].astype(dt)).reshape(B, T, H, N).transpose(0, 2, 1, 3)
    kk = (xk @ p["w_k"].astype(dt)).reshape(B, T, H, N).transpose(0, 2, 1, 3)
    vv = (xv @ p["w_v"].astype(dt)).reshape(B, T, H, N).transpose(0, 2, 1, 3)
    g = jax.nn.silu(xg @ p["w_g"].astype(dt))                      # (B,T,d)
    # data-dependent per-channel decay, logw <= 0
    dlo = jnp.tanh(xw @ p["decay_lora_A"].astype(dt)) @ p["decay_lora_B"].astype(dt)
    logw = -jnp.exp(p["decay_base"].astype(jnp.float32)
                    + dlo.astype(jnp.float32))                     # (B,T,d)
    logw = logw.reshape(B, T, H, N).transpose(0, 2, 1, 3)
    return rr, kk, vv, logw, g


def rwkv_block_forward(p, x, cfg: ModelConfig, state: RWKVState
                       ) -> Tuple[jnp.ndarray, RWKVState]:
    """Sequence mode.  x: (B, T, d)."""
    r: RWKVConfig = cfg.rwkv
    B, T, d = x.shape
    H, N = cfg.n_heads, r.head_dim
    # token shift: previous token (carry state.shift_tm for t=0)
    x_prev = jnp.concatenate([state.shift_tm[:, None, :], x[:, :-1]], axis=1)
    rr, kk, vv, logw, g = _time_mix_qkvwg(p, x, x_prev, cfg)
    y, S = gla_chunked(rr, kk, vv, logw, u=p["u_bonus"], mode="rwkv",
                       chunk=min(r.chunk_size, T), initial_state=state.S)
    y = y.transpose(0, 2, 1, 3).reshape(B, T, d)
    y = _group_norm(y, p["gn_scale"], p["gn_bias"], H)
    out_tm = (y * g) @ p["w_o"].astype(x.dtype)
    h = x + out_tm

    # channel mix
    h_prev = jnp.concatenate([state.shift_cm[:, None, :], h[:, :-1]], axis=1)
    dt = h.dtype
    delta = h_prev - h
    hr = h + delta * p["cm_mu"][0].astype(dt)
    hk = h + delta * p["cm_mu"][1].astype(dt)
    rgate = jax.nn.sigmoid(hr @ p["cm_wr"].astype(dt))
    kk2 = jnp.square(jax.nn.relu(hk @ p["cm_wk"].astype(dt)))
    out_cm = rgate * (kk2 @ p["cm_wv"].astype(dt))
    out = h + out_cm

    new_state = RWKVState(shift_tm=x[:, -1, :], shift_cm=h[:, -1, :], S=S)
    return out, new_state


def rwkv_block_decode(p, x, cfg: ModelConfig, state: RWKVState
                      ) -> Tuple[jnp.ndarray, RWKVState]:
    """Decode one token.  x: (B, 1, d)."""
    r: RWKVConfig = cfg.rwkv
    B, _, d = x.shape
    H, N = cfg.n_heads, r.head_dim
    x_prev = state.shift_tm[:, None, :]
    rr, kk, vv, logw, g = _time_mix_qkvwg(p, x, x_prev, cfg)
    y, S = gla_decode_step(rr[:, :, 0], kk[:, :, 0], vv[:, :, 0],
                           logw[:, :, 0], state.S, u=p["u_bonus"], mode="rwkv")
    y = y.reshape(B, 1, d)
    y = _group_norm(y, p["gn_scale"], p["gn_bias"], H)
    out_tm = (y * g) @ p["w_o"].astype(x.dtype)
    h = x + out_tm

    h_prev = state.shift_cm[:, None, :]
    dt = h.dtype
    delta = h_prev - h
    hr = h + delta * p["cm_mu"][0].astype(dt)
    hk = h + delta * p["cm_mu"][1].astype(dt)
    rgate = jax.nn.sigmoid(hr @ p["cm_wr"].astype(dt))
    kk2 = jnp.square(jax.nn.relu(hk @ p["cm_wk"].astype(dt)))
    out = h + rgate * (kk2 @ p["cm_wv"].astype(dt))

    new_state = RWKVState(shift_tm=x[:, 0, :], shift_cm=h[:, 0, :], S=S)
    return out, new_state


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> RWKVState:
    r: RWKVConfig = cfg.rwkv
    return RWKVState(
        shift_tm=jnp.zeros((batch, cfg.d_model), dtype),
        shift_cm=jnp.zeros((batch, cfg.d_model), dtype),
        S=jnp.zeros((batch, cfg.n_heads, r.head_dim, r.head_dim), jnp.float32),
    )
