"""Shared model layers: norms, RoPE, SwiGLU MLP, embeddings.

All params are plain nested dicts of jnp arrays; init fns take an rng key.
Compute dtype is bf16 by default (params stored fp32, cast at use).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16


def _dense_init(key, shape, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale)


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dtype)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, D) or (..., T, D); positions: broadcastable to (..., T)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                    # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, D/2)
    if x.ndim == angles.ndim + 1:                          # has heads axis
        angles = angles[..., None, :]                      # (..., T, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_swiglu(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, (d_model, d_ff)),
        "w_up": _dense_init(k2, (d_model, d_ff)),
        "w_down": _dense_init(k3, (d_ff, d_model)),
    }


def swiglu(params, x):
    dt = x.dtype
    g = x @ params["w_gate"].astype(dt)
    u = x @ params["w_up"].astype(dt)
    return (jax.nn.silu(g) * u) @ params["w_down"].astype(dt)


def init_gelu_mlp(key, d_model: int, d_ff: int):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": _dense_init(k1, (d_model, d_ff)),
        "b_in": jnp.zeros((d_ff,), jnp.float32),
        "w_out": _dense_init(k2, (d_ff, d_model)),
        "b_out": jnp.zeros((d_model,), jnp.float32),
    }


def gelu_mlp(params, x):
    dt = x.dtype
    h = jax.nn.gelu(x @ params["w_in"].astype(dt) + params["b_in"].astype(dt))
    return h @ params["w_out"].astype(dt) + params["b_out"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int):
    return {"table": jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02}


def embed(params, tokens):
    return params["table"].astype(COMPUTE_DTYPE)[tokens]


def unembed(params, x, table=None):
    """Project to vocab logits.  ``table`` overrides (tied embeddings)."""
    w = table if table is not None else params["table"]
    return x.astype(jnp.float32) @ w.astype(jnp.float32).T
