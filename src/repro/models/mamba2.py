"""Mamba2 (SSD) block for the Zamba2 hybrid backbone.

Structure (arXiv:2405.21060 / zamba2 arXiv:2411.15242):
  in_proj -> (z gate, x, B, C, dt); causal conv1d over [x, B, C];
  SSD recurrence per head with scalar decay  a_t = exp(A * softplus(dt + bias))
  (A < 0 learned per head), k=B_t (N), v=x_t (P=head_dim), read q=C_t;
  y = y + D * x (skip), gated by silu(z), RMS-norm, out_proj.

Sequence mode uses the chunked GLA engine (scalar-decay matmul path);
decode is the O(1) state update.  State = (conv window, S) per layer.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.gla import gla_chunked, gla_decode_step
from repro.models.layers import _dense_init, rmsnorm


class MambaState(NamedTuple):
    conv: jnp.ndarray    # (B, K-1, conv_channels) trailing inputs
    S: jnp.ndarray       # (B, H, N, P) fp32


def _dims(cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = s.n_heads or d_inner // s.head_dim
    conv_channels = d_inner + 2 * s.state_dim * 1   # x + B + C (single group)
    return s, d_inner, n_heads, conv_channels


def init_mamba2_block(key, cfg: ModelConfig):
    s, d_inner, H, conv_ch = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * s.state_dim + H     # z, x, B, C, dt
    p = {
        "w_in": _dense_init(ks[0], (d, proj_out)),
        "conv_w": _dense_init(ks[1], (s.conv_kernel, conv_ch), scale=0.2),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),  # A = -exp(A_log) < 0
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "w_out": _dense_init(ks[2], (d_inner, d)),
    }
    return p


def _split_proj(proj, cfg: ModelConfig):
    s, d_inner, H, _ = _dims(cfg)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_inner + 2 * s.state_dim]
    dt = proj[..., -H:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, prev):
    """xbc: (B, T, C); prev: (B, K-1, C) trailing context. Returns (out, new_prev)."""
    K = conv_w.shape[0]
    x_ext = jnp.concatenate([prev.astype(xbc.dtype), xbc], axis=1)  # (B, T+K-1, C)
    out = jnp.zeros_like(xbc)
    for i in range(K):
        out = out + x_ext[:, i:i + xbc.shape[1]] * conv_w[i].astype(xbc.dtype)
    out = jax.nn.silu(out + conv_b.astype(xbc.dtype))
    new_prev = x_ext[:, -(K - 1):] if K > 1 else prev
    return out, new_prev


def _ssd_inputs(p, xbc, dt_raw, cfg: ModelConfig):
    """Build (q=C, k=B, v=x, logw) for the GLA engine."""
    s, d_inner, H, _ = _dims(cfg)
    B_, T = xbc.shape[0], xbc.shape[1]
    P = s.head_dim
    N = s.state_dim
    xpart = xbc[..., :d_inner].reshape(B_, T, H, P)
    Bpart = xbc[..., d_inner:d_inner + N]                    # (B,T,N) shared
    Cpart = xbc[..., d_inner + N:]                           # (B,T,N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,T,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)
    logw = (dt * A)[..., None]                                # (B,T,H,1) <= 0
    # v scaled by dt (discretized input), k = B, q = C shared across heads
    v = (xpart.astype(jnp.float32) * dt[..., None]).astype(xbc.dtype)
    q = jnp.broadcast_to(Cpart[:, :, None, :], (B_, T, H, N))
    k = jnp.broadcast_to(Bpart[:, :, None, :], (B_, T, H, N))
    # to (B,H,T,*)
    tr = lambda a: a.transpose(0, 2, 1, 3)
    return tr(q), tr(k), tr(v), tr(logw), xpart


def mamba2_block_forward(p, x, cfg: ModelConfig, state: MambaState
                         ) -> Tuple[jnp.ndarray, MambaState]:
    s, d_inner, H, _ = _dims(cfg)
    B_, T, d = x.shape
    dt_ = x.dtype
    proj = x @ p["w_in"].astype(dt_)
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], state.conv)
    q, k, v, logw, xpart = _ssd_inputs(p, xbc, dt_raw, cfg)
    y, S = gla_chunked(q, k, v, logw, mode="mamba",
                       chunk=min(s.chunk_size, T), initial_state=state.S,
                       scalar_decay=True)
    y = y.transpose(0, 2, 1, 3)                              # (B,T,H,P)
    y = y + xpart * p["D_skip"].astype(dt_)[None, None, :, None]
    y = y.reshape(B_, T, d_inner) * jax.nn.silu(z)
    y = rmsnorm({"scale": p["norm_scale"]}, y, cfg.norm_eps)
    out = y @ p["w_out"].astype(dt_)
    return out, MambaState(conv=new_conv, S=S)


def mamba2_block_decode(p, x, cfg: ModelConfig, state: MambaState
                        ) -> Tuple[jnp.ndarray, MambaState]:
    s, d_inner, H, _ = _dims(cfg)
    B_, _, d = x.shape
    dt_ = x.dtype
    proj = x @ p["w_in"].astype(dt_)
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], state.conv)
    q, k, v, logw, xpart = _ssd_inputs(p, xbc, dt_raw, cfg)
    y, S = gla_decode_step(q[:, :, 0], k[:, :, 0], v[:, :, 0], logw[:, :, 0],
                           state.S, mode="mamba")
    y = y[:, None, :, :] if y.ndim == 3 else y               # (B,1,H,P)
    y = y.reshape(B_, 1, H, s.head_dim) + xpart * p["D_skip"].astype(dt_)[None, None, :, None]
    y = y.reshape(B_, 1, d_inner) * jax.nn.silu(z)
    y = rmsnorm({"scale": p["norm_scale"]}, y, cfg.norm_eps)
    out = y @ p["w_out"].astype(dt_)
    return out, MambaState(conv=new_conv, S=S)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> MambaState:
    s, d_inner, H, conv_ch = _dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, s.conv_kernel - 1, conv_ch), dtype),
        S=jnp.zeros((batch, H, s.state_dim, s.head_dim), jnp.float32),
    )
