"""Attention variants: GQA (full / sliding-window / cross) and DeepSeek MLA.

Two execution modes:
  * sequence mode (train / prefill): full (B, T) -> (B, T) with causal mask;
  * decode mode: one new token per sequence against a contiguous KV cache
    (B, S_max, H_kv, D) written at position ``pos``.

The paged-KV decode path used by the serving engine lives in
``repro.kernels`` (paged_attention) — the contiguous path here is what the
distributed dry-run lowers.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.layers import _dense_init, apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, nq * hd)),
        "wk": _dense_init(ks[1], (d, nkv * hd)),
        "wv": _dense_init(ks[2], (d, nkv * hd)),
        "wo": _dense_init(ks[3], (nq * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((nkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((nkv * hd,), jnp.float32)
    return p


def _project_qkv(p, x, cfg: ModelConfig):
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, T, cfg.n_heads, hd)
    k = k.reshape(B, T, cfg.n_kv_heads, hd)
    v = v.reshape(B, T, cfg.n_kv_heads, hd)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q: (B,T,Hq,D) k,v: (B,S,Hkv,D); mask: broadcast (B,1,T,S) bool.

    K/V are consumed in their storage dtype with fp32 ACCUMULATION
    (preferred_element_type) — materializing fp32 copies of the KV cache
    would dominate decode HBM traffic (§Perf: observed 24 GB/step/device
    on mistral-nemo decode_32k before this change)."""
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, T, Hkv, group, D)
    logits = jnp.einsum("bthgd,bshd->bhgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                           logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, T, Hq, D).astype(q.dtype)


# Sequence lengths above this use the q-chunked path (peak attention
# memory (B, H, CHUNK_Q, S) instead of (B, H, T, S) — the pure-jnp
# analogue of flash attention for long-prefill lowering).
CHUNK_THRESHOLD = 4096
CHUNK_Q = 1024


def _sdpa_chunked(q, k, v, scale, window: Optional[int] = None,
                  chunk_q: int = CHUNK_Q):
    """Causal attention, scanned over query chunks.  q: (B,T,Hq,D),
    k/v: (B,S,Hkv,D) with S == T (self-attention sequence mode).

    §Perf note (refuted hypothesis, kept for the record): statically
    slicing K/V per chunk to skip fully-masked keys should halve the
    attention flops, but K/V are SHARDED over `model` on their sequence
    dim here — slicing a sharded dim forces GSPMD into full-shape
    resharding (measured: zero flops change, +4x temp memory).  The real
    tile-skip belongs in the Pallas flash kernel (kernels/flash_attention)
    where the grid owns the layout.  This path keeps the masked full-S
    compute with fp32-accumulation einsums (bf16 operand I/O).
    """
    B, T, Hq, D = q.shape
    S = k.shape[1]
    Hkv = k.shape[2]
    group = Hq // Hkv
    pad = (-T) % chunk_q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // chunk_q
    qs = jnp.moveaxis(q.reshape(B, nq, chunk_q, Hq, D), 1, 0)
    kj = jnp.arange(S)[None, :]

    def body(_, xs):
        ci, qc = xs                                    # qc: (B,cq,Hq,D)
        qg = qc.reshape(B, chunk_q, Hkv, group, D)
        logits = jnp.einsum("bthgd,bshd->bhgts", qg, k,
                            preferred_element_type=jnp.float32) * scale
        qi = ci * chunk_q + jnp.arange(chunk_q)[:, None]
        m = kj <= qi                                   # (cq, S)
        if window is not None:
            m = m & (kj > qi - window)
        logits = jnp.where(m[None, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgts,bshd->bthgd", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return None, out.reshape(B, chunk_q, Hq, D).astype(q.dtype)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * chunk_q, Hq, D)
    return out[:, :T]


def causal_mask(T: int, S: int, window: Optional[int] = None,
                offset: int = 0) -> jnp.ndarray:
    """(1, 1, T, S) bool; query i attends key j iff j <= i+offset and within
    window (if set)."""
    qi = jnp.arange(T)[:, None] + offset
    kj = jnp.arange(S)[None, :]
    m = kj <= qi
    if window is not None:
        m = m & (kj > qi - window)
    return m[None, None]


def gqa_forward(p, x, cfg: ModelConfig, positions, window: Optional[int] = None):
    """Sequence mode (train/prefill).  Returns (out, (k, v)) for cache init."""
    B, T, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    from repro.models.sharding import constrain_kv_seq
    k = constrain_kv_seq(k)
    v = constrain_kv_seq(v)
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    if T > CHUNK_THRESHOLD:
        out = _sdpa_chunked(q, k, v, scale, window=window)
    else:
        mask = causal_mask(T, T, window)
        out = _sdpa(q, k, v, mask, scale)
    out = out.reshape(B, T, -1) @ p["wo"].astype(x.dtype)
    return out, (k, v)


# int8 KV-cache quantization scale (beyond-paper §Perf optimization for
# memory-bound decode: halves the dominant HBM term vs bf16).  A fixed
# symmetric scale keeps the dry-run structural; a deployment would carry
# per-head running scales alongside the pool.
KV_QSCALE = 0.05


def gqa_decode(p, x, cfg: ModelConfig, cache_k, cache_v, pos,
               window: Optional[int] = None, ring: bool = False):
    """Decode one token.  x: (B, 1, d); cache_k/v: (B, S, Hkv, D);
    pos: scalar int32 — number of tokens already in the cache.

    When ``ring`` is True the cache is a ring buffer of size W (sliding
    window): the new token is written at pos % W and all S slots are valid
    once pos >= W.  int8 caches are quantized on write / dequantized on
    read.  Returns (out, cache_k, cache_v).
    """
    B, S, Hkv, D = cache_k.shape
    q, k, v = _project_qkv(p, x, cfg)
    q = apply_rope(q, jnp.full((B, 1), pos), cfg.rope_theta)
    k = apply_rope(k, jnp.full((B, 1), pos), cfg.rope_theta)
    quant = cache_k.dtype == jnp.int8
    if quant:
        qz = lambda a: jnp.clip(jnp.round(a.astype(jnp.float32) / KV_QSCALE),
                                -127, 127).astype(jnp.int8)
        k_w, v_w = qz(k), qz(v)
    else:
        k_w, v_w = k, v
    slot = pos % S if ring else pos
    cache_k = jax.lax.dynamic_update_slice(cache_k, k_w, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v_w, (0, slot, 0, 0))
    if quant:
        k_r = cache_k.astype(x.dtype) * KV_QSCALE
        v_r = cache_v.astype(x.dtype) * KV_QSCALE
    else:
        k_r, v_r = cache_k, cache_v
    kj = jnp.arange(S)
    if ring:
        valid = kj < jnp.minimum(pos + 1, S)          # ring: all written slots
    else:
        valid = kj <= pos
        if window is not None:
            valid = valid & (kj > pos - window)
    mask = valid[None, None, None, :]                  # (1,1,1,S) -> T=1
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    out = _sdpa(q, k_r, v_r, mask, scale)
    out = out.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    return out, cache_k, cache_v


def init_cross_attention(key, cfg: ModelConfig):
    """Whisper-style cross attention (no RoPE, kv from encoder)."""
    return init_gqa(key, cfg)


def cross_attention(p, x, enc_kv, cfg: ModelConfig):
    """x: (B, T, d); enc_kv: (k, v) each (B, S_enc, Hkv, D)."""
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, T, cfg.n_heads, hd)
    k, v = enc_kv
    scale = 1.0 / math.sqrt(hd)
    out = _sdpa(q, k, v, None, scale)
    return out.reshape(B, T, -1) @ p["wo"].astype(dt)


def project_cross_kv(p, enc_out, cfg: ModelConfig):
    B, S, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    dt = enc_out.dtype
    k = (enc_out @ p["wk"].astype(dt)).reshape(B, S, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"].astype(dt)).reshape(B, S, cfg.n_kv_heads, hd)
    return k, v


# ---------------------------------------------------------------------------
# DeepSeek-V2 MLA (Multi-head Latent Attention)
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    latent: jnp.ndarray   # (B, S, kv_lora_rank)
    k_rope: jnp.ndarray   # (B, S, rope_head_dim)


def init_mla(key, cfg: ModelConfig):
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qd = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "w_dq": _dense_init(ks[0], (d, m.q_lora_rank)),
        "w_uq": _dense_init(ks[1], (m.q_lora_rank, H * qd)),
        "w_dkv": _dense_init(ks[2], (d, m.kv_lora_rank)),
        "w_krope": _dense_init(ks[3], (d, m.rope_head_dim)),
        "w_uk": _dense_init(ks[4], (m.kv_lora_rank, H * m.nope_head_dim)),
        "w_uv": _dense_init(ks[5], (m.kv_lora_rank, H * m.v_head_dim)),
        "wo": _dense_init(ks[6], (H * m.v_head_dim, d)),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
    }


def _rms(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
            * scale).astype(x.dtype)


def mla_forward(p, x, cfg: ModelConfig, positions):
    """Sequence mode.  Returns (out, MLACache)."""
    m: MLAConfig = cfg.mla
    B, T, _ = x.shape
    H = cfg.n_heads
    dt = x.dtype
    cq = _rms(x @ p["w_dq"].astype(dt), p["q_norm"])
    q = (cq @ p["w_uq"].astype(dt)).reshape(B, T, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    latent = _rms(x @ p["w_dkv"].astype(dt), p["kv_norm"])          # (B,T,r)
    k_rope = apply_rope(x @ p["w_krope"].astype(dt), positions, cfg.rope_theta)
    k_nope = (latent @ p["w_uk"].astype(dt)).reshape(B, T, H, m.nope_head_dim)
    v = (latent @ p["w_uv"].astype(dt)).reshape(B, T, H, m.v_head_dim)
    from repro.models.sharding import constrain_kv_seq
    k_nope = constrain_kv_seq(k_nope)
    v = constrain_kv_seq(v)

    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    # fold the rope parts into standard per-head attention inputs so the
    # shared (chunked) SDPA path applies
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)              # (B,T,H,dn+dr)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, T, H, m.rope_head_dim))], axis=-1)
    if m.v_head_dim < q_full.shape[-1]:
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                            (0, q_full.shape[-1] - m.v_head_dim)))
    else:
        v_pad = v
    if T > CHUNK_THRESHOLD:
        out = _sdpa_chunked(q_full, k_full, v_pad, scale)
    else:
        out = _sdpa(q_full, k_full, v_pad, causal_mask(T, T), scale)
    out = out[..., :m.v_head_dim]
    out = out.reshape(B, T, -1) @ p["wo"].astype(dt)
    return out, MLACache(latent=latent, k_rope=k_rope)


def mla_decode(p, x, cfg: ModelConfig, cache: MLACache, pos):
    """Absorbed-weight decode: scores/value read directly on the latent cache
    (the 18x-smaller cache that makes FastSwitch blocks tiny — see DESIGN.md).
    x: (B, 1, d)."""
    m: MLAConfig = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    dt = x.dtype
    cq = _rms(x @ p["w_dq"].astype(dt), p["q_norm"])
    q = (cq @ p["w_uq"].astype(dt)).reshape(B, 1, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = apply_rope(q_rope, jnp.full((B, 1), pos), cfg.rope_theta)

    latent_t = _rms(x @ p["w_dkv"].astype(dt), p["kv_norm"])        # (B,1,r)
    k_rope_t = apply_rope(x @ p["w_krope"].astype(dt),
                          jnp.full((B, 1), pos), cfg.rope_theta)
    latent = jax.lax.dynamic_update_slice(cache.latent, latent_t, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache.k_rope, k_rope_t, (0, pos, 0))

    # absorb w_uk into q:  q_abs (B,H,r)
    w_uk = p["w_uk"].astype(dt).reshape(m.kv_lora_rank, H, m.nope_head_dim)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    # latent cache consumed in storage dtype, fp32 accumulation (§Perf)
    logits = (jnp.einsum("bhr,bsr->bhs", q_abs, latent,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], k_rope,
                           preferred_element_type=jnp.float32)) * scale
    S = latent.shape[1]
    valid = (jnp.arange(S) <= pos)[None, None, :]
    logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs.astype(latent.dtype), latent,
                     preferred_element_type=jnp.float32)  # (B,H,r)
    w_uv = p["w_uv"].astype(dt).reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", ctx.astype(dt), w_uv)
    out = out.reshape(B, 1, -1) @ p["wo"].astype(dt)
    return out, MLACache(latent=latent, k_rope=k_rope)
