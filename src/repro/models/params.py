"""Parameter counting via jax.eval_shape (exact, zero allocation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@functools.lru_cache(maxsize=64)
def _count_cached(cfg: ModelConfig) -> int:
    from repro.models.transformer import init_params
    import math
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(math.prod(l.shape) if l.shape else 1
               for l in jax.tree.leaves(shapes))


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact param count from eval_shape.  ``active_only`` subtracts the
    non-activated routed-expert weights (MoE): active = total
    - (E - top_k)/E * routed_expert_params."""
    total = _count_cached(cfg)
    if not active_only or cfg.moe is None:
        return total
    m = cfg.moe
    # routed expert params per layer: 3 matrices (gate/up/down) of d*dff
    per_layer = 3 * cfg.d_model * m.d_expert_ff * m.n_experts
    n_moe_layers = cfg.n_layers - (1 if m.layer_pattern == "skip_first" else 0)
    routed_total = per_layer * n_moe_layers
    inactive_frac = (m.n_experts - m.top_k) / m.n_experts
    return total - int(routed_total * inactive_frac)
