"""Mixture-of-Experts FFN with top-k routing (OLMoE / DeepSeek-V2 style).

Dispatch is gather/scatter based (no (T, E, C) one-hot einsum): token ranks
within their expert come from an exclusive cumsum over the one-hot routing
matrix, tokens beyond expert capacity are dropped (scatter mode='drop'),
and expert outputs are scatter-added back with their gate weights.  This
keeps peak memory at (E, C, D) which shards over the `model` axis
(expert parallelism) under GSPMD.

Returns an auxiliary load-balance loss (Switch-style) for training.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import _dense_init


def init_moe(key, cfg: ModelConfig):
    m: MoEConfig = cfg.moe
    d, f = cfg.d_model, m.d_expert_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, m.n_experts), scale=0.02),
        "w_gate": _dense_init(ks[1], (m.n_experts, d, f)),
        "w_up": _dense_init(ks[2], (m.n_experts, d, f)),
        "w_down": _dense_init(ks[3], (m.n_experts, f, d)),
    }
    if m.n_shared_experts:
        from repro.models.layers import init_swiglu
        p["shared"] = init_swiglu(ks[4], d, f * m.n_shared_experts)
    return p


def moe_forward(p, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) -> (out, aux_loss)."""
    m: MoEConfig = cfg.moe
    B, T, d = x.shape
    dt = x.dtype
    xf = x.reshape(B * T, d)
    n_tok = B * T
    E, K = m.n_experts, m.top_k

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (N, E)
    gate_vals, eids = jax.lax.top_k(probs, K)                    # (N, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # ---- load-balance aux loss (Switch): E * sum_e f_e * p_e -------------
    me = jnp.mean(probs, axis=0)                                  # (E,)
    one_hot_top1 = jax.nn.one_hot(eids[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux_loss = E * jnp.sum(me * ce)

    # ---- capacity + ranks -------------------------------------------------
    capacity = int(math.ceil(n_tok * K / E * m.capacity_factor))
    capacity = max(capacity, 4)
    flat_eids = eids.reshape(-1)                                  # (N*K,)
    flat_gates = gate_vals.reshape(-1)
    onehot = jax.nn.one_hot(flat_eids, E, dtype=jnp.int32)        # (N*K, E)
    ranks_all = jnp.cumsum(onehot, axis=0) - onehot               # exclusive
    ranks = jnp.take_along_axis(ranks_all, flat_eids[:, None], 1)[:, 0]
    overflow = ranks >= capacity
    slot = jnp.where(overflow, capacity, ranks)                   # drop slot

    # ---- gather tokens into (E, C) buffers --------------------------------
    tok_idx = jnp.arange(n_tok * K, dtype=jnp.int32) // K         # source token
    buf_tok = jnp.full((E, capacity), n_tok, jnp.int32)           # sentinel
    buf_tok = buf_tok.at[flat_eids, slot].set(tok_idx, mode="drop")
    buf_gate = jnp.zeros((E, capacity), jnp.float32)
    buf_gate = buf_gate.at[flat_eids, slot].set(flat_gates, mode="drop")

    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), dt)], axis=0)
    xe = x_pad[buf_tok]                                           # (E, C, d)
    from repro.models.sharding import constrain_experts
    xe = constrain_experts(xe)                                    # EP over model

    # ---- expert compute (per-expert SwiGLU) --------------------------------
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))    # (E, C, d)

    # ---- combine: scatter-add back ----------------------------------------
    ye_w = ye * buf_gate[..., None].astype(dt)
    out = jnp.zeros((n_tok + 1, d), dt)
    out = out.at[buf_tok.reshape(-1)].add(ye_w.reshape(-1, d), mode="drop")
    out = out[:n_tok]

    if m.n_shared_experts:
        from repro.models.layers import swiglu
        out = out + swiglu(p["shared"], xf)

    return out.reshape(B, T, d), aux_loss
