"""Synthetic ShareGPT-like multi-turn conversation traces.

The paper (§4, Fig. 4) uses Multi-Round ShareGPT: ~5.5 turns/conversation
on average, 78 % multi-turn, log-normal-ish prompt/response lengths, and
Poisson arrivals at 1 req/s.  We generate statistically matched synthetic
conversations (the dataset itself is not redistributable offline).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class Turn:
    prompt_tokens: int
    response_tokens: int
    # actual prompt token ids (real mode, supplied by the serving client
    # at add_request/continue_session time); None for sim-mode traces
    prompt_ids: Optional[List[int]] = None


@dataclass
class Conversation:
    conv_id: int
    arrival_s: float            # first-turn arrival time
    turns: List[Turn]
    think_time_s: float = 5.0   # user gap between turns


def sample_conversations(n: int, *, rate_req_s: float = 1.0, seed: int = 0,
                         mean_turns: float = 5.5,
                         multi_turn_frac: float = 0.78,
                         prompt_mu: float = 4.6, prompt_sigma: float = 0.9,
                         resp_mu: float = 5.1, resp_sigma: float = 0.7,
                         max_tokens: int = 3500,
                         max_context: int = 6000) -> List[Conversation]:
    """Poisson arrivals; geometric-ish turn counts conditioned on the
    multi-turn fraction; log-normal prompt/response token lengths.
    ``max_context`` bounds the cumulative conversation context (a
    conversation must fit the serving pool, as in any deployed system)."""
    rng = random.Random(seed)
    out: List[Conversation] = []
    t = 0.0
    for i in range(n):
        t += rng.expovariate(rate_req_s)
        if rng.random() < multi_turn_frac:
            # shifted geometric with mean ~ (mean_turns - adj)
            p = 1.0 / (mean_turns - (1 - multi_turn_frac)) if mean_turns > 1 else 1.0
            k = 2 + _geometric(rng, p)
        else:
            k = 1
        turns = []
        ctx = 0
        for _ in range(k):
            pt = int(min(max_tokens, max(4, rng.lognormvariate(prompt_mu, prompt_sigma))))
            rt = int(min(max_tokens, max(4, rng.lognormvariate(resp_mu, resp_sigma))))
            if turns and ctx + pt + rt > max_context:
                break
            pt = min(pt, max(4, max_context - ctx - 8))
            rt = min(rt, max(4, max_context - ctx - pt))
            ctx += pt + rt
            turns.append(Turn(prompt_tokens=pt, response_tokens=rt))
        out.append(Conversation(conv_id=i, arrival_s=t, turns=turns,
                                think_time_s=max(0.5, rng.gauss(5.0, 2.0))))
    return out


def synth_prompt_ids(conv_id: int, turn_idx: int, n_tokens: int,
                     vocab_size: int) -> List[int]:
    """Deterministic synthetic prompt ids for one (conversation, turn) —
    the token stream real-mode replay clients submit via ``add_request``
    (a pure function of the ids, so any driver regenerates the identical
    prompt: the bit-exact-replay anchor)."""
    import numpy as np
    rng = np.random.RandomState((conv_id * 1009 + turn_idx) % (2 ** 31))
    return rng.randint(1, vocab_size, size=n_tokens).tolist()


def prompt_for_turn(conv: "Conversation", turn_idx: int,
                    vocab_size: Optional[int] = None):
    """What a replay client passes as ``add_request``'s prompt for one
    trace turn: the synthetic id stream when serving a real model
    (``vocab_size`` given), else just the sim-mode token count."""
    turn = conv.turns[turn_idx]
    if vocab_size is None:
        return turn.prompt_tokens
    return synth_prompt_ids(conv.conv_id, turn_idx, turn.prompt_tokens,
                            vocab_size)


def _geometric(rng: random.Random, p: float) -> int:
    """Number of failures before first success."""
    u = rng.random()
    return int(math.floor(math.log(max(u, 1e-12)) / math.log(max(1 - p, 1e-12))))


def trace_stats(convs: List[Conversation]) -> dict:
    turns = [len(c.turns) for c in convs]
    toks = [t.prompt_tokens + t.response_tokens for c in convs for t in c.turns]
    return {
        "n": len(convs),
        "mean_turns": sum(turns) / len(turns),
        "multi_turn_frac": sum(1 for k in turns if k > 1) / len(turns),
        "mean_turn_tokens": sum(toks) / len(toks),
    }
