"""Context-switching (priority) trace simulation — paper §4.

Two offline patterns, priorities recomputed every 1/frequency iterations:
  * Random: i.i.d. priorities each update (uncontrolled environment);
  * Markov: temporal locality — recently served requests keep high
    priority with probability ``stickiness``, others random-walk.
"""
from __future__ import annotations

import random
from typing import Dict, Iterable


class PriorityTrace:
    def __init__(self, pattern: str = "markov", update_freq: float = 0.02,
                 seed: int = 0, stickiness: float = 0.8):
        assert pattern in ("random", "markov")
        self.pattern = pattern
        self.period = max(1, int(round(1.0 / update_freq)))
        self.rng = random.Random(seed)
        self.stickiness = stickiness
        self._prio: Dict[int, float] = {}
        self._iter = 0

    def priority(self, req_id: int) -> float:
        if req_id not in self._prio:
            self._prio[req_id] = self.rng.random()
        return self._prio[req_id]

    def step(self, active_ids: Iterable[int], running_ids: Iterable[int]
             ) -> bool:
        """Advance one iteration; returns True when priorities were updated
        this iteration (scheduler must re-balance)."""
        self._iter += 1
        if self._iter % self.period != 0:
            return False
        running = set(running_ids)
        for rid in active_ids:
            if self.pattern == "random":
                self._prio[rid] = self.rng.random()
            else:  # markov: temporal locality
                if rid in running and self.rng.random() < self.stickiness:
                    # recently served stays high
                    self._prio[rid] = 0.5 + 0.5 * self.rng.random()
                else:
                    base = self._prio.get(rid, self.rng.random())
                    self._prio[rid] = min(1.0, max(
                        0.0, base + self.rng.uniform(-0.35, 0.35)))
        return True
