"""Paged KV cache pools (device + host) and block tables.

The GPU pool is a jnp array of shape (L, 2, num_blocks, block_size, Hkv, D)
(2 = K/V); the CPU pool is numpy with num_cpu_blocks.  The serving engine
moves whole blocks between them through the swap channel; the model decode
step reads the GPU pool through a block table (see kernels/paged_attention).

For trace-driven benchmarks the pools can be ``data=False`` (bookkeeping
only) so thousand-conversation runs stay fast.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class PoolSpec:
    n_layers: int
    n_kv_heads: int
    head_dim: int
    block_size: int          # tokens per block
    num_gpu_blocks: int
    num_cpu_blocks: int
    dtype: str = "bfloat16"

    @classmethod
    def from_config(cls, cfg: ModelConfig, num_gpu_blocks: int,
                    num_cpu_blocks: int, block_size: int = 16) -> "PoolSpec":
        return cls(n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
                   head_dim=cfg.resolved_head_dim, block_size=block_size,
                   num_gpu_blocks=num_gpu_blocks,
                   num_cpu_blocks=num_cpu_blocks)

    def block_bytes(self) -> int:
        """Bytes of ONE block across all layers and K+V (what one swap of
        one block moves)."""
        itemsize = 2 if self.dtype == "bfloat16" else 4
        return (self.n_layers * 2 * self.block_size * self.n_kv_heads
                * self.head_dim * itemsize)


class PagedPools:
    def __init__(self, spec: PoolSpec, with_data: bool = True):
        self.spec = spec
        self.with_data = with_data
        if with_data:
            s = spec
            self.gpu = jnp.zeros((s.n_layers, 2, s.num_gpu_blocks,
                                  s.block_size, s.n_kv_heads, s.head_dim),
                                 jnp.bfloat16)
            self.cpu = np.zeros((s.n_layers, 2, s.num_cpu_blocks,
                                 s.block_size, s.n_kv_heads, s.head_dim),
                                np.float32)
        else:
            self.gpu = None
            self.cpu = None

    # -- data plane (used by the swap channel worker threads) -------------

    def copy_out(self, gpu_blocks: List[int], cpu_blocks: List[int]) -> None:
        """GPU -> CPU block copy (d2h)."""
        if not self.with_data:
            return
        g = np.asarray(self.gpu[:, :, np.asarray(gpu_blocks)], np.float32)
        self.cpu[:, :, np.asarray(cpu_blocks)] = g

    def copy_in(self, cpu_blocks: List[int], gpu_blocks: List[int]) -> None:
        """CPU -> GPU block copy (h2d)."""
        if not self.with_data:
            return
        data = jnp.asarray(self.cpu[:, :, np.asarray(cpu_blocks)], jnp.bfloat16)
        self.gpu = self.gpu.at[:, :, np.asarray(gpu_blocks)].set(data)

    def write_tokens(self, block_ids: List[int], token_offset: int,
                     k: np.ndarray, v: np.ndarray) -> None:
        """Write per-layer K/V for contiguous tokens into the paged GPU pool.
        k, v: (L, T, Hkv, D); token_offset = index of first token in request.

        Host-side data-plane utility (tools/tests/parity baselines): the
        engine's prefill path now inserts KV on device through the
        DecodeRunner (``kernels.ops.insert_prefill``, DESIGN.md §3.5)."""
        if not self.with_data:
            return
        bs = self.spec.block_size
        k = np.asarray(k)
        v = np.asarray(v)
        T = k.shape[1]
        if T == 0:
            return
        if token_offset % bs == 0:
            # fused path (the engine always writes block-aligned): one
            # scatter for all touched blocks instead of 2 updates each.
            # The zero-padded tail of a partial last block lies beyond the
            # context length — masked by attention and overwritten by the
            # decode step before it ever becomes visible.
            L, _, H, D = k.shape
            nblk = (T + bs - 1) // bs
            pad = nblk * bs - T
            if pad:
                pw = ((0, 0), (0, pad), (0, 0), (0, 0))
                k = np.pad(k, pw)
                v = np.pad(v, pw)
            b0 = token_offset // bs
            blocks = np.asarray(block_ids[b0:b0 + nblk])
            kv = np.stack([k, v], axis=1).reshape(L, 2, nblk, bs, H, D)
            self.gpu = self.gpu.at[:, :, blocks].set(
                jnp.asarray(kv, jnp.bfloat16))
            return
        gpu = self.gpu
        for t0 in range(0, T, bs):
            t1 = min(t0 + bs, T)
            tok = token_offset + t0
            blk = block_ids[tok // bs]
            off = tok % bs
            gpu = gpu.at[:, 0, blk, off:off + (t1 - t0)].set(
                jnp.asarray(k[:, t0:t1], jnp.bfloat16))
            gpu = gpu.at[:, 1, blk, off:off + (t1 - t0)].set(
                jnp.asarray(v[:, t0:t1], jnp.bfloat16))
        self.gpu = gpu

    def read_tokens(self, block_ids: List[int], n_tokens: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Gather (L, T, Hkv, D) K and V for the first n_tokens of a request."""
        assert self.with_data
        bs = self.spec.block_size
        n_blocks = (n_tokens + bs - 1) // bs
        blocks = np.asarray(block_ids[:n_blocks])
        g = np.asarray(self.gpu[:, :, blocks])      # (L, 2, nb, bs, H, D)
        L, _, nb, _, H, D = g.shape
        flat = g.reshape(L, 2, nb * bs, H, D)[:, :, :n_tokens]
        return flat[:, 0], flat[:, 1]
