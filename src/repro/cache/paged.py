"""Paged KV cache pools (device + host) and block tables.

The GPU pool is a jnp array of shape (L, 2, num_blocks, block_size, Hkv, D)
(2 = K/V); the CPU pool is numpy with num_cpu_blocks storing the bf16 BIT
PATTERN as uint16 (half the host memory of a float32 store, and the d2h
leg needs no dtype conversion).  The serving engine moves whole blocks
between them through the swap channel; the model decode step reads the GPU
pool through a block table (see kernels/paged_attention).

Two data planes (DESIGN.md §4):
  * ``copy_out`` / ``copy_in`` — the host-mediated baseline (a blocking
    gather of the live pool, an un-donated full-pool ``.at[].set``); kept
    for parity tests and the swap_path benchmark baseline.
  * ``copy_out_staged`` / ``copy_in_staged`` — the engine's path: a
    grouped Pallas kernel stages a request's blocks into one contiguous
    device slab (one DMA chain per run), the slab crosses the PCIe/host
    link as a SINGLE transfer, and the swap-in scatter DONATES the pool
    (in-place write).  ``copy_in_staged`` rebinds ``self.gpu`` — the pool
    object is the owner-of-record; callers serialize under the engine's
    pool lock.

For trace-driven benchmarks the pools can be ``data=False`` (bookkeeping
only) so thousand-conversation runs stay fast.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops


@dataclass
class PoolSpec:
    n_layers: int
    n_kv_heads: int
    head_dim: int
    block_size: int          # tokens per block
    num_gpu_blocks: int
    num_cpu_blocks: int
    dtype: str = "bfloat16"

    @classmethod
    def from_config(cls, cfg: ModelConfig, num_gpu_blocks: int,
                    num_cpu_blocks: int, block_size: int = 16) -> "PoolSpec":
        return cls(n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
                   head_dim=cfg.resolved_head_dim, block_size=block_size,
                   num_gpu_blocks=num_gpu_blocks,
                   num_cpu_blocks=num_cpu_blocks)

    def block_bytes(self) -> int:
        """Bytes of ONE block across all layers and K+V (what one swap of
        one block moves)."""
        itemsize = 2 if self.dtype == "bfloat16" else 4
        return (self.n_layers * 2 * self.block_size * self.n_kv_heads
                * self.head_dim * itemsize)


class PagedPools:
    def __init__(self, spec: PoolSpec, with_data: bool = True, mesh=None,
                 stage_blocks: int = 16):
        """``mesh``: a ("data", "model") jax mesh — the GPU pool's KV
        head axis is then partitioned over ``model`` (NamedSharding,
        DESIGN.md §9) and every staged swap runs per shard: the slab
        stays head-sharded and the host link carries one transfer per
        chunk PER SHARD.  A 1-device mesh is normalized to None — the
        single-device data plane is byte-identical to the pre-mesh code
        (and the sharded path degrades to it bit-exactly).

        ``stage_blocks``: double-buffer granularity of ``copy_in_staged``
        — a swap-in larger than this many blocks is uploaded in
        stage-sized sub-slabs so the host gather + h2d of sub-slab k+1
        overlap the (async-dispatched, donated) scatter of sub-slab k.
        Each sub-slab counts as its own staged call in the transfer
        accounting.  <= 0 disables the split (one slab per call)."""
        self.spec = spec
        self.with_data = with_data
        self.stage_blocks = stage_blocks
        if mesh is not None and mesh.size == 1:
            mesh = None
        self.mesh = mesh
        self.n_shards = 1 if mesh is None else int(mesh.shape["model"])
        if mesh is not None:
            assert spec.n_kv_heads % self.n_shards == 0, (
                spec.n_kv_heads, self.n_shards)
        # host-transfer accounting (asserted by the per-shard swap tests;
        # each count is one device<->host hop — a sharded slab moves
        # n_shards of them, each 1/n_shards the bytes)
        self.d2h_transfers = 0
        self.h2d_transfers = 0
        self.staged_out_calls = 0
        self.staged_in_calls = 0
        if with_data:
            s = spec
            self.gpu = jnp.zeros((s.n_layers, 2, s.num_gpu_blocks,
                                  s.block_size, s.n_kv_heads, s.head_dim),
                                 jnp.bfloat16)
            if mesh is not None:
                from repro.models.sharding import pool_pspec
                self.gpu = jax.device_put(
                    self.gpu, jax.sharding.NamedSharding(mesh, pool_pspec()))
            # bf16 bit pattern: uint16 halves host memory vs the old
            # float32 store and the staged d2h path copies bytes verbatim
            self.cpu = np.zeros((s.n_layers, 2, s.num_cpu_blocks,
                                 s.block_size, s.n_kv_heads, s.head_dim),
                                np.uint16)
        else:
            self.gpu = None
            self.cpu = None

    def cpu_bf16(self) -> np.ndarray:
        """The host pool reinterpreted as bfloat16 (zero-copy view)."""
        return self.cpu.view(jnp.bfloat16)

    # -- baseline data plane (parity tests, swap_path benchmark) ----------

    def copy_out(self, gpu_blocks: List[int], cpu_blocks: List[int]) -> None:
        """GPU -> CPU block copy (d2h) — host-mediated baseline: one
        blocking gather of the live pool per call."""
        if not self.with_data:
            return
        g = np.asarray(self.gpu[:, :, np.asarray(gpu_blocks)])
        self.cpu[:, :, np.asarray(cpu_blocks)] = g.view(np.uint16)

    def copy_in(self, cpu_blocks: List[int], gpu_blocks: List[int]) -> None:
        """CPU -> GPU block copy (h2d), routed through the staged
        donating path.  This used to be an un-donated whole-pool
        ``.at[].set`` (fslint FS006); the staged route is bit-exact and
        writes in place.  Order-preserving run coalescing keeps the
        positional cpu<->gpu block pairing of the flat-list API."""
        if not self.with_data or not gpu_blocks:
            return
        runs: List[Tuple[int, int]] = []
        for b in gpu_blocks:
            if runs and b == runs[-1][0] + runs[-1][1]:
                runs[-1] = (runs[-1][0], runs[-1][1] + 1)
            else:
                runs.append((b, 1))
        self.copy_in_staged(cpu_blocks, runs)

    # -- staged data plane (the engine's swap path, DESIGN.md §4) ---------

    def copy_out_staged(self, gpu_runs: Sequence[Tuple[int, int]],
                        cpu_blocks: List[int]) -> None:
        """GPU -> CPU via the device staging slab: one grouped gather
        kernel coalesces ``gpu_runs`` [(start, n)] into a contiguous
        slab, then ONE d2h transfer moves the slab; the host side is a
        single vectorized store of the bf16 bit pattern."""
        if not self.with_data or not gpu_runs:
            return
        slab, total = ops.gather_swap_runs(self.gpu, gpu_runs,
                                           mesh=self.mesh)
        assert total == len(cpu_blocks), (total, len(cpu_blocks))
        # ONE d2h per shard (the slab prefix; head-sharded under a mesh)
        sliced = slab[:, :total]
        host = np.asarray(sliced)
        self.staged_out_calls += 1
        self.d2h_transfers += len(sliced.sharding.device_set)
        s = self.spec
        self.cpu[:, :, np.asarray(cpu_blocks)] = host.view(np.uint16).reshape(
            s.n_layers, 2, total, s.block_size, s.n_kv_heads, s.head_dim)

    def copy_in_staged(self, cpu_blocks: List[int],
                       gpu_runs: Sequence[Tuple[int, int]],
                       stage_blocks: Optional[int] = None) -> None:
        """CPU -> GPU via the host staging slab: a vectorized host
        gather, ONE h2d transfer per sub-slab, then a grouped scatter
        kernel with the pool DONATED (in-place write, never a full-pool
        copy).  REBINDS ``self.gpu`` — the pools object is the pool's
        owner-of-record; callers must hold the engine's pool lock.

        Double buffering: a call larger than ``stage_blocks`` (ctor
        default) is split into stage-sized sub-slabs.  The scatter of
        sub-slab k dispatches asynchronously (JAX async dispatch; the
        donation chain sequences it after sub-slab k-1's), so sub-slab
        k+1's host gather and upload run WHILE k scatters — the h2d leg
        and the device-side scatter pipeline instead of serializing.
        Each sub-slab counts as its own staged call, preserving the
        transfer-accounting invariant ``h2d_transfers == n_shards *
        staged_in_calls``.  One ``block_until_ready`` at the end keeps
        the residency contract of the single-slab path."""
        if not self.with_data or not gpu_runs:
            return
        s = self.spec
        total = sum(n for _, n in gpu_runs)
        assert total == len(cpu_blocks), (total, len(cpu_blocks))
        stage = self.stage_blocks if stage_blocks is None else stage_blocks
        if stage <= 0 or total <= stage:
            stages: List[List[Tuple[int, int]]] = [list(gpu_runs)]
        else:
            from repro.kernels.block_copy import split_runs
            stages = split_runs(gpu_runs, stage)
        C = s.n_layers * 2
        if self.mesh is not None:
            from repro.models.sharding import slab_pspec
            sharding = jax.sharding.NamedSharding(self.mesh, slab_pspec())
        pos = 0
        for runs_c in stages:
            cnt = sum(n for _, n in runs_c)
            # zeros, not empty: the pow2 pad tail is masked off by the
            # run lengths, but it IS uploaded and streamed through the
            # kernel — uninitialized bytes decode to NaN/denormal bf16,
            # which measurably slows the copy (earns nothing: one memset)
            slab = np.zeros((C, ops.slab_bucket_blocks(cnt), s.block_size,
                             s.n_kv_heads, s.head_dim), np.uint16)
            slab[:, :cnt] = self.cpu[
                :, :, np.asarray(cpu_blocks[pos:pos + cnt])].reshape(
                C, cnt, s.block_size, s.n_kv_heads, s.head_dim)
            pos += cnt
            # ONE h2d per shard (bucketed slab; head-sharded under a mesh)
            if self.mesh is None:
                dev = jnp.asarray(slab.view(jnp.bfloat16))
            else:
                dev = jax.device_put(slab.view(jnp.bfloat16), sharding)
            self.staged_in_calls += 1
            self.h2d_transfers += len(dev.sharding.device_set)
            self.gpu = ops.scatter_swap_runs(self.gpu, dev, runs_c,
                                             mesh=self.mesh)
        # Materialize before the caller releases the pool lock: a swap
        # task's future completing must mean THE DATA IS RESIDENT
        # (step-1 promotes on it).  A lazy donated scatter escaping the
        # lock both outlives the locals backing its host staging slab
        # and interleaves with the decode thread's donating dispatches
        # on the same pool chain — observed torn KV under storm
        # preemption (CPU donation is in-place).  The wait costs
        # worker-thread time only — never simulated time.
        jax.block_until_ready(self.gpu)

    def write_tokens(self, block_ids: List[int], token_offset: int,
                     k: np.ndarray, v: np.ndarray) -> None:
        """Write per-layer K/V for contiguous tokens into the paged GPU pool.
        k, v: (L, T, Hkv, D); token_offset = index of first token in request.

        Host-side data-plane utility (tools/tests/parity baselines): the
        engine's prefill path now inserts KV on device through the
        DecodeRunner (``kernels.ops.insert_prefill``, DESIGN.md §3.5)."""
        if not self.with_data:
            return
        bs = self.spec.block_size
        k = np.asarray(k)
        v = np.asarray(v)
        T = k.shape[1]
        if T == 0:
            return
        if token_offset % bs == 0:
            # fused path (the engine always writes block-aligned): one
            # scatter for all touched blocks instead of 2 updates each.
            # The zero-padded tail of a partial last block lies beyond the
            # context length — masked by attention and overwritten by the
            # decode step before it ever becomes visible.
            L, _, H, D = k.shape
            nblk = (T + bs - 1) // bs
            pad = nblk * bs - T
            if pad:
                pw = ((0, 0), (0, pad), (0, 0), (0, 0))
                k = np.pad(k, pw)
                v = np.pad(v, pw)
            b0 = token_offset // bs
            blocks = np.asarray(block_ids[b0:b0 + nblk])
            kv = np.stack([k, v], axis=1).reshape(L, 2, nblk, bs, H, D)
            # fslint: disable=FS006(host-side tool/test utility, not on the serving path)
            self.gpu = self.gpu.at[:, :, blocks].set(
                jnp.asarray(kv, jnp.bfloat16))
            return
        gpu = self.gpu
        for t0 in range(0, T, bs):
            t1 = min(t0 + bs, T)
            tok = token_offset + t0
            blk = block_ids[tok // bs]
            off = tok % bs
            # fslint: disable=FS006(host-side tool/test utility, not on the serving path)
            gpu = gpu.at[:, 0, blk, off:off + (t1 - t0)].set(
                jnp.asarray(k[:, t0:t1], jnp.bfloat16))
            # fslint: disable=FS006(host-side tool/test utility, not on the serving path)
            gpu = gpu.at[:, 1, blk, off:off + (t1 - t0)].set(
                jnp.asarray(v[:, t0:t1], jnp.bfloat16))
        self.gpu = gpu

    def read_tokens(self, block_ids: List[int], n_tokens: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Gather (L, T, Hkv, D) K and V for the first n_tokens of a request."""
        assert self.with_data
        bs = self.spec.block_size
        n_blocks = (n_tokens + bs - 1) // bs
        blocks = np.asarray(block_ids[:n_blocks])
        g = np.asarray(self.gpu[:, :, blocks])      # (L, 2, nb, bs, H, D)
        L, _, nb, _, H, D = g.shape
        flat = g.reshape(L, 2, nb * bs, H, D)[:, :, :n_tokens]
        return flat[:, 0], flat[:, 1]
