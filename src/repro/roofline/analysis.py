"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the spec:
    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
are parsed from the optimized HLO text by summing operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")

# e.g. "bf16[2,128,4096]{2,1,0}" — capture dtype + dims
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum output shape bytes of every collective op in optimized HLO.

    Uses the op RESULT shape (what actually crosses links for all-gather;
    a good proxy for the others), counted once per op instruction.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%name = bf16[...]{...} all-reduce(...)" or tuple results
        m = re.match(r"%?[\w.\-]+ = (.+?) ([\w\-]+)\(", s)
        if not m:
            continue
        shape_part, opname = m.groups()
        base = opname.rstrip("-start").rstrip("-done")
        matched = None
        for c in _COLLECTIVE_OPS:
            if opname == c or opname == c + "-start" or opname == c + "-done":
                matched = c
                break
        if matched is None or opname.endswith("-done"):
            continue
        # tuple "(" f32[..], f32[..] ")" or single shape
        total = 0
        for sh in re.findall(r"\w+\[[\d,]*\]", shape_part):
            total += _shape_bytes(sh)
        out[matched] += total
        counts[matched] += 1
    out["_op_counts"] = counts  # type: ignore
    return out


@dataclass
class RooflineTerms:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    n_chips: int
    collective_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0           # 6*N*D analytic
    bytes_per_chip_peak: float = 0.0   # from memory_analysis

    # NOTE: flops / hbm_bytes / collective_bytes are PER-DEVICE quantities
    # (cost_analysis and the optimized-HLO shapes are post-SPMD), so each
    # term is per-chip time directly — equivalent to the spec's
    # global_quantity / (chips * per_chip_rate).

    @property
    def t_compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute_s, "memory": self.t_memory_s,
                 "collective": self.t_collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS (global) vs total compiled flops (per-dev x chips)."""
        total = self.flops * self.n_chips
        return self.model_flops / total if total else 0.0

    def as_dict(self) -> Dict:
        return {
            "flops_source": getattr(self, "flops_source", "hlo"),
            "bytes_source": getattr(self, "bytes_source", "hlo"),
            "hlo_flops": getattr(self, "hlo_flops", 0.0),
            "hlo_bytes": getattr(self, "hlo_bytes", 0.0),
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "t_compute_s": self.t_compute_s,
            "t_memory_s": self.t_memory_s,
            "t_collective_s": self.t_collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "bytes_per_chip_peak": self.bytes_per_chip_peak,
            "collective_breakdown": {
                k: v for k, v in self.collective_breakdown.items()
                if not k.startswith("_")},
            "n_chips": self.n_chips,
        }


def analyze(compiled, n_chips: int, model_flops: float = 0.0,
            hlo_text: Optional[str] = None,
            analytic_flops_dev: float = 0.0,
            analytic_bytes_dev: float = 0.0) -> RooflineTerms:
    """NOTE on sources: ``cost_analysis()`` values are PER-DEVICE after SPMD
    partitioning.  On the CPU backend XLA does not multiply while-loop
    (lax.scan) bodies by their trip count for programs under ``grad`` —
    verified empirically (7-layer and 14-layer train steps report identical
    flops).  We therefore floor the HLO numbers with analytic per-device
    estimates and record which source won (``flops_source``)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):               # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    flops_src = "hlo"
    bytes_src = "hlo"
    if analytic_flops_dev > flops:
        flops = analytic_flops_dev
        flops_src = "analytic"
    if analytic_bytes_dev > hbm:
        hbm = analytic_bytes_dev
        bytes_src = "analytic"
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes_from_hlo(text)
    coll_total = sum(v for k, v in coll.items() if not k.startswith("_"))
    peak = 0.0
    try:
        ma = compiled.memory_analysis()
        peak = float(getattr(ma, "temp_size_in_bytes", 0)
                     + getattr(ma, "argument_size_in_bytes", 0)
                     + getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    t = RooflineTerms(flops=flops, hbm_bytes=hbm,
                      collective_bytes=coll_total, n_chips=n_chips,
                      collective_breakdown=coll,
                      model_flops=model_flops,
                      bytes_per_chip_peak=peak)
    t.flops_source = flops_src        # type: ignore[attr-defined]
    t.bytes_source = bytes_src        # type: ignore[attr-defined]
    t.hlo_flops = float(ca.get("flops", 0.0))    # type: ignore
    t.hlo_bytes = float(ca.get("bytes accessed", 0.0))  # type: ignore
    return t


def _attn_context(cfg, S: int):
    """Per-layer (context_len, n_layers) pairs for attention-flops floors,
    respecting sliding windows / hybrid patterns / recurrent blocks."""
    lp = cfg.layer_pattern
    if lp == "rwkv":
        # linear recurrence: state ops, no context scan
        return [(0, cfg.n_layers)]
    if lp.startswith("local_global"):
        r = int(lp.split(":")[1])
        period = r + 1
        n_glob = cfg.n_layers // period
        w = min(cfg.sliding_window or S, S)
        return [(w, cfg.n_layers - n_glob), (S, n_glob)]
    if lp == "zamba2":
        n_attn = cfg.n_layers // cfg.hybrid_attn_every
        return [(S, n_attn), (0, cfg.n_layers - n_attn)]
    return [(S, cfg.n_layers)]


def analytic_floors(cfg, shape, n_chips: int):
    """Per-device (flops, hbm_bytes) lower-bound estimates used to floor
    XLA's (scan-undercounting) CPU cost analysis.  Matmul flops from
    active params; attention context per the layer pattern; HBM traffic
    from param/optimizer reads + activation/KV movement."""
    import math

    from repro.models.params import count_params_analytic
    n_act = count_params_analytic(cfg, active_only=True)
    B = shape.global_batch
    S = min(shape.seq_len, cfg.max_seq_len) if cfg.encoder_decoder \
        else shape.seq_len
    Hq, hd, d, L = cfg.n_heads, cfg.resolved_head_dim, cfg.d_model, cfg.n_layers

    if shape.kind == "train":
        tokens = B * S
        if cfg.encoder_decoder:
            n_enc, n_dec = _encdec_param_split(cfg)
            flops = 6.0 * (n_enc * B * cfg.n_encoder_tokens
                           + n_dec * tokens)
        else:
            flops = 6.0 * n_act * tokens
        for ctx, nl in _attn_context(cfg, S):
            # fwd 2*B*S*ctx*Hq*hd (QK+AV, causal/2) x3 for backward
            flops += 3.0 * 2.0 * B * S * max(ctx, 1) / 2 * Hq * hd * nl \
                if ctx else 3.0 * 2.0 * B * S * Hq * hd * hd * nl
        bytes_dev = (16.0 * n_act / n_chips             # p+g+opt fp32 traffic
                     + 20.0 * tokens * d * L / n_chips)  # acts fwd+bwd+remat
        return flops / n_chips, bytes_dev
    if shape.kind == "prefill":
        tokens = B * S
        if cfg.encoder_decoder:
            n_enc, n_dec = _encdec_param_split(cfg)
            flops = 2.0 * (n_enc * B * cfg.n_encoder_tokens
                           + n_dec * tokens)
        else:
            flops = 2.0 * n_act * tokens
        for ctx, nl in _attn_context(cfg, S):
            flops += (2.0 * B * S * max(ctx, 1) / 2 * Hq * hd * nl
                      if ctx else 2.0 * B * S * Hq * hd * hd * nl)
        bytes_dev = (2.0 * n_act / n_chips
                     + 6.0 * tokens * d * L / n_chips)
        return flops / n_chips, bytes_dev
    # decode: one token per sequence; the cache read dominates memory
    flops = 2.0 * n_act * B
    for ctx, nl in _attn_context(cfg, S):
        flops += (4.0 * B * ctx * Hq * hd * nl if ctx
                  else 4.0 * B * Hq * hd * hd * nl)
    import jax
    import jax.numpy as jnp

    from repro.models import transformer as T
    caches = jax.eval_shape(lambda: T.init_caches(cfg, B, S))
    cache_bytes = sum(math.prod(l.shape) * l.dtype.itemsize
                      for l in jax.tree.leaves(caches))
    bytes_dev = (2.0 * n_act + cache_bytes) / n_chips
    return flops / n_chips, bytes_dev


def _encdec_param_split(cfg):
    """(encoder_params, other_params) for enc-dec models — the encoder
    processes n_encoder_tokens frames, not the decoder sequence."""
    import math

    import jax
    import jax.numpy as jnp

    from repro.models.transformer import init_params
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    n_enc = sum(math.prod(l.shape) if l.shape else 1
                for l in jax.tree.leaves(shapes.get("enc_layers", {})))
    n_total = sum(math.prod(l.shape) if l.shape else 1
                  for l in jax.tree.leaves(shapes))
    return n_enc, n_total - n_enc


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (forward-only), N = active
    params for MoE.  Enc-dec models split: encoder params x encoder
    tokens + decoder params x decoder tokens."""
    from repro.models.params import count_params_analytic
    B = shape.global_batch
    k = 6.0 if shape.kind == "train" else 2.0
    if cfg.encoder_decoder:
        n_enc, n_dec = _encdec_param_split(cfg)
        if shape.kind == "decode":
            return k * n_dec * B
        s_dec = min(shape.seq_len, cfg.max_seq_len)
        return k * (n_enc * B * cfg.n_encoder_tokens + n_dec * B * s_dec)
    n = count_params_analytic(cfg, active_only=True)
    if shape.kind == "decode":
        return k * n * B
    return k * n * B * shape.seq_len
