"""Asyncio streaming front-end over N ``ServingEngine`` replicas.

Stdlib only (``asyncio.start_server`` + JSON lines) — the point is the
serving architecture, not an HTTP framework:

* One ``EngineReplica`` per engine, each with a DEDICATED step-loop
  thread.  The engine is single-threaded by contract (fslint FS006
  enforces it inside the engine); the replica thread is the only code
  that ever touches it.  The asyncio side talks to a replica through a
  call queue — ``EngineReplica.call`` returns a
  ``concurrent.futures.Future`` which coroutines consume via
  ``asyncio.wrap_future`` (never ``.result()`` — FS007 flags blocking
  calls on the event loop, and this server must pass its own lint).
* New sessions funnel through the ``FairAdmissionQueue``; a single
  dispatcher coroutine pops in VTC order, routes with least-predicted
  TTFT (``Router``), and charges the client's counter only on a
  SUCCESSFUL engine submit.  Follow-up turns skip queueing (their KV is
  resident — making them wait would throw the reuse copy's value away)
  but still bill their decode tokens, so a chatty session keeps paying.
* Backpressure ladder (DESIGN.md §11): admission queue at capacity ->
  429 refusal at the door; engine ``EngineOverloadError`` at dispatch ->
  silent requeue-front (the client keeps its position, pays nothing);
  ``drain`` -> 503 for everything new while in-flight work finishes.
* A client disconnect aborts every live request it owns, releases its
  parked sessions and purges its queued tickets — a dead socket must
  not hold GPU blocks.

Protocol: newline-delimited JSON, one object per line, both ways.
Client ops: ``submit``, ``continue``, ``abort``, ``release``,
``drain``.  Server events: ``accepted``, ``token``, ``finish``,
``drained``, ``error`` (with an HTTP-ish ``code``).
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import json
import queue as _queue
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.faults import EngineDrainingError, EngineOverloadError
from repro.core.request_api import SamplingParams, SLOSpec
from repro.core.serving import ServingEngine
from repro.frontend.admission import (FairAdmissionQueue, QueueFullError,
                                      slo_priority)
from repro.frontend.router import Router

_STOP = object()


class EngineReplica:
    """One engine + its step-loop thread.  All engine access happens on
    that thread: coroutines enqueue closures via ``call`` and await the
    returned future.  Between calls the thread steps the engine while it
    has work, publishes a fresh ``load_snapshot`` (plain dict ref-swap —
    readers on any thread see a coherent sample) and hands each step's
    outputs to the asyncio loop via ``call_soon_threadsafe``."""

    def __init__(self, index: int, engine: ServingEngine,
                 loop: asyncio.AbstractEventLoop, on_outputs):
        self.index = index
        self.engine = engine
        self._loop = loop
        self._on_outputs = on_outputs      # fn(index, outputs), runs on loop
        self._calls: _queue.Queue = _queue.Queue()
        self._snapshot: Dict[str, object] = engine.load_snapshot()
        self._stopped = False
        self._thread = threading.Thread(
            target=self._run, name=f"replica-{index}", daemon=True)

    def start(self) -> None:
        self._thread.start()

    def stop(self) -> None:
        self._stopped = True
        self._calls.put(_STOP)
        self._thread.join(timeout=10.0)
        # cancel any call that raced in behind the sentinel — an
        # awaiter must never block on a thread that has exited
        while True:
            try:
                item = self._calls.get_nowait()
            except _queue.Empty:
                break
            if item is not _STOP:
                item[0].cancel()

    def snapshot(self) -> Dict[str, object]:
        return self._snapshot

    def call(self, fn, *args, **kwargs) -> concurrent.futures.Future:
        fut: concurrent.futures.Future = concurrent.futures.Future()
        if self._stopped:
            fut.cancel()
            return fut
        self._calls.put((fut, fn, args, kwargs))
        return fut

    # -- step-loop thread --------------------------------------------------

    def _drain_calls(self, block: bool) -> bool:
        """Run every queued call (admission/abort beats stepping).
        Returns False when the stop sentinel arrived."""
        first = True
        while True:
            try:
                if block and first:
                    item = self._calls.get(timeout=0.02)
                else:
                    item = self._calls.get_nowait()
            except _queue.Empty:
                return True
            first = False
            if item is _STOP:
                return False
            fut, fn, args, kwargs = item
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                res = fn(*args, **kwargs)
            except BaseException as e:           # delivered to the awaiter
                fut.set_exception(e)
            else:
                # publish the post-call snapshot BEFORE resolving, so an
                # awaiter's next routing decision sees this call's load
                self._snapshot = self.engine.load_snapshot()
                fut.set_result(res)

    def _run(self) -> None:
        while True:
            idle = not self.engine.has_work()
            if not self._drain_calls(block=idle):
                break
            if self.engine.has_work():
                outs = self.engine.step()
                if outs and self._on_outputs is not None:
                    self._loop.call_soon_threadsafe(
                        self._on_outputs, self.index, outs)
            self._snapshot = self.engine.load_snapshot()


@dataclass
class _Session:
    handle: int
    client: str
    conn: "_Conn"
    retain: bool
    live: bool = False        # a turn is in flight on the engine
    parked: bool = False      # finished + retained, awaiting follow-up


@dataclass
class _Ticket:
    """One queued ``submit`` awaiting fair dispatch."""
    handle: int
    conn: "_Conn"
    req_id: Optional[object]
    prompt: object
    sampling: SamplingParams
    slo: Optional[SLOSpec]
    retain: bool

    def prompt_tokens(self) -> int:
        return self.prompt if isinstance(self.prompt, int) else len(self.prompt)


@dataclass
class _Conn:
    writer: asyncio.StreamWriter
    client: str = "anon"
    handles: Set[int] = field(default_factory=set)
    sendq: "asyncio.Queue[Optional[bytes]]" = field(
        default_factory=asyncio.Queue)
    closed: bool = False

    def send(self, obj: Dict[str, object]) -> None:
        """Queue one JSON line (callable from loop callbacks — the
        sender task owns the actual socket writes + drain)."""
        if not self.closed:
            self.sendq.put_nowait(
                json.dumps(obj, separators=(",", ":")).encode() + b"\n")


class FrontendServer:
    """Owns the replicas, the fair queue, the router and the listener.

    ``engines`` are fully-constructed ``ServingEngine``s (the caller
    wires event sinks — e.g. one JSONL file per replica, written only
    from that replica's thread, so the logs need no locking)."""

    def __init__(self, engines: List[ServingEngine], *,
                 host: str = "127.0.0.1", port: int = 0,
                 admission_capacity: int = 256,
                 weights: Optional[Dict[str, float]] = None,
                 migrate_threshold: int = 4,
                 rebalance_period_s: float = 0.05):
        self.host, self.port = host, port
        self.loop = asyncio.get_event_loop()
        self.queue = FairAdmissionQueue(capacity=admission_capacity,
                                        weights=weights)
        self.router = Router(len(engines), migrate_threshold=migrate_threshold)
        self.replicas = [EngineReplica(i, e, self.loop, self._on_outputs)
                         for i, e in enumerate(engines)]
        self.sessions: Dict[int, _Session] = {}
        self._next_handle = 0
        self._kick = asyncio.Event()
        self._migrating: Dict[int, asyncio.Event] = {}
        self._busy: Set[int] = set()       # follow-up dispatch in flight
        self._draining = False
        self._drain_waiters: List[_Conn] = []
        self._rebalance_period_s = rebalance_period_s
        self._server: Optional[asyncio.AbstractServer] = None
        self._tasks: List[asyncio.Task] = []
        self._conn_tasks: Set[asyncio.Task] = set()

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> Tuple[str, int]:
        for r in self.replicas:
            r.start()
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self._tasks.append(asyncio.ensure_future(self._dispatcher()))
        self._tasks.append(asyncio.ensure_future(self._rebalancer()))
        sock = self._server.sockets[0]
        self.host, self.port = sock.getsockname()[:2]
        return self.host, self.port

    async def close(self) -> None:
        for t in self._tasks:
            t.cancel()
        for t in self._tasks:
            try:
                await t
            except (asyncio.CancelledError, Exception):
                pass
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        # on 3.10 ``wait_closed`` does NOT wait for connection handler
        # tasks — cancel and await them BEFORE stopping the replicas so
        # their disconnect cleanup (abort/release engine calls) still
        # has live step-loop threads to run against
        for t in list(self._conn_tasks):
            t.cancel()
        for t in list(self._conn_tasks):
            try:
                await t
            except (asyncio.CancelledError, Exception):
                pass
        for r in self.replicas:
            r.stop()

    # -- token/finish fan-out (loop thread, via call_soon_threadsafe) ------

    def _on_outputs(self, index: int, outs) -> None:
        for out in outs:
            sess = self.sessions.get(out.handle)
            if sess is None:
                continue
            if out.new_tokens > 0:
                self.queue.feedback(sess.client, out.new_tokens)
                ev: Dict[str, object] = {
                    "event": "token", "handle": out.handle,
                    "new_tokens": out.new_tokens, "generated": out.generated,
                }
                if out.token_ids is not None:
                    ev["token_ids"] = list(out.token_ids)
                if out.first_token:
                    ev["first"] = True
                sess.conn.send(ev)
            if out.finished:
                sess.live = False
                self.queue.done(sess.client)
                retained = sess.retain and out.finish_reason in ("length",
                                                                 "stop")
                sess.parked = retained
                sess.conn.send({
                    "event": "finish", "handle": out.handle,
                    "reason": out.finish_reason, "generated": out.generated,
                    "retained": retained,
                })
                if not retained:
                    self._forget(sess)

    def _forget(self, sess: _Session) -> None:
        self.sessions.pop(sess.handle, None)
        sess.conn.handles.discard(sess.handle)
        self.router.release(sess.handle)

    # -- fair dispatch -----------------------------------------------------

    async def _dispatcher(self) -> None:
        while True:
            await self._kick.wait()
            self._kick.clear()
            while True:
                popped = self.queue.pop()
                if popped is None:
                    break
                client, ticket = popped
                if ticket.conn.closed:
                    self.queue.done(client)
                    self.router.release(ticket.handle)
                    continue
                snaps = [r.snapshot() for r in self.replicas]
                try:
                    idx = self.router.route_new(ticket.handle, snaps)
                except RuntimeError:           # every replica draining
                    self.queue.done(client)
                    self._refuse(ticket, 503, "all replicas draining")
                    continue
                rep = self.replicas[idx]
                try:
                    await asyncio.wrap_future(rep.call(
                        rep.engine.add_request, ticket.prompt,
                        ticket.sampling, slo=ticket.slo,
                        handle=ticket.handle, retain_kv=ticket.retain,
                        priority=slo_priority(ticket.slo)))
                except EngineOverloadError:
                    # not a refusal: requeue at the front, uncharged, and
                    # let in-flight work drain before trying again
                    self.router.release(ticket.handle)
                    self.queue.requeue(client, ticket)
                    await asyncio.sleep(0.02)
                    self._kick.set()
                    break
                except EngineDrainingError:
                    self.queue.done(client)
                    self.router.release(ticket.handle)
                    self._refuse(ticket, 503, "replica draining")
                    continue
                self.queue.charge(client, ticket.prompt_tokens())
                sess = self.sessions.get(ticket.handle)
                if sess is None:
                    # owner disconnected while the submit was in flight;
                    # the engine accepted it, so take it back out
                    await asyncio.wrap_future(rep.call(
                        rep.engine.abort, ticket.handle))
                    self.queue.done(client)
                    self.router.release(ticket.handle)
                    continue
                sess.live = True
                ticket.conn.send({"event": "accepted", "id": ticket.req_id,
                                  "handle": ticket.handle, "replica": idx})

    def _refuse(self, ticket: _Ticket, code: int, msg: str) -> None:
        self.sessions.pop(ticket.handle, None)
        ticket.conn.handles.discard(ticket.handle)
        ticket.conn.send({"event": "error", "id": ticket.req_id,
                          "code": code, "message": msg})

    # -- rebalancing -------------------------------------------------------

    async def _rebalancer(self) -> None:
        while True:
            await asyncio.sleep(self._rebalance_period_s)
            if self._draining:
                self._check_drained()
                continue
            snaps = [r.snapshot() for r in self.replicas]
            busy = self._busy | set(self._migrating)
            for handle, src, dst in self.router.plan_migrations(snaps, busy):
                sess = self.sessions.get(handle)
                if sess is None or not sess.parked or handle in self._busy:
                    continue
                gate = self._migrating[handle] = asyncio.Event()
                try:
                    try:
                        payload = await asyncio.wrap_future(
                            self.replicas[src].call(
                                self.replicas[src].engine.export_session,
                                handle))
                    except KeyError:
                        continue   # session left between planning and export
                    try:
                        await asyncio.wrap_future(self.replicas[dst].call(
                            self.replicas[dst].engine.import_session,
                            payload))
                        self.router.note_migrated(handle, dst)
                    except (EngineDrainingError, ValueError):
                        # dst refused: put the session back home (src just
                        # exported it, so the handle is free there again)
                        await asyncio.wrap_future(self.replicas[src].call(
                            self.replicas[src].engine.import_session,
                            payload))
                finally:
                    del self._migrating[handle]
                    gate.set()

    def _check_drained(self) -> None:
        if not self._drain_waiters:
            return
        if self.queue.depth() > 0:
            return
        for r in self.replicas:
            s = r.snapshot()
            if Router._load(s) > 0:
                return
        for conn in self._drain_waiters:
            conn.send({"event": "drained"})
        self._drain_waiters = []

    # -- per-connection protocol -------------------------------------------

    async def _sender(self, conn: _Conn) -> None:
        try:
            while True:
                buf = await conn.sendq.get()
                if buf is None:
                    break
                conn.writer.write(buf)
                await conn.writer.drain()
        except (ConnectionError, asyncio.CancelledError):
            pass

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        task = asyncio.current_task()
        if task is not None:
            self._conn_tasks.add(task)
        conn = _Conn(writer=writer)
        sender = asyncio.ensure_future(self._sender(conn))
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                try:
                    msg = json.loads(line)
                except ValueError:
                    conn.send({"event": "error", "code": 400,
                               "message": "bad json"})
                    continue
                await self._handle_msg(conn, msg)
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            if task is not None:
                self._conn_tasks.discard(task)
            conn.closed = True
            await self._on_disconnect(conn)
            conn.sendq.put_nowait(None)
            sender.cancel()
            try:
                await sender
            except asyncio.CancelledError:
                pass
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    @staticmethod
    def _parse_sampling(msg: Dict[str, object]) -> SamplingParams:
        return SamplingParams(
            max_tokens=int(msg.get("max_tokens", 16)),
            temperature=msg.get("temperature"),
            top_k=msg.get("top_k"), top_p=msg.get("top_p"),
            stop_token_ids=tuple(msg.get("stop_token_ids") or ()))

    @staticmethod
    def _parse_slo(msg: Dict[str, object]) -> Optional[SLOSpec]:
        slo = msg.get("slo")
        if not isinstance(slo, dict):
            return None
        return SLOSpec(ttft_ms=slo.get("ttft_ms"), tbt_ms=slo.get("tbt_ms"))

    async def _handle_msg(self, conn: _Conn, msg: Dict[str, object]) -> None:
        op = msg.get("op")
        rid = msg.get("id")
        if op == "submit":
            if self._draining:
                conn.send({"event": "error", "id": rid, "code": 503,
                           "message": "draining"})
                return
            conn.client = str(msg.get("client", conn.client))
            handle = self._next_handle
            self._next_handle += 1
            ticket = _Ticket(
                handle=handle, conn=conn, req_id=rid,
                prompt=msg["prompt"], sampling=self._parse_sampling(msg),
                slo=self._parse_slo(msg),
                retain=bool(msg.get("retain", True)))
            self.sessions[handle] = _Session(
                handle=handle, client=conn.client, conn=conn,
                retain=ticket.retain)
            conn.handles.add(handle)
            try:
                self.queue.push(conn.client, ticket)
            except QueueFullError as e:
                self.sessions.pop(handle, None)
                conn.handles.discard(handle)
                conn.send({"event": "error", "id": rid, "code": 429,
                           "message": str(e), "queue_depth": e.queue_depth})
                return
            self._kick.set()
        elif op == "continue":
            await self._handle_continue(conn, msg, rid)
        elif op == "abort":
            await self._handle_abort(conn, int(msg["handle"]))
        elif op == "release":
            await self._handle_release(conn, int(msg["handle"]))
        elif op == "drain":
            self._draining = True
            for r in self.replicas:
                await asyncio.wrap_future(r.call(r.engine.drain))
            self._drain_waiters.append(conn)
            self._check_drained()
        else:
            conn.send({"event": "error", "id": rid, "code": 400,
                       "message": f"unknown op {op!r}"})

    async def _handle_continue(self, conn: _Conn, msg: Dict[str, object],
                               rid) -> None:
        handle = int(msg["handle"])
        sess = self.sessions.get(handle)
        if sess is None or sess.conn is not conn or not sess.parked:
            conn.send({"event": "error", "id": rid, "code": 400,
                       "message": f"handle {handle} not continuable"})
            return
        # a rebalance may be moving this session between replicas —
        # follow-ups wait for the move, then route to the new home
        gate = self._migrating.get(handle)
        if gate is not None:
            await gate.wait()
        self._busy.add(handle)
        try:
            idx = self.router.route_followup(handle)
            rep = self.replicas[idx]
            slo = self._parse_slo(msg)
            prompt = msg["prompt"]
            try:
                await asyncio.wrap_future(rep.call(
                    rep.engine.continue_session, handle, prompt,
                    self._parse_sampling(msg), slo=slo,
                    retain_kv=bool(msg.get("retain", True)),
                    priority=slo_priority(slo)))
            except (EngineDrainingError, EngineOverloadError, KeyError) as e:
                code = 503 if isinstance(e, EngineDrainingError) else 429
                conn.send({"event": "error", "id": rid, "handle": handle,
                           "code": code, "message": str(e)})
                return
            # follow-ups skip the fair queue (their KV is resident) but
            # still bill the prompt so chatty sessions keep paying
            ntok = prompt if isinstance(prompt, int) else len(prompt)
            self.queue.begin(sess.client)
            self.queue.charge(sess.client, ntok)
            sess.parked = False
            sess.live = True
            conn.send({"event": "accepted", "id": rid, "handle": handle,
                       "replica": idx})
        finally:
            self._busy.discard(handle)

    async def _handle_abort(self, conn: _Conn, handle: int) -> None:
        sess = self.sessions.get(handle)
        if sess is None or sess.conn is not conn:
            return
        gate = self._migrating.get(handle)
        if gate is not None:
            await gate.wait()
        idx = self.router.affinity.get(handle)
        acked = False
        if idx is not None:
            rep = self.replicas[idx]
            acked = await asyncio.wrap_future(
                rep.call(rep.engine.abort, handle))
        if sess.live:
            self.queue.done(sess.client)
        if acked or idx is None:
            # the engine emits the abort's output on its NEXT step,
            # which an idle engine never takes — acknowledge here so
            # the client's stream always terminates
            conn.send({"event": "finish", "handle": handle,
                       "reason": "abort", "retained": False})
        self._forget(sess)

    async def _handle_release(self, conn: _Conn, handle: int) -> None:
        sess = self.sessions.get(handle)
        if sess is None or sess.conn is not conn or not sess.parked:
            return
        gate = self._migrating.get(handle)
        if gate is not None:
            await gate.wait()
        idx = self.router.affinity.get(handle)
        if idx is not None:
            rep = self.replicas[idx]
            await asyncio.wrap_future(rep.call(
                rep.engine.release_session, handle))
        self._forget(sess)

    async def _on_disconnect(self, conn: _Conn) -> None:
        """A dead socket must not hold resources: abort live turns,
        release parked sessions, drop queued tickets."""
        self.queue.purge(
            lambda _c, t: isinstance(t, _Ticket) and t.conn is conn)
        for handle in list(conn.handles):
            sess = self.sessions.get(handle)
            if sess is None:
                continue
            gate = self._migrating.get(handle)
            if gate is not None:
                await gate.wait()
            idx = self.router.affinity.get(handle)
            if idx is None:                    # still queued (now purged)
                self.sessions.pop(handle, None)
                continue
            rep = self.replicas[idx]
            if sess.parked:
                await asyncio.wrap_future(rep.call(
                    rep.engine.release_session, handle))
            else:
                await asyncio.wrap_future(rep.call(rep.engine.abort, handle))
                if sess.live:
                    self.queue.done(sess.client)
            self._forget(sess)


async def serve(engines: List[ServingEngine], *, host: str = "127.0.0.1",
                port: int = 0, ready: Optional[asyncio.Event] = None,
                **kw) -> FrontendServer:
    """Convenience: start a server and return it (port 0 picks a free
    one — read ``server.port``)."""
    srv = FrontendServer(engines, host=host, port=port, **kw)
    await srv.start()
    if ready is not None:
        ready.set()
    return srv
