"""Production-shaped load generator + multi-replica fairness bench.

Three pieces:

* ``storm_workload`` — arrivals shaped like a real serving day instead
  of a flat Poisson stream: a diurnal sinusoid rate, superimposed burst
  storms (a surge of near-simultaneous sessions — the shared-prefix
  stampede a prefix cache loves and a fair scheduler hates), and a
  heavy-tailed "whale" client whose Pareto session lengths would eat
  the cluster without VTC admission.
* ``DirectCluster`` — a deterministic, single-threaded N-replica driver
  that reuses the EXACT router + fair-queue decision code the asyncio
  server runs (``repro.frontend.router`` / ``.admission``), stepping
  whichever engine's virtual clock is furthest behind.  No threads, no
  wall clock: the same seed gives the same ``BENCH_frontend.json``
  byte-for-byte.
* ``--smoke`` — boots the REAL network path for CI: a loopback
  ``FrontendServer`` over two sim replicas, a handful of socket
  clients (submit / stream / follow-up / abort), a clean ``drain``,
  then per-replica event-log validation and the affinity audit.

Bench acceptance (ISSUE 10): on the storm workload, 2 routed replicas
must show per-client Jain fairness >= the single overloaded replica,
with ZERO affinity violations in the merged event logs.
"""
from __future__ import annotations

import argparse
import asyncio
import heapq
import json
import math
import random
from typing import Dict, List, Optional, Tuple

from repro.core.faults import EngineOverloadError
from repro.core.policies import EngineConfig
from repro.core.request_api import SamplingParams, SLOSpec, jain_index
from repro.core.serving import ServingEngine
from repro.data.sharegpt import Conversation, Turn
from repro.frontend.admission import FairAdmissionQueue, slo_priority
from repro.frontend.router import Router, count_affinity_violations


# ---------------------------------------------------------------------------
# workload
# ---------------------------------------------------------------------------

# SLO tiers (sim-time ms).  "interactive" is tight enough that an
# overloaded replica misses it for queued requests; "batch" is loose
# enough that only pathological queueing misses it — the spread is what
# makes per-client attainment informative (an all-zero or all-one
# attainment vector makes Jain trivially 1.0).
SLO_TIERS = {
    "interactive": SLOSpec(ttft_ms=60.0, tbt_ms=55.0),
    "standard": SLOSpec(ttft_ms=300.0, tbt_ms=90.0),
    "batch": SLOSpec(ttft_ms=3000.0, tbt_ms=300.0),
}


def _client_tier(i: int) -> str:
    return ("interactive", "standard", "batch")[i % 3]


def storm_workload(*, n_clients: int = 6, duration_s: float = 60.0,
                   base_rate: float = 3.5, diurnal_amp: float = 0.6,
                   diurnal_period_s: float = 40.0, storms: int = 2,
                   storm_size: int = 20, storm_span_s: float = 1.0,
                   seed: int = 0
                   ) -> List[Tuple[float, str, Conversation, SLOSpec]]:
    """Build (arrival_s, client, conversation, slo) tuples.

    Clients 0..n-2 are "normal" (lognormal-ish lengths, SLO tier by
    index); the LAST client is the whale: rarer arrivals but Pareto
    heavy-tail response lengths and long multi-turn sessions."""
    rng = random.Random(seed)
    whale = f"client{n_clients - 1}"
    work: List[Tuple[float, str, Conversation, SLOSpec]] = []
    cid = 0

    def normal_conv(t: float) -> Conversation:
        nonlocal cid
        k = 1 + _geom(rng, 0.45)
        turns = [Turn(prompt_tokens=rng.randint(16, 96),
                      response_tokens=rng.randint(8, 48))
                 for _ in range(min(k, 4))]
        c = Conversation(conv_id=cid, arrival_s=t, turns=turns,
                         think_time_s=max(0.2, rng.gauss(1.5, 0.5)))
        cid += 1
        return c

    # diurnal Poisson stream (thinning against the peak rate)
    lam_max = base_rate * (1.0 + diurnal_amp)
    t = 0.0
    while True:
        t += rng.expovariate(lam_max)
        if t >= duration_s:
            break
        lam = base_rate * (1.0 + diurnal_amp
                           * math.sin(2.0 * math.pi * t / diurnal_period_s))
        if rng.random() * lam_max > lam:
            continue
        client = f"client{rng.randrange(n_clients - 1)}"
        work.append((t, client, normal_conv(t),
                     SLO_TIERS[_client_tier(int(client[6:]))]))

    # burst storms: storm_size sessions landing within storm_span_s,
    # all opening with the SAME long prompt length (the shared-prefix
    # stampede shape; real-mode ids would share a cacheable prefix)
    for s in range(storms):
        t0 = (s + 0.5) * duration_s / storms
        shared_prompt = 64 + 32 * s
        for _ in range(storm_size):
            ts = t0 + rng.random() * storm_span_s
            client = f"client{rng.randrange(n_clients - 1)}"
            turns = [Turn(prompt_tokens=shared_prompt,
                          response_tokens=rng.randint(8, 32))]
            work.append((ts, client,
                         Conversation(conv_id=cid, arrival_s=ts, turns=turns,
                                      think_time_s=1.0),
                         SLO_TIERS[_client_tier(int(client[6:]))]))
            cid += 1

    # the whale: few sessions, Pareto heavy-tail responses, many turns
    tw = rng.uniform(0.0, duration_s / 4)
    while tw < duration_s:
        turns = []
        for _ in range(rng.randint(3, 6)):
            rt = int(min(384, 24 * rng.paretovariate(1.3)))
            turns.append(Turn(prompt_tokens=rng.randint(32, 128),
                              response_tokens=max(8, rt)))
        work.append((tw, whale,
                     Conversation(conv_id=cid, arrival_s=tw, turns=turns,
                                  think_time_s=0.5),
                     SLO_TIERS["standard"]))
        cid += 1
        tw += rng.expovariate(0.15)
    work.sort(key=lambda w: (w[0], w[2].conv_id))
    return work


def _geom(rng: random.Random, p: float) -> int:
    u = rng.random()
    return int(math.floor(math.log(max(u, 1e-12)) / math.log(max(1 - p, 1e-12))))


# ---------------------------------------------------------------------------
# deterministic multi-replica driver
# ---------------------------------------------------------------------------

def sim_engine_config(*, gpu_blocks: int = 160, cpu_blocks: int = 640,
                      max_running: int = 8) -> EngineConfig:
    """One replica of the bench cluster: small enough that the storm
    workload genuinely overloads a single replica (the 1-vs-2 Jain
    comparison needs contention), bounded waiting queue so backlog
    lives in the FAIR queue, not the engine's FIFO."""
    return EngineConfig(
        mode="sim", num_gpu_blocks=gpu_blocks, num_cpu_blocks=cpu_blocks,
        max_running=max_running, max_waiting=2 * max_running,
        overload_policy="reject",
    ).with_policy("fastswitch")


class DirectCluster:
    """Single-threaded virtual-time driver over N sim engines, sharing
    the server's Router + FairAdmissionQueue decision code.  Always
    steps the busy engine whose clock is furthest behind; idle engines
    fast-forward (``step(until_us=...)``) to the event that wakes them,
    so each replica's timeline stays coherent without any global
    clock."""

    def __init__(self, n_replicas: int, *,
                 config: Optional[EngineConfig] = None,
                 migrate_threshold: int = 6, rebalance_every: int = 16):
        cfg = config or sim_engine_config()
        self.engines = [ServingEngine(cfg) for _ in range(n_replicas)]
        self.router = Router(n_replicas, migrate_threshold=migrate_threshold)
        self.queue = FairAdmissionQueue(capacity=0)
        self.rebalance_every = rebalance_every
        self.sessions: Dict[int, Dict[str, object]] = {}
        self.client_of: Dict[int, str] = {}
        self._events: List[Tuple[float, int, str, int]] = []   # heap
        self._seq = 0
        self._next_handle = 0
        self._pending: List[Tuple[float, str, Conversation, SLOSpec]] = []

    # -- event plumbing ----------------------------------------------------

    def _push_event(self, t_us: float, kind: str, ref: int) -> None:
        heapq.heappush(self._events, (t_us, self._seq, kind, ref))
        self._seq += 1

    def _snapshots(self) -> List[Dict[str, object]]:
        return [e.load_snapshot() for e in self.engines]

    def _advance_to(self, idx: int, t_us: float) -> None:
        """Fast-forward an IDLE engine's clock to ``t_us`` (an engine
        with work earns its time by stepping)."""
        e = self.engines[idx]
        while not e.has_work() and e.clock.now_us < t_us:
            e.step(until_us=t_us)

    # -- arrivals / turns --------------------------------------------------

    def _fire(self, t_us: float, kind: str, ref: int) -> None:
        if kind == "arrive":
            t, client, conv, slo = self._pending[ref]
            handle = self._next_handle
            self._next_handle += 1
            self.sessions[handle] = {
                "client": client, "conv": conv, "turn": 0, "slo": slo,
            }
            self.client_of[handle] = client
            self.queue.push(client, handle)
        elif kind == "continue":
            handle = ref
            sess = self.sessions[handle]
            idx = self.router.route_followup(handle)
            self._advance_to(idx, t_us)
            conv: Conversation = sess["conv"]          # type: ignore
            tix = int(sess["turn"]) + 1                # type: ignore
            sess["turn"] = tix
            turn = conv.turns[tix]
            slo: SLOSpec = sess["slo"]                 # type: ignore
            self.engines[idx].continue_session(
                handle, turn.prompt_tokens,
                SamplingParams(max_tokens=turn.response_tokens), slo=slo,
                retain_kv=(tix + 1 < len(conv.turns)),
                priority=slo_priority(slo))
            self.queue.begin(sess["client"])           # type: ignore
            self.queue.charge(sess["client"], turn.prompt_tokens)

    def _dispatch(self) -> None:
        """Drain the fair queue in VTC order until an engine pushes
        back; a refused dispatch requeues at the front, uncharged."""
        while True:
            popped = self.queue.pop()
            if popped is None:
                return
            client, handle = popped
            sess = self.sessions[handle]
            snaps = self._snapshots()
            idx = self.router.route_new(handle, snaps)
            conv: Conversation = sess["conv"]          # type: ignore
            turn = conv.turns[0]
            slo: SLOSpec = sess["slo"]                 # type: ignore
            # the arrival reaches the replica "now" on its own timeline;
            # an idle replica first catches up to the busiest clock so
            # its latency accounting shares the cluster's notion of now
            tref = max((e.clock.now_us for e in self.engines
                        if e.has_work()), default=0.0)
            self._advance_to(idx, tref)
            try:
                self.engines[idx].add_request(
                    turn.prompt_tokens,
                    SamplingParams(max_tokens=turn.response_tokens),
                    slo=slo, handle=handle,
                    retain_kv=(len(conv.turns) > 1),
                    priority=slo_priority(slo))
            except EngineOverloadError:
                self.router.release(handle)
                self.queue.requeue(client, handle)
                return
            self.queue.charge(client, turn.prompt_tokens)

    def _consume(self, idx: int, outs) -> None:
        for out in outs:
            sess = self.sessions.get(out.handle)
            if sess is None:
                continue
            client = sess["client"]                    # type: ignore
            if out.new_tokens > 0:
                self.queue.feedback(client, out.new_tokens)
            if out.finished:
                self.queue.done(client)
                conv: Conversation = sess["conv"]      # type: ignore
                tix = int(sess["turn"])                # type: ignore
                more = (out.finish_reason in ("length", "stop")
                        and tix + 1 < len(conv.turns))
                if more:
                    wake = self.engines[idx].clock.now_us \
                        + conv.think_time_s * 1e6
                    self._push_event(wake, "continue", out.handle)
                else:
                    self.router.release(out.handle)
                    del self.sessions[out.handle]

    def _rebalance(self) -> None:
        snaps = self._snapshots()
        for handle, src, dst in self.router.plan_migrations(snaps):
            try:
                payload = self.engines[src].export_session(handle)
            except KeyError:
                continue
            self.engines[dst].import_session(payload)
            self.router.note_migrated(handle, dst)

    # -- the run loop ------------------------------------------------------

    def run(self, workload: List[Tuple[float, str, Conversation, SLOSpec]]
            ) -> None:
        self._pending = list(workload)
        for i, (t, _c, _conv, _slo) in enumerate(self._pending):
            self._push_event(t * 1e6, "arrive", i)
        iters = 0
        while True:
            busy = [i for i, e in enumerate(self.engines) if e.has_work()]
            if busy:
                now = min(self.engines[i].clock.now_us for i in busy)
                while self._events and self._events[0][0] <= now:
                    t_us, _s, kind, ref = heapq.heappop(self._events)
                    self._fire(t_us, kind, ref)
                self._dispatch()
                busy = [i for i, e in enumerate(self.engines)
                        if e.has_work()]
                if busy:
                    idx = min(busy,
                              key=lambda i: self.engines[i].clock.now_us)
                    nxt = self._events[0][0] if self._events else None
                    outs = self.engines[idx].step(until_us=nxt)
                    self._consume(idx, outs)
                iters += 1
                if iters % self.rebalance_every == 0:
                    self._rebalance()
            elif self._events:
                t_us, _s, kind, ref = heapq.heappop(self._events)
                self._fire(t_us, kind, ref)
                self._dispatch()
            elif self.queue.depth() > 0:
                self._dispatch()
            else:
                break

    # -- results -----------------------------------------------------------

    def results(self) -> Dict[str, object]:
        per_client_scores: Dict[str, List[float]] = {}
        per_client_ttft: Dict[str, List[float]] = {}
        per_client_maxtbt: Dict[str, List[float]] = {}
        for e in self.engines:
            for st in e.metrics.request_stats:
                client = self.client_of.get(st.handle)
                if client is None:
                    continue
                parts = []
                if st.ttft_ok is not None:
                    parts.append(1.0 if st.ttft_ok else 0.0)
                if st.tbt_ok_frac is not None:
                    parts.append(float(st.tbt_ok_frac))
                if parts:
                    per_client_scores.setdefault(client, []).append(
                        sum(parts) / len(parts))
                if st.ttft_us is not None:
                    per_client_ttft.setdefault(client, []).append(st.ttft_us)
                per_client_maxtbt.setdefault(client, []).append(st.max_tbt_us)
        attain = {c: sum(v) / len(v)
                  for c, v in sorted(per_client_scores.items())}
        logs = [[ev.as_dict() for ev in e.events] for e in self.engines]
        return {
            "replicas": len(self.engines),
            "per_client_attainment": attain,
            "jain_attainment": jain_index(list(attain.values())),
            "per_client_p95_ttft_ms": {
                c: _p95(v) / 1e3 for c, v in sorted(per_client_ttft.items())},
            "per_client_p95_max_tbt_ms": {
                c: _p95(v) / 1e3
                for c, v in sorted(per_client_maxtbt.items())},
            "turns_finished": sum(len(e.metrics.request_stats)
                                  for e in self.engines),
            "migrations": self.router.n_migrations,
            "affinity_violations": count_affinity_violations(logs),
        }


def _p95(xs: List[float]) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    return ys[min(len(ys) - 1, int(math.ceil(0.95 * len(ys))) - 1)]


# ---------------------------------------------------------------------------
# bench: 1 replica vs 2 routed replicas on the same storm
# ---------------------------------------------------------------------------

def run_bench(seed: int = 0, duration_s: float = 60.0) -> Dict[str, object]:
    rows = []
    for n in (1, 2):
        work = storm_workload(seed=seed, duration_s=duration_s)
        cluster = DirectCluster(n)
        cluster.run(work)
        rows.append(cluster.results())
    return {
        "bench": "frontend_storm",
        "seed": seed,
        "duration_s": duration_s,
        "workload": {"sessions": len(storm_workload(seed=seed,
                                                    duration_s=duration_s))},
        "rows": rows,
        "jain_gain": (rows[1]["jain_attainment"] or 0.0)
        - (rows[0]["jain_attainment"] or 0.0),
    }


# ---------------------------------------------------------------------------
# --smoke: the real network path on loopback (CI gate)
# ---------------------------------------------------------------------------

async def _smoke_client(host: str, port: int, name: str, prompts: List[int],
                        *, follow_up: bool = True,
                        abort_one: bool = False) -> Dict[str, object]:
    """One socket client: submit every prompt, stream until each turn
    finishes, follow up once on the first retained session (so that
    handle finishes TWICE), release every retained session, abort one
    mid-flight when asked.  Returns the finish reasons seen."""
    reader, writer = await asyncio.open_connection(host, port)
    for i, p in enumerate(prompts):
        # the request that will be aborted gets a huge budget so the
        # abort reliably lands while it is still decoding
        req = {"op": "submit", "id": f"{name}/{i}", "client": name,
               "prompt": p,
               "max_tokens": 512 if (abort_one and i == 0) else 8,
               "slo": {"ttft_ms": 5000.0, "tbt_ms": 500.0}}
        writer.write(json.dumps(req).encode() + b"\n")
    await writer.drain()
    reasons: List[str] = []
    handles: List[int] = []
    continued: Optional[int] = None
    aborted: Optional[int] = None
    expected = len(prompts)
    n_finish = 0
    while n_finish < expected:
        line = await reader.readline()
        if not line:
            break
        ev = json.loads(line)
        if ev.get("event") == "accepted":
            h = ev["handle"]
            if h not in handles:
                handles.append(h)
                if abort_one and aborted is None \
                        and ev.get("id") == f"{name}/0":
                    aborted = h
                    writer.write(json.dumps(
                        {"op": "abort", "handle": h}).encode() + b"\n")
                    await writer.drain()
        elif ev.get("event") == "finish":
            h = ev["handle"]
            n_finish += 1
            reasons.append(ev["reason"])
            if ev.get("retained"):
                if follow_up and continued is None:
                    # one follow-up turn through the affinity-pinned
                    # replica; the handle finishes a second time
                    continued = h
                    expected += 1
                    writer.write(json.dumps(
                        {"op": "continue", "handle": h, "prompt": 12,
                         "max_tokens": 6}).encode() + b"\n")
                else:
                    writer.write(json.dumps(
                        {"op": "release", "handle": h}).encode() + b"\n")
                await writer.drain()
        elif ev.get("event") == "error":
            raise AssertionError(f"{name}: server error {ev}")
    writer.close()
    await writer.wait_closed()
    return {"name": name, "reasons": reasons, "aborted": aborted,
            "continued": continued}


async def _smoke_async(events_prefix: str) -> Dict[str, object]:
    from repro.frontend.server import FrontendServer

    n_replicas = 2
    files = [open(f"{events_prefix}_r{i}.jsonl", "w")
             for i in range(n_replicas)]

    def mk_sink(i: int):
        def sink(ev):
            files[i].write(json.dumps(ev.as_dict()) + "\n")
        return sink

    cfg = EngineConfig(mode="sim", num_gpu_blocks=256, num_cpu_blocks=1024,
                       max_running=8).with_policy("fastswitch")
    engines = [ServingEngine(cfg, event_sink=mk_sink(i))
               for i in range(n_replicas)]
    srv = FrontendServer(engines, admission_capacity=64)
    host, port = await srv.start()
    try:
        results = await asyncio.gather(
            _smoke_client(host, port, "alice", [24, 40, 16]),
            _smoke_client(host, port, "bob", [32, 20], abort_one=True),
            _smoke_client(host, port, "carol", [48], follow_up=True),
        )
        # clean drain: no new work admitted, in-flight finishes, server
        # acknowledges when every replica is empty
        reader, writer = await asyncio.open_connection(host, port)
        writer.write(b'{"op": "drain"}\n')
        await writer.drain()
        line = await reader.readline()
        assert json.loads(line).get("event") == "drained", line
        writer.write(b'{"op": "submit", "id": "late", "prompt": 8}\n')
        await writer.drain()
        refusal = json.loads(await reader.readline())
        assert refusal.get("code") == 503, refusal
        writer.close()
        await writer.wait_closed()
    finally:
        await srv.close()
        for f in files:
            f.close()
    return {"clients": results,
            "paths": [f"{events_prefix}_r{i}.jsonl"
                      for i in range(n_replicas)]}


def run_smoke(events_prefix: str) -> Dict[str, object]:
    out = asyncio.get_event_loop().run_until_complete(
        _smoke_async(events_prefix))
    from repro.frontend.router import load_event_log
    from repro.launch.serve import validate_event_log

    logs = []
    for path in out["paths"]:
        validate_event_log(path)
        logs.append(load_event_log(path))
    violations = count_affinity_violations(logs)
    assert violations == 0, f"{violations} affinity violations"
    reasons = [r for c in out["clients"] for r in c["reasons"]]
    assert "abort" in reasons and "length" in reasons, reasons
    assert any(c["continued"] is not None for c in out["clients"])
    return {
        "bench": "frontend_smoke", "replicas": len(out["paths"]),
        "turns_finished": len(reasons), "affinity_violations": violations,
        "events_validated": out["paths"],
    }


# ---------------------------------------------------------------------------


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="loopback server smoke (CI): 2 sim replicas, "
                         "socket clients, clean drain, event-log audit")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--duration", type=float, default=60.0,
                    help="storm workload length (virtual seconds)")
    ap.add_argument("--events-prefix", default="/tmp/fastswitch_online_frontend",
                    help="per-replica event-log path prefix (smoke mode)")
    ap.add_argument("--json-out", default=None,
                    help="write results to this path")
    args = ap.parse_args(argv)

    if args.smoke:
        res = run_smoke(args.events_prefix)
    else:
        res = run_bench(seed=args.seed, duration_s=args.duration)
    text = json.dumps(res, indent=2, sort_keys=True)
    print(text)
    if args.json_out:
        with open(args.json_out, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
