"""Network front-end for multi-replica FastSwitch serving (DESIGN.md §11).

The first layer where fairness is enforced ACROSS engines rather than
inside one:

* ``admission``  — virtual-token-counter fair queue (arxiv 2401.00588)
  and the SLO-tightness -> scheduler-priority map (Equinox,
  arxiv 2508.16646): deadlines drive preemption.
* ``router``     — session-affinity routing over N replicas with
  least-predicted-TTFT dispatch and a parked-session migration planner;
  plus the event-log affinity auditor.
* ``server``     — asyncio streaming server (stdlib only) owning one
  ``ServingEngine`` per replica, each on a dedicated step-loop thread.
* ``loadgen``    — production-shaped load (diurnal rates, burst storms,
  heavy-tail sessions) and the deterministic ``DirectCluster`` driver
  behind ``BENCH_frontend.json``.
"""
from repro.frontend.admission import (FairAdmissionQueue, QueueFullError,
                                      slo_priority)
from repro.frontend.router import Router, count_affinity_violations

__all__ = [
    "FairAdmissionQueue", "QueueFullError", "slo_priority",
    "Router", "count_affinity_violations",
]
