"""Session-affinity router over N engine replicas (DESIGN.md §11).

Pure decision logic — no threads, no sockets — so the asyncio server
and the deterministic ``DirectCluster`` driver share EXACTLY the same
routing behaviour (the loopback driver-equivalence test leans on this).

* **New sessions** go to the replica with the least predicted TTFT
  (each replica's ``ServingEngine.load_snapshot`` carries its admission
  queue model's prediction), with queue depth and index as
  deterministic tie-breaks.
* **Affinity**: a session's KV reuse copy lives on ONE replica, so
  every follow-up turn is pinned there — routing it anywhere else
  would silently re-prefill the whole context (and double the session's
  memory).  The affinity map is the single source of truth; the
  event-log auditor (``count_affinity_violations``) checks that no
  replica ever served a session it did not own.
* **Migration**: when load skews, PARKED sessions (turn finished,
  awaiting a follow-up) move hot -> cold via
  ``ServingEngine.export_session`` / ``import_session`` — the CPU reuse
  copy's bytes travel with the session, so the follow-up still pays
  only the prefix swap-in on its new home.  Live requests never move:
  their KV is on GPU and mid-flight; the router rebalances between
  turns.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


class Router:
    def __init__(self, n_replicas: int, migrate_threshold: int = 4):
        assert n_replicas >= 1
        self.n_replicas = n_replicas
        # handle -> replica index owning the session's reuse copy
        self.affinity: Dict[int, int] = {}
        # load gap (queued+running requests) that triggers a rebalance
        self.migrate_threshold = migrate_threshold
        self.n_migrations = 0

    # -- dispatch ----------------------------------------------------------

    @staticmethod
    def _load(snap: Dict[str, object]) -> int:
        return int(snap["waiting"]) + int(snap["running"]) \
            + int(snap["swapped"]) + int(snap["swapping_in"])

    def route_new(self, handle: int,
                  snapshots: Sequence[Dict[str, object]]) -> int:
        """Least-predicted-TTFT dispatch for a fresh session; pins the
        handle's affinity.  Draining replicas are skipped (drain is the
        per-replica shutdown rung of the backpressure ladder)."""
        cands = [i for i, s in enumerate(snapshots) if not s["draining"]]
        if not cands:
            raise RuntimeError("all replicas draining")
        idx = min(cands, key=lambda i: (
            float(snapshots[i]["predicted_ttft_us"]),
            self._load(snapshots[i]), i))
        self.affinity[handle] = idx
        return idx

    def route_followup(self, handle: int) -> int:
        """Follow-up turns go where the session's KV lives — always."""
        return self.affinity[handle]

    def release(self, handle: int) -> None:
        self.affinity.pop(handle, None)

    def note_migrated(self, handle: int, dst: int) -> None:
        self.affinity[handle] = dst
        self.n_migrations += 1

    # -- rebalancing -------------------------------------------------------

    def plan_migrations(self, snapshots: Sequence[Dict[str, object]],
                        busy: Optional[Iterable[int]] = None
                        ) -> List[Tuple[int, int, int]]:
        """Plan parked-session moves (handle, src, dst) to close a load
        gap >= ``migrate_threshold`` between the hottest and coldest
        replica.  Only sessions parked on the hot replica move (its
        snapshot lists them), and only enough to halve the gap —
        rebalancing is damping, not oscillation.  ``busy`` handles
        (a follow-up mid-dispatch) are never planned."""
        if self.n_replicas < 2:
            return []
        loads = [self._load(s) for s in snapshots]
        hot = max(range(len(loads)), key=lambda i: (loads[i], i))
        cold = min(range(len(loads)), key=lambda i: (loads[i], -i))
        gap = loads[hot] - loads[cold]
        if hot == cold or gap < self.migrate_threshold \
                or snapshots[cold]["draining"]:
            return []
        skip = set(busy or ())
        movable = [h for h in snapshots[hot]["parked"]
                   if self.affinity.get(h) == hot and h not in skip]
        plans: List[Tuple[int, int, int]] = []
        for h in sorted(movable)[:max(1, gap // 2)]:
            plans.append((h, hot, cold))
        return plans


# ---------------------------------------------------------------------------
# event-log affinity audit
# ---------------------------------------------------------------------------

def count_affinity_violations(
        logs: Sequence[Sequence[Dict[str, object]]]) -> int:
    """Reconstruct session ownership from per-replica event logs and
    count violations — the acceptance gate's "zero cross-replica
    misroutes" check, computed from the logs alone (no trust in the
    router's own bookkeeping).

    Ownership discipline per replica log (each log is time-ordered on
    its own clock; replica clocks are not comparable, so the audit is
    per-log interval discipline plus global open/close pairing):

    * ``arrive`` / ``migrate_in`` open ownership of a handle.
    * ``migrate_out``, ``release``, a terminal ``abort``/``drop``/
      ``error``/``shed`` and a non-retained ``finish`` close it.
    * ANY other request event on a replica that does not currently own
      the handle is a violation (a follow-up or abort routed to the
      wrong replica shows up exactly like this).
    * Globally, a handle may be opened at most once more than it was
      handed off (``migrate_out``): two replicas claiming the same
      session is a violation even if each log is locally coherent.
    """
    violations = 0
    opens: Dict[int, int] = {}
    outs: Dict[int, int] = {}
    for events in logs:
        owned: set = set()
        for ev in events:
            h = int(ev["handle"])
            if h < 0:
                continue                      # engine-level (drain)
            kind = ev["kind"]
            if kind in ("arrive", "migrate_in"):
                if h in owned:
                    violations += 1           # double-open on one replica
                owned.add(h)
                opens[h] = opens.get(h, 0) + 1
            elif kind == "migrate_out":
                if h not in owned:
                    violations += 1
                owned.discard(h)
                outs[h] = outs.get(h, 0) + 1
            elif kind in ("release", "abort", "drop", "error", "shed"):
                if h not in owned:
                    violations += 1
                owned.discard(h)
            elif kind == "finish":
                if h not in owned:
                    violations += 1
                if not ev.get("retained", False):
                    owned.discard(h)
            else:
                if h not in owned:
                    violations += 1
    for h, n in opens.items():
        violations += max(0, n - 1 - outs.get(h, 0))
    return violations


def load_event_log(path: str) -> List[Dict[str, object]]:
    """Read one replica's JSONL event log (as written by the server's
    per-replica sink / ``launch.serve``'s ``--events``)."""
    out: List[Dict[str, object]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
