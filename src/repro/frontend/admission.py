"""Fair admission — the virtual-token-counter queue and the SLO map.

Two ideas from the fairness line of work (PAPERS.md) meet here:

* **VTC fair queueing** (Fairness in Serving LLMs, arxiv 2401.00588):
  each client carries a virtual *service counter* — tokens served,
  weighted by its share.  Dispatch always picks the backlogged client
  with the LOWEST normalized counter, so a client streaming one long
  session cannot starve ten clients sending short ones.  A client that
  (re)activates after idling has its counter LIFTED to the minimum over
  the active set: idling banks no credit (the "no free lunch for
  sleeping" rule the paper's U-bound proof needs).  With per-dispatch
  charges bounded by ``U`` tokens, any two continuously backlogged
  clients' normalized counters stay within ``2 * U`` of each other —
  the property ``tests/test_frontend.py`` checks.

* **SLO tightness -> scheduler priority** (Equinox, arxiv 2508.16646):
  deadlines should DRIVE preemption, not just be measured after the
  fact.  ``slo_priority`` maps a request's effective deadline onto the
  engine's priority scale (higher = more important, see
  ``PriorityScheduler``), and the front-end passes it through the
  ``add_request(priority=...)`` override — so a tight-TTFT request
  preempts a loose batch job instead of queueing behind it.

The queue is thread-safe (the asyncio loop and N replica threads all
touch it) and holds opaque items: the server queues tickets, the
DirectCluster driver queues conversations.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple


class QueueFullError(Exception):
    """Admission queue at capacity — the 429 rung of the backpressure
    ladder (DESIGN.md §11): refuse at the door, before any per-request
    state exists."""

    def __init__(self, msg: str, queue_depth: int = 0, capacity: int = 0):
        super().__init__(msg)
        self.queue_depth = queue_depth
        self.capacity = capacity


def slo_priority(slo) -> float:
    """Map SLO tightness onto scheduler priority (Equinox): monotone
    decreasing in the effective deadline, so tighter deadlines preempt
    looser ones.  The effective deadline is the binding constraint —
    the TTFT deadline, or the TBT deadline scaled by a nominal response
    length (a 40 ms TBT budget binds like a ~1 s completion deadline).
    Requests without any SLO sit at a low floor: they yield to every
    deadline-carrying request but still order among themselves via
    arrival.  Range (0, 1] — deliberately inside the priority traces'
    scale so overrides and trace priorities compose."""
    if slo is None or (slo.ttft_ms is None and slo.tbt_ms is None):
        return 0.25
    parts = []
    if slo.ttft_ms is not None:
        parts.append(float(slo.ttft_ms))
    if slo.tbt_ms is not None:
        parts.append(float(slo.tbt_ms) * 25.0)
    d = min(parts)
    return 1.0 / (1.0 + d / 1000.0)


class FairAdmissionQueue:
    """Weighted VTC fair queue over per-client FIFO lanes.

    Charging protocol (the server/cluster drivers follow it):
      * ``pop`` picks the next (client, item) to DISPATCH — it does not
        charge.
      * ``charge(client, prompt_tokens)`` on SUCCESSFUL engine submit
        (a dispatch refused by an overloaded engine is ``requeue``d
        uncharged — otherwise a refusal would bill the client twice).
      * ``feedback(client, n)`` as decode tokens stream out, so a long
        generation keeps paying while it runs.
    """

    def __init__(self, capacity: int = 0,
                 weights: Optional[Dict[str, float]] = None):
        self._lock = threading.Lock()
        self.capacity = capacity            # 0 = unbounded
        self.weights: Dict[str, float] = dict(weights or {})
        self.counters: Dict[str, float] = {}
        self._lanes: Dict[str, Deque[object]] = {}
        self._inflight: Dict[str, int] = {}
        self._depth = 0

    # -- introspection -----------------------------------------------------

    def weight(self, client: str) -> float:
        return self.weights.get(client, 1.0)

    def depth(self) -> int:
        with self._lock:
            return self._depth

    def backlogged(self) -> List[str]:
        with self._lock:
            return [c for c, q in self._lanes.items() if q]

    def norm_counter(self, client: str) -> float:
        with self._lock:
            return self.counters.get(client, 0.0) / self.weight(client)

    # -- the queue ---------------------------------------------------------

    def _active_min(self) -> float:
        """Minimum normalized counter over ACTIVE clients (backlogged or
        with dispatched work still in flight) — the lift target for a
        (re)activating client."""
        vals = [self.counters[c] / self.weight(c)
                for c in self.counters
                if self._lanes.get(c) or self._inflight.get(c, 0)]
        return min(vals) if vals else 0.0

    def push(self, client: str, item: object) -> None:
        with self._lock:
            if self.capacity and self._depth >= self.capacity:
                raise QueueFullError(
                    f"admission queue full ({self._depth} >= "
                    f"capacity={self.capacity})",
                    queue_depth=self._depth, capacity=self.capacity)
            lane = self._lanes.setdefault(client, deque())
            if not lane and not self._inflight.get(client, 0):
                # (re)activation: lift to the active minimum so idle
                # time banks no credit (VTC's no-starvation invariant)
                lift = self._active_min() * self.weight(client)
                self.counters[client] = max(
                    self.counters.get(client, 0.0), lift)
            else:
                self.counters.setdefault(client, 0.0)
            lane.append(item)
            self._depth += 1

    def pop(self) -> Optional[Tuple[str, object]]:
        """Next (client, item) to dispatch: lowest normalized counter
        among backlogged clients, FIFO within the client's lane.  Marks
        the client in flight until ``done``/``requeue``."""
        with self._lock:
            cands = [c for c, q in self._lanes.items() if q]
            if not cands:
                return None
            client = min(cands, key=lambda c: (
                self.counters.get(c, 0.0) / self.weight(c), c))
            item = self._lanes[client].popleft()
            self._depth -= 1
            self._inflight[client] = self._inflight.get(client, 0) + 1
            return client, item

    def begin(self, client: str) -> None:
        """Mark one dispatched item in flight WITHOUT it having queued
        (follow-up turns skip the lanes — their KV is resident — but
        must still count as active so the client's counter is not
        lifted away and ``done`` balances)."""
        with self._lock:
            self.counters.setdefault(client, 0.0)
            self._inflight[client] = self._inflight.get(client, 0) + 1

    def requeue(self, client: str, item: object) -> None:
        """Put a refused dispatch BACK at the front of its lane,
        uncharged — the engine said 'not now' (overload), not 'never';
        the client keeps its queue position."""
        with self._lock:
            self._lanes.setdefault(client, deque()).appendleft(item)
            self._depth += 1
            n = self._inflight.get(client, 0) - 1
            if n > 0:
                self._inflight[client] = n
            elif client in self._inflight:
                del self._inflight[client]

    def charge(self, client: str, tokens: int) -> None:
        """Bill ``tokens`` of service against the client's counter
        (weighted).  Prompt tokens at successful dispatch; decode
        tokens through ``feedback`` as they stream."""
        with self._lock:
            self.counters[client] = self.counters.get(client, 0.0) \
                + float(max(tokens, 0))

    # decode-time billing is the same operation; the distinct name keeps
    # call sites honest about WHICH tokens they are charging
    feedback = charge

    def done(self, client: str) -> None:
        """A dispatched item finished (any terminal reason): the client
        leaves the in-flight set once its last item ends."""
        with self._lock:
            n = self._inflight.get(client, 0) - 1
            if n > 0:
                self._inflight[client] = n
            elif client in self._inflight:
                del self._inflight[client]

    def purge(self, pred: Callable[[str, object], bool]) -> int:
        """Drop queued items matching ``pred`` (a disconnected client's
        tickets).  Returns the number removed."""
        removed = 0
        with self._lock:
            for client, lane in self._lanes.items():
                kept = deque(i for i in lane if not pred(client, i))
                removed += len(lane) - len(kept)
                self._lanes[client] = kept
            self._depth -= removed
        return removed
