"""Dry-run case construction: ShapeDtypeStruct inputs + shardings for every
(architecture x input shape), plus the jit-able step function for each kind.

``input_specs(cfg, shape)`` gives weak-type-correct, shardable stand-ins —
no device allocation ever happens in the dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import sharding as shard_rules
from repro.models import steps
from repro.models import transformer as T
from repro.train.optimizer import adamw_init

SDS = jax.ShapeDtypeStruct


def supports_case(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """long_500k only runs on sub-quadratic-decode archs (DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.supports_long_context_decode:
        return False, ("skip: pure full-attention arch without a "
                       "windowed/recurrent variant (DESIGN.md long_500k rule)")
    return True, ""


# ---------------------------------------------------------------------------
# input ShapeDtypeStructs
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, SDS]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.encoder_decoder:
        # decoder seq bounded by the model's max positions; encoder frames
        # carry the (stubbed) audio frontend embeddings
        S = min(S, cfg.max_seq_len)
    batch = {"tokens": SDS((B, S), jnp.int32),
             "labels": SDS((B, S), jnp.int32)}
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        batch["extra_embeds"] = SDS((B, cfg.frontend.n_tokens,
                                     cfg.frontend.d_embed), jnp.float32)
    if cfg.encoder_decoder:
        batch["encoder_frames"] = SDS((B, cfg.n_encoder_tokens, cfg.d_model),
                                      jnp.float32)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: InputShape, kv_dtype=None):
    B, S = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(
        lambda: T.init_caches(cfg, B, S, kv_dtype=kv_dtype))
    token = SDS((B,), jnp.int32)
    pos = SDS((), jnp.int32)
    return caches, token, pos


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def _batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axsize(mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def _fit(dim: int, mesh: Mesh, ax):
    return ax if dim % _axsize(mesh, ax) == 0 else None


def batch_spec(mesh: Mesh, batch: int, all_axes: bool = False) -> Any:
    """all_axes: spread the batch over the WHOLE mesh (ZeRO-3-style fully
    data-parallel activations — params stay 2-D sharded and GSPMD
    all-gathers them per layer inside the scan).  Used for train_step where
    attention logits dominate per-device temp memory."""
    if all_axes:
        full = tuple(mesh.axis_names)
        if batch % _axsize(mesh, full) == 0:
            return full
    ba = _batch_axes(mesh)
    if batch % _axsize(mesh, ba) == 0:
        return ba
    if batch % mesh.shape["data"] == 0:
        return "data"
    return None


def train_batch_shardings(cfg, mesh: Mesh, batch_specs_tree):
    def one(leaf):
        bax = batch_spec(mesh, leaf.shape[0], all_axes=True)
        return NamedSharding(mesh, P(bax, *([None] * (len(leaf.shape) - 1))))
    return jax.tree.map(one, batch_specs_tree)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, caches_shape,
                    global_batch: int, long_context: bool):
    """Per-leaf decode-cache shardings (see DESIGN.md §5):
    batch over (pod, data); for (L,B,S,H,D)-like leaves shard seq over
    `model` (uniform rule that works for every kv_heads count); when
    batch == 1 (long context) shard seq over (data, model)."""
    bax = batch_spec(mesh, global_batch)

    def leaf_spec(leaf) -> P:
        shp = leaf.shape
        nd = len(shp)
        spec = [None] * nd
        # locate the batch dim: first dim equal to global_batch after any
        # leading stack axes
        b_idx = None
        for i, d in enumerate(shp):
            if d == global_batch and i <= 2:
                b_idx = i
                break
        if b_idx is None:
            return P()
        if bax is not None and global_batch > 1:
            spec[b_idx] = bax
        # sequence dim = the large dim following batch (>= 256)
        s_idx = None
        for i in range(b_idx + 1, nd - 1):
            if shp[i] >= 256:
                s_idx = i
                break
        if s_idx is not None:
            if long_context:
                ax = _fit(shp[s_idx], mesh, ("data", "model"))
                spec[s_idx] = ax if ax else _fit(shp[s_idx], mesh, "model")
            else:
                spec[s_idx] = _fit(shp[s_idx], mesh, "model")
        else:
            # stateful caches (SSM/RWKV): shard heads over model
            for i in range(b_idx + 1, nd):
                if shp[i] >= 8 and shp[i] % mesh.shape["model"] == 0:
                    spec[i] = "model"
                    break
        return P(*spec)

    return jax.tree.map(lambda l: NamedSharding(mesh, leaf_spec(l)),
                        caches_shape)


# ---------------------------------------------------------------------------
# case assembly
# ---------------------------------------------------------------------------

def activation_ctx_for(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                       variants=()):
    """Activation-sharding context for trace time (DESIGN.md §5):
      * train: batch over ALL axes (ZeRO-3: params all-gathered per layer);
      * prefill/decode: batch over (pod, data), K/V sequence over model."""
    from repro.models.sharding import ActivationCtx
    cap = "moe-cap-shard" in variants
    if shape.kind == "train":
        bax = batch_spec(mesh, shape.global_batch, all_axes=True)
        return ActivationCtx(mesh=mesh, batch_axes=bax, kv_seq_axis=None,
                             moe_cap_shard=cap)
    bax = batch_spec(mesh, shape.global_batch)
    return ActivationCtx(mesh=mesh, batch_axes=bax, kv_seq_axis="model",
                         moe_cap_shard=cap)


def _with_act_ctx(fn, ctx):
    """Wrap a step fn so the activation context is set during tracing."""
    import functools as _ft

    from repro.models.sharding import (reset_activation_ctx,
                                       set_activation_ctx)

    @_ft.wraps(fn)
    def wrapped(*args, **kw):
        tok = set_activation_ctx(ctx)
        try:
            return fn(*args, **kw)
        finally:
            reset_activation_ctx(tok)
    return wrapped


def build_case(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
               key=None, variant: str = "baseline") -> Dict[str, Any]:
    """Returns dict(fn, args (ShapeDtypeStructs), in_shardings,
    out_shardings, donate) ready for jit().lower(...).

    §Perf hillclimb variants ('+'-combinable, e.g. "tp-params+kv-int8"):
      * "tp-params": pure tensor-parallel params (no data-axis ZeRO shard)
        — removes the per-step weight all-gather for decode;
      * "kv-int8": int8-quantized attention KV cache — halves the
        memory-bound decode's dominant HBM term;
      * "moe-cap-shard": shard MoE dispatch capacity over `data` — removes
        the data-axis replication of expert matmuls.
    """
    variants = set(variant.split("+")) if variant else {"baseline"}
    known = {"baseline", "tp-params", "kv-int8", "moe-cap-shard"}
    assert variants <= known, variants
    params_shape = jax.eval_shape(
        lambda k: T.init_params(cfg, k), SDS((2,), jnp.uint32))
    pshard = shard_rules.param_shardings(
        cfg, mesh, params_shape, replicate_data="tp-params" in variants)
    repl = NamedSharding(mesh, P())
    act_ctx = activation_ctx_for(cfg, shape, mesh, variants=variants)

    if shape.kind == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        # optimizer moments share the param shardings; step is replicated
        from repro.train.optimizer import AdamWState
        opt_shard = AdamWState(step=repl, mu=pshard, nu=pshard)
        batch = train_batch_specs(cfg, shape)
        bshard = train_batch_shardings(cfg, mesh, batch)
        fn = _with_act_ctx(functools.partial(steps.train_step, cfg=cfg),
                           act_ctx)
        return dict(fn=fn, args=(params_shape, opt_shape, batch),
                    in_shardings=(pshard, opt_shard, bshard),
                    out_shardings=(pshard, opt_shard, repl),
                    donate_argnums=(0, 1))

    if shape.kind == "prefill":
        B = shape.global_batch
        S = min(shape.seq_len, cfg.max_seq_len) if cfg.encoder_decoder \
            else shape.seq_len
        tokens = SDS((B, S), jnp.int32)
        bax = batch_spec(mesh, B)
        tshard = NamedSharding(mesh, P(bax, None))
        # pack modality inputs into one positional "extras" dict
        # (pjit rejects kwargs when in_shardings is given)
        extras = {}
        if cfg.frontend is not None and cfg.frontend.kind == "vision":
            extras["extra_embeds"] = SDS(
                (B, cfg.frontend.n_tokens, cfg.frontend.d_embed), jnp.float32)
        if cfg.encoder_decoder:
            extras["encoder_frames"] = SDS(
                (B, cfg.n_encoder_tokens, cfg.d_model), jnp.float32)

        def fn(params, tokens, extras):
            return steps.prefill(params, cfg, tokens, **extras)

        extras_sh = {k: NamedSharding(mesh, P(bax, None, None))
                     for k in extras}
        fn = _with_act_ctx(fn, act_ctx)
        # prefill output caches: let GSPMD choose (unconstrained)
        return dict(fn=fn, args=(params_shape, tokens, extras),
                    in_shardings=(pshard, tshard, extras_sh),
                    out_shardings=None,
                    donate_argnums=())

    # decode
    S = min(shape.seq_len, cfg.max_seq_len) if cfg.encoder_decoder \
        else shape.seq_len
    eff_shape = shape if S == shape.seq_len else InputShape(
        shape.name, S, shape.global_batch, shape.kind)
    caches, token, pos = decode_input_specs(
        cfg, eff_shape,
        kv_dtype=jnp.int8 if "kv-int8" in variants else None)
    cshard = cache_shardings(cfg, mesh, caches, shape.global_batch,
                             long_context=shape.global_batch == 1)
    bax = batch_spec(mesh, shape.global_batch)
    tshard = NamedSharding(mesh, P(bax))
    fn = _with_act_ctx(functools.partial(steps.serve_step, cfg=cfg), act_ctx)
    return dict(fn=fn, args=(params_shape, caches, token, pos),
                in_shardings=(pshard, cshard, tshard, repl),
                out_shardings=(tshard, None, cshard),
                donate_argnums=(1,))
