"""Production mesh definitions (TPU v5e pods).

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model").

Defined as functions so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS *before* any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs (same axis names, size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))
