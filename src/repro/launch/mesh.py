"""Production mesh definitions (TPU v5e pods).

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model").

Defined as functions so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS *before* any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs (same axis names, size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(shape):
    """The serving engine's ("data", "model") mesh for a (D, M)
    ``EngineConfig.mesh_shape`` — or None for (1, 1): the single-device
    engine runs the pre-mesh code path byte-for-byte (the sharded path
    degrades to it bit-exactly, DESIGN.md §9).  Raises if the host
    exposes fewer than D*M devices (on CPU CI, force them with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` BEFORE the
    first jax import)."""
    d, m = shape
    if d * m == 1:
        return None
    avail = len(jax.devices())
    if avail < d * m:
        raise ValueError(
            f"mesh_shape {shape} needs {d * m} devices, have {avail}")
    return jax.make_mesh((d, m), ("data", "model"))
