"""Training launcher — end-to-end driver on CPU with a reduced config
(or the full config via --dry-run, which delegates to dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import steps as S
    from repro.models import transformer as T
    from repro.train.checkpoint import save_checkpoint
    from repro.train.optimizer import adamw_init

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    opt = adamw_init(params)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n:,}")

    step_fn = jax.jit(lambda p, o, b: S.train_step(p, o, b, cfg=cfg,
                                                   lr=args.lr, remat=False))

    def make_batch(i):
        k = jax.random.fold_in(key, i)
        # synthetic LM data with learnable structure (shifted tokens)
        base = jax.random.randint(k, (args.batch, args.seq + 1), 0,
                                  cfg.vocab_size)
        b = {"tokens": base[:, :-1], "labels": base[:, 1:]}
        if cfg.frontend is not None and cfg.frontend.kind == "vision":
            b["extra_embeds"] = jnp.ones(
                (args.batch, cfg.frontend.n_tokens, cfg.frontend.d_embed),
                jnp.float32) * 0.02
        if cfg.encoder_decoder:
            b["encoder_frames"] = jnp.ones(
                (args.batch, cfg.n_encoder_tokens, cfg.d_model),
                jnp.float32) * 0.02
        return b

    t0 = time.time()
    for i in range(args.steps):
        params, opt, loss = step_fn(params, opt, make_batch(i))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)

    if args.checkpoint:
        save_checkpoint(args.checkpoint, params)
        print("saved", args.checkpoint)


if __name__ == "__main__":
    main()
