"""Serving launcher: run the FastSwitch engine end-to-end.

CPU-real example (reduced model, actual tokens through the paged pool):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --real \
      --conversations 8

Trace-driven (sim) benchmark run:
  PYTHONPATH=src python -m repro.launch.serve --policy vllm --policy fastswitch \
      --conversations 200 --update-freq 0.04 --pattern markov
"""
from __future__ import annotations

import argparse
import json


def main() -> None:
    from repro.core.policies import POLICIES
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--real", action="store_true",
                    help="reduced real model + paged pool (CPU)")
    ap.add_argument("--policy", action="append", default=None,
                    choices=sorted(POLICIES))
    ap.add_argument("--conversations", type=int, default=100)
    ap.add_argument("--rate", type=float, default=1.0)
    ap.add_argument("--pattern", default="markov",
                    choices=["markov", "random"])
    ap.add_argument("--update-freq", type=float, default=0.02)
    ap.add_argument("--gpu-blocks", type=int, default=None)
    ap.add_argument("--cpu-blocks", type=int, default=None)
    ap.add_argument("--max-running", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.core import EngineConfig, FastSwitchEngine
    from repro.data.priority import PriorityTrace
    from repro.data.sharegpt import sample_conversations, trace_stats

    policies = args.policy or ["fastswitch"]
    results = {}

    if args.real:
        import jax

        from repro.configs import get_smoke_config
        from repro.models import transformer as T
        cfg = get_smoke_config(args.arch)
        from repro.models.paged import supports_paged
        if not supports_paged(cfg):
            raise SystemExit(
                f"{args.arch}: real-mode serving needs a uniform GQA arch "
                "(paged pool path); use sim mode for this family")
        params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
        convs = sample_conversations(args.conversations, rate_req_s=args.rate,
                                     seed=args.seed, prompt_mu=3.0,
                                     resp_mu=3.0, max_tokens=96)
        for pol in policies:
            ec = EngineConfig(
                mode="real",
                num_gpu_blocks=args.gpu_blocks or 256,
                num_cpu_blocks=args.cpu_blocks or 1024,
                max_running=args.max_running or 8, max_batch=8,
            ).with_policy(pol)
            eng = FastSwitchEngine(
                ec, [c for c in convs],
                trace=PriorityTrace(args.pattern, args.update_freq,
                                    seed=args.seed),
                model_bundle={"cfg": cfg, "params": params})
            m = eng.run()
            results[pol] = {**m.summary(), **eng.swap.stats()}
            print(pol, json.dumps(m.summary(), indent=None))
    else:
        convs = sample_conversations(args.conversations, rate_req_s=args.rate,
                                     seed=args.seed)
        print("trace:", trace_stats(convs))
        for pol in policies:
            ec = EngineConfig(
                mode="sim",
                num_gpu_blocks=args.gpu_blocks or 2048,
                num_cpu_blocks=args.cpu_blocks or 8192,
                max_running=args.max_running or 32,
            ).with_policy(pol)
            eng = FastSwitchEngine(
                ec, [c for c in convs],
                trace=PriorityTrace(args.pattern, args.update_freq,
                                    seed=args.seed))
            m = eng.run()
            results[pol] = {**m.summary(), **eng.swap.stats()}
            s = m.summary()
            print(f"{pol:12s} p99_ttft={s['p99_ttft_ms']:.1f}ms "
                  f"p999_tbt={s['p999_tbt_ms']:.1f}ms "
                  f"throughput={s['throughput_tok_s']:.1f} tok/s")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
