"""Serving launcher: trace-replay benchmarks AND the online serving API.

Quickstart — online open-world serving (the ``ServingEngine``
``add_request/step/abort/continue_session`` API, DESIGN.md §6):

  # sim-mode online replay with streaming finish markers, random
  # cancellations and a per-request JSONL event log
  PYTHONPATH=src python -m repro.launch.serve --online \
      --conversations 20 --cancel-frac 0.2 --events /tmp/events.jsonl \
      --slo-ttft-ms 500 --slo-tbt-ms 80

  # real mode (reduced model, actual tokens through the paged pool),
  # printing token-id deltas as they stream out
  PYTHONPATH=src python -m repro.launch.serve --online --real --stream \
      --conversations 6

  # tier-1 smoke: tiny run + event-log well-formedness assertions
  PYTHONPATH=src python -m repro.launch.serve --online --smoke [--real]

Failure containment / chaos quickstart (DESIGN.md §7):

  # seeded chaos schedule (swap faults, stalls, poison requests,
  # allocation-pressure spikes) with the invariant sanitizer on every
  # step — the engine must degrade per-request, never crash step()
  PYTHONPATH=src python -m repro.launch.serve --online --chaos --smoke

  # admission control: bounded waiting queue, shed-lowest-priority
  PYTHONPATH=src python -m repro.launch.serve --online --max-waiting 8 \
      --overload-policy shed --conversations 50 --rate 20

  # drain mode: stop admitting at t=5s, finish in-flight work, exit
  PYTHONPATH=src python -m repro.launch.serve --online --drain 5

The online driver is an ordinary CLIENT of the engine: it submits
arrivals with ``add_request`` (multi-turn follow-ups via
``continue_session`` — the KV-reuse path), drains ``step()`` outputs,
and aborts a random fraction mid-flight to exercise cancellation in
every lifecycle state.  At the end it prints the latency summary AND
the per-request SLO-attainment / fairness rollup (``slo_summary``).

Network front-end — multi-replica fair router (DESIGN.md §11):

  # 2 sim replicas behind the VTC fair-admission queue + affinity
  # router, JSON-lines protocol on localhost:8471, one event log per
  # replica at /tmp/fe_r<i>.jsonl
  PYTHONPATH=src python -m repro.launch.serve --serve --router 2 \
      --events /tmp/fe

  # then talk to it over any TCP client, one JSON object per line:
  #   {"op": "submit", "client": "me", "prompt": 64, "max_tokens": 16}
  #   {"op": "continue", "handle": 0, "prompt": 32}   (KV-reuse turn)
  #   {"op": "drain"}                                  (graceful stop)

  # CI loopback smoke (boots the server, drives socket clients through
  # submit/stream/follow-up/abort, drains, audits the event logs):
  PYTHONPATH=src python -m repro.frontend.loadgen --smoke

Trace-driven (sim) benchmark replay — the classic closed-world runs:
  PYTHONPATH=src python -m repro.launch.serve --policy vllm \
      --policy fastswitch --conversations 200 --update-freq 0.04

CPU-real replay:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --real \
      --conversations 8
"""
from __future__ import annotations

import argparse
import json
import random


def _build_real_bundle(arch: str, seed: int):
    import jax

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.models.paged import supports_paged
    cfg = get_smoke_config(arch)
    if not supports_paged(cfg):
        raise SystemExit(
            f"{arch}: real-mode serving needs a uniform GQA arch "
            "(paged pool path); use sim mode for this family")
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    return {"cfg": cfg, "params": params}


def validate_event_log(path: str) -> int:
    """Assert the JSONL event log is well-formed: every line parses,
    kinds are known, timestamps are monotone, and every handle's
    lifecycle is coherent (an arrive first; at most one hard terminal
    among abort/drop/error/shed).
    System events (``drain``) carry a negative handle and sit outside
    any request lifecycle.  ``retry`` events must name a direction.
    Returns the number of events."""
    from repro.core.request_api import EVENT_KINDS
    n = 0
    last_t = -1.0
    seen_arrive = set()
    terminal = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            assert {"t_us", "handle", "kind"} <= set(ev), f"bad event {ev}"
            assert ev["kind"] in EVENT_KINDS, f"unknown kind {ev['kind']}"
            assert ev["t_us"] >= last_t, "event log not time-ordered"
            last_t = ev["t_us"]
            h = ev["handle"]
            if h < 0:
                # engine-level event (drain): no per-request lifecycle
                assert ev["kind"] == "drain", f"system event kind: {ev}"
                n += 1
                continue
            if ev["kind"] in ("arrive", "migrate_in"):
                # migrate_in opens a handle's lifecycle on THIS replica
                # (the session arrived elsewhere and moved here)
                seen_arrive.add(h)
            else:
                assert h in seen_arrive, f"event before arrive: {ev}"
            if ev["kind"] == "retry":
                assert ev.get("direction") in ("in", "out"), \
                    f"retry without direction: {ev}"
            if ev["kind"] == "error":
                assert ev.get("error"), f"error event without message: {ev}"
            if ev["kind"] in ("abort", "drop", "error", "shed"):
                terminal.setdefault(h, []).append(ev["kind"])
            n += 1
    for h, kinds in terminal.items():
        # a retained session may finish several turns; exactly one
        # hard terminal (abort/drop/error/shed) may end it
        assert len(kinds) <= 1, f"handle {h} terminated twice: {kinds}"
    assert n > 0, "empty event log"
    return n


def run_online(args) -> dict:
    """Open-world client loop over the ServingEngine API.

    Deliberately an INDEPENDENT client — it shares no driver scaffold
    with ``FastSwitchEngine``'s replay (tests pin the two equivalent);
    what a network front-end would do, it does here inline."""
    import dataclasses

    from repro.core import (EngineConfig, EngineDrainingError,
                            EngineOverloadError, FaultPlan, SamplingParams,
                            ServingEngine, SLOSpec)
    from repro.data.priority import PriorityTrace
    from repro.data.sharegpt import prompt_for_turn, sample_conversations

    policy = (args.policy or ["fastswitch"])[0]
    n_conv = 6 if args.smoke else args.conversations
    if args.chaos and args.smoke and not args.real:
        # the chaos smoke needs CONTENTION: a roomy pool never swaps, so
        # no swap-fault site is ever reached.  Starve it instead.
        n_conv = 16
        args.gpu_blocks = args.gpu_blocks or 64
        args.cpu_blocks = args.cpu_blocks or 256
        args.max_running = args.max_running or 4
        args.rate = max(args.rate, 20.0)
    model = None
    if args.real:
        model = _build_real_bundle(args.arch, args.seed)
        cfg = EngineConfig(
            mode="real",
            num_gpu_blocks=args.gpu_blocks or 64,
            num_cpu_blocks=args.cpu_blocks or 256,
            max_running=args.max_running or 4, max_batch=4,
        ).with_policy(policy)
        convs = sample_conversations(n_conv, rate_req_s=args.rate,
                                     seed=args.seed, prompt_mu=2.5,
                                     resp_mu=2.5, max_tokens=48)
    else:
        cfg = EngineConfig(
            mode="sim",
            num_gpu_blocks=args.gpu_blocks or (256 if args.smoke else 2048),
            num_cpu_blocks=args.cpu_blocks or (1024 if args.smoke else 8192),
            max_running=args.max_running or (8 if args.smoke else 32),
        ).with_policy(policy)
        convs = sample_conversations(n_conv, rate_req_s=args.rate,
                                     seed=args.seed,
                                     max_context=cfg.num_gpu_blocks * 8)

    # robustness wiring (DESIGN.md §7): seeded chaos schedule, invariant
    # sanitizer cadence, copy watchdog, bounded admission
    overrides = {}
    if args.prefix_cache:
        overrides["prefix_cache"] = True
        if args.smoke:
            # the prefix-cache smoke doubles as a refcount-conservation
            # gate: C1/C2 checked after every step
            overrides["check_invariants_every"] = 1
    if args.chaos:
        overrides["fault_plan"] = FaultPlan.chaos(seed=args.seed,
                                                  intensity=args.chaos)
        overrides["swap_watchdog_us"] = 100_000.0
        overrides["check_invariants_every"] = 1 if args.smoke else 50
    if args.max_waiting:
        overrides["max_waiting"] = args.max_waiting
        overrides["overload_policy"] = args.overload_policy
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    slo = None
    if args.slo_ttft_ms or args.slo_tbt_ms:
        slo = SLOSpec(ttft_ms=args.slo_ttft_ms or None,
                      tbt_ms=args.slo_tbt_ms or None)
    ev_file = open(args.events, "w") if args.events else None
    sink = (lambda ev: ev_file.write(json.dumps(ev.as_dict()) + "\n")) \
        if ev_file else None
    engine = ServingEngine(cfg, trace=PriorityTrace(args.pattern,
                                                    args.update_freq,
                                                    seed=args.seed),
                           model_bundle=model, event_sink=sink,
                           stream_tokens=args.stream and args.real)

    # shared "system prompt": with the prefix cache on, every
    # conversation's FIRST turn opens with the same token run so the
    # radix tree gets real cross-request hits (3 full blocks cacheable
    # out of 49 tokens at block_size 16)
    sys_prefix = []
    if args.prefix_cache:
        vocab = model["cfg"].vocab_size if model else 1 << 20
        sys_prefix = [(7 * i + 3) % vocab for i in range(49)]

    def prompt_for(conv, tix):
        toks = prompt_for_turn(
            conv, tix, model["cfg"].vocab_size if model else None)
        if tix == 0 and sys_prefix:
            toks = sys_prefix + list(toks)
        return toks

    rng = random.Random(args.seed + 1)
    pending = sorted(convs, key=lambda c: c.arrival_s)
    sleeping = []                    # (wake_s, conv, next_turn_idx)
    by_handle = {c.conv_id: c for c in convs}
    live, n_aborted, n_refused = set(), 0, 0
    iters = 0
    max_iters = 20_000 if args.real else 300_000
    while (pending or sleeping or engine.has_work()) and iters < max_iters:
        now_s = engine.clock.now_us / 1e6
        if args.drain and now_s >= args.drain and not engine.draining:
            # stop admissions; in-flight work runs to completion.  The
            # client drops its own backlog too — every further submit
            # would just raise EngineDrainingError.
            engine.drain()
            n_refused += len(pending) + len(sleeping)
            pending, sleeping = [], []
            print(f"draining at t={now_s:.2f}s "
                  f"({len(engine.sched.requests)} in flight)")
        while pending and pending[0].arrival_s <= now_s:
            conv = pending.pop(0)
            t = conv.turns[0]
            try:
                engine.add_request(
                    prompt_for(conv, 0),
                    SamplingParams(max_tokens=t.response_tokens),
                    slo=slo, handle=conv.conv_id,
                    retain_kv=len(conv.turns) > 1)
                live.add(conv.conv_id)
            except (EngineOverloadError, EngineDrainingError):
                n_refused += 1       # a real front-end would 429/503 here
        for entry in list(sleeping):
            if entry[0] <= now_s:
                sleeping.remove(entry)
                _, conv, tix = entry
                t = conv.turns[tix]
                try:
                    engine.continue_session(
                        conv.conv_id, prompt_for(conv, tix),
                        SamplingParams(max_tokens=t.response_tokens),
                        slo=slo, retain_kv=tix + 1 < len(conv.turns))
                    live.add(conv.conv_id)
                except (EngineOverloadError, EngineDrainingError):
                    n_refused += 1
        events = [w[0] * 1e6 for w in sleeping]
        if pending:
            events.append(pending[0].arrival_s * 1e6)
        outs = engine.step(until_us=min(events) if events else None)
        for out in outs:
            if args.stream and (out.token_ids or out.finished):
                ids = "".join(f" {t}" for t in (out.token_ids or []))
                mark = f" [{out.finish_reason}]" if out.finished else ""
                print(f"  req {out.handle}.{out.turn}:{ids}{mark}")
            if out.finished:
                live.discard(out.handle)
                conv = by_handle[out.handle]
                if (out.finish_reason in ("length", "stop")
                        and out.turn + 1 < len(conv.turns)):
                    sleeping.append((out.t_us / 1e6 + conv.think_time_s,
                                     conv, out.turn + 1))
        # cancellation: a random client hangs up mid-flight (any state)
        if args.cancel_frac and live and rng.random() < args.cancel_frac:
            victim = rng.choice(sorted(live))
            if engine.abort(victim):
                live.discard(victim)
                n_aborted += 1
                # the whole conversation is gone: drop queued follow-ups
                sleeping = [w for w in sleeping if w[1].conv_id != victim]
        iters += 1
    engine.shutdown()

    m = engine.metrics
    result = {**m.summary(), "slo": m.slo_summary(), **engine.swap.stats()}
    if args.chaos:
        result["faults_fired"] = dict(engine.faults.fired)
    print(f"online[{policy}] " + json.dumps(m.summary()))
    print("slo " + json.dumps(m.slo_summary()))
    if args.chaos:
        print("chaos " + json.dumps({
            "fired": dict(engine.faults.fired), "faulted": m.faulted,
            "swap_failure_resumes": m.swap_failure_resumes,
            "copy_retries": engine.swap.n_retries,
            "copy_failures": engine.swap.n_copy_failures,
            "watchdog_rescues": engine.swap.n_watchdog,
            "invariant_checks": m.invariant_checks}))
    if args.max_waiting or args.drain:
        print("admission " + json.dumps({
            "rejected": m.rejected, "shed": m.shed,
            "client_refused": n_refused}))
    if args.prefix_cache:
        result["prefix"] = engine.prefix.stats()
        print("prefix " + json.dumps(engine.prefix.stats()))
    if ev_file:
        ev_file.close()
        n_ev = validate_event_log(args.events)
        print(f"event log {args.events}: {n_ev} events, well-formed")
    if args.smoke:
        assert not engine.has_work(), "smoke run did not drain"
        assert m.total_tokens > 0, "smoke run served no tokens"
        assert len(m.request_stats) > 0, "no per-request SLO records"
        if args.cancel_frac:
            assert m.aborted == n_aborted, \
                f"abort accounting mismatch: {m.aborted} != {n_aborted}"
        if args.chaos:
            # the chaos smoke is a CONTAINMENT gate: with the sanitizer
            # on every step, faults must have fired and every live
            # request must still have ended in a terminal state
            assert sum(engine.faults.fired.values()) > 0, \
                "chaos smoke fired no faults"
            assert m.invariant_checks > 0, "invariant sanitizer never ran"
        if args.prefix_cache:
            # cross-request sharing must actually happen: every conv
            # after the first opens with the cached system prompt
            assert m.prefix_hits > 0, "prefix-cache smoke saw no hits"
            assert m.prefix_tokens_saved > 0, "prefix hits saved nothing"
            assert m.invariant_checks > 0, \
                "prefix smoke ran without the sanitizer"
        print(f"online smoke OK: {m.total_tokens} tokens, "
              f"{len(m.request_stats)} turns, {m.aborted} aborted, "
              f"{m.faulted} faulted")
    return result


def run_replay(args) -> dict:
    """Closed-world trace replay (FastSwitchEngine driving the serving
    core) — the benchmark path."""
    from repro.core import EngineConfig, FastSwitchEngine
    from repro.data.priority import PriorityTrace
    from repro.data.sharegpt import sample_conversations, trace_stats

    policies = args.policy or ["fastswitch"]
    results = {}
    if args.real:
        model = _build_real_bundle(args.arch, args.seed)
        convs = sample_conversations(args.conversations, rate_req_s=args.rate,
                                     seed=args.seed, prompt_mu=3.0,
                                     resp_mu=3.0, max_tokens=96)
        for pol in policies:
            ec = EngineConfig(
                mode="real",
                num_gpu_blocks=args.gpu_blocks or 256,
                num_cpu_blocks=args.cpu_blocks or 1024,
                max_running=args.max_running or 8, max_batch=8,
            ).with_policy(pol)
            eng = FastSwitchEngine(
                ec, [c for c in convs],
                trace=PriorityTrace(args.pattern, args.update_freq,
                                    seed=args.seed),
                model_bundle=model)
            m = eng.run()
            results[pol] = {**m.summary(), **eng.swap.stats()}
            print(pol, json.dumps(m.summary(), indent=None))
    else:
        convs = sample_conversations(args.conversations, rate_req_s=args.rate,
                                     seed=args.seed)
        print("trace:", trace_stats(convs))
        for pol in policies:
            ec = EngineConfig(
                mode="sim",
                num_gpu_blocks=args.gpu_blocks or 2048,
                num_cpu_blocks=args.cpu_blocks or 8192,
                max_running=args.max_running or 32,
            ).with_policy(pol)
            eng = FastSwitchEngine(
                ec, [c for c in convs],
                trace=PriorityTrace(args.pattern, args.update_freq,
                                    seed=args.seed))
            m = eng.run()
            results[pol] = {**m.summary(), **eng.swap.stats()}
            s = m.summary()
            print(f"{pol:12s} p99_ttft={s['p99_ttft_ms']:.1f}ms "
                  f"p999_tbt={s['p999_tbt_ms']:.1f}ms "
                  f"throughput={s['throughput_tok_s']:.1f} tok/s")
    return results


def run_serve(args) -> dict:
    """Network front-end mode: boot ``--router N`` engine replicas
    behind the fair-admission router and serve the JSON-lines protocol
    until interrupted (``repro.frontend.server``).  ``--events PREFIX``
    writes one JSONL event log per replica at ``PREFIX_r<i>.jsonl``."""
    import asyncio

    from repro.core import EngineConfig, ServingEngine
    from repro.frontend.server import FrontendServer

    n = max(1, args.router)
    policy = (args.policy or ["fastswitch"])[0]
    model = _build_real_bundle(args.arch, args.seed) if args.real else None
    cfg = EngineConfig(
        mode="real" if args.real else "sim",
        num_gpu_blocks=args.gpu_blocks or (64 if args.real else 256),
        num_cpu_blocks=args.cpu_blocks or (256 if args.real else 1024),
        max_running=args.max_running or (4 if args.real else 8),
        max_batch=4 if args.real else 32,
        max_waiting=args.max_waiting,
        overload_policy=args.overload_policy,
    ).with_policy(policy)

    files = []
    engines = []
    for i in range(n):
        sink = None
        if args.events:
            # line-buffered: a long-running server is usually stopped by
            # SIGTERM, which never unwinds to the close() below — each
            # event must be durable the moment it is written
            f = open(f"{args.events}_r{i}.jsonl", "w", buffering=1)
            files.append(f)
            sink = (lambda fh: lambda ev: fh.write(
                json.dumps(ev.as_dict()) + "\n"))(f)
        engines.append(ServingEngine(cfg, model_bundle=model,
                                     event_sink=sink,
                                     stream_tokens=bool(args.stream
                                                        and args.real)))

    async def _run():
        srv = FrontendServer(engines, host=args.host, port=args.port)
        host, port = await srv.start()
        print(f"frontend: {n} {cfg.mode} replica(s) on {host}:{port}",
              flush=True)
        try:
            while True:
                await asyncio.sleep(3600.0)
        finally:
            await srv.close()

    try:
        asyncio.get_event_loop().run_until_complete(_run())
    except KeyboardInterrupt:
        print("frontend: interrupted, shutting down")
    finally:
        for f in files:
            f.close()
    return {"replicas": n, "mode": cfg.mode}


def main() -> None:
    from repro.core.policies import POLICIES
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--real", action="store_true",
                    help="reduced real model + paged pool (CPU)")
    ap.add_argument("--policy", action="append", default=None,
                    choices=sorted(POLICIES))
    ap.add_argument("--conversations", type=int, default=100)
    ap.add_argument("--rate", type=float, default=1.0)
    ap.add_argument("--pattern", default="markov",
                    choices=["markov", "random"])
    ap.add_argument("--update-freq", type=float, default=0.02)
    ap.add_argument("--gpu-blocks", type=int, default=None)
    ap.add_argument("--cpu-blocks", type=int, default=None)
    ap.add_argument("--max-running", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    # online serving API (DESIGN.md §6)
    ap.add_argument("--online", action="store_true",
                    help="drive the open-world add_request/step API")
    ap.add_argument("--stream", action="store_true",
                    help="print per-request token deltas (real mode)")
    ap.add_argument("--events", default=None,
                    help="write the per-request JSONL event log here")
    ap.add_argument("--cancel-frac", type=float, default=0.0,
                    help="probability per step of aborting a live request")
    ap.add_argument("--slo-ttft-ms", type=float, default=0.0)
    ap.add_argument("--slo-tbt-ms", type=float, default=0.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny online run + event-log assertions (tier-1)")
    # robustness / failure containment (DESIGN.md §7)
    ap.add_argument("--chaos", nargs="?", const=1.0, type=float,
                    default=0.0, metavar="INTENSITY",
                    help="seeded fault-injection schedule "
                         "(FaultPlan.chaos; optional intensity, default 1)")
    ap.add_argument("--max-waiting", type=int, default=0,
                    help="bound the waiting queue (0 = unbounded)")
    ap.add_argument("--overload-policy", default="reject",
                    choices=["reject", "shed"],
                    help="full queue: reject the new request or shed "
                         "the least valuable waiting one")
    ap.add_argument("--drain", type=float, default=0.0, metavar="T_S",
                    help="enter drain mode at t=T_S: refuse new work, "
                         "finish in-flight requests, exit")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="cross-request prefix cache (DESIGN.md §10); "
                         "implies --real --online")
    ap.add_argument("--serve", action="store_true",
                    help="network front-end: fair router over N replicas "
                         "(JSON lines over TCP, DESIGN.md §11)")
    ap.add_argument("--router", type=int, default=1, metavar="N",
                    help="number of engine replicas behind --serve")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8471,
                    help="--serve listen port (0 picks a free one)")
    args = ap.parse_args()

    if args.prefix_cache:
        args.real = True               # the cache lives on the real pool
        args.online = True
    if args.smoke and not args.online:
        args.online = True
    if args.smoke:
        args.cancel_frac = args.cancel_frac or 0.05
        if not (args.slo_ttft_ms or args.slo_tbt_ms):
            args.slo_ttft_ms, args.slo_tbt_ms = 2000.0, 200.0

    if args.serve:
        results = run_serve(args)
    elif args.online:
        results = run_online(args)
    else:
        results = run_replay(args)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
