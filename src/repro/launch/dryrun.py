import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
for the production meshes and emit memory/cost/roofline artifacts.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun

Exit code != 0 if any requested case fails to compile.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import INPUT_SHAPES, SHAPES_BY_NAME, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_case, supports_case
from repro.roofline import analysis as roofline


def run_case(arch: str, shape_name: str, multi_pod: bool,
             want_roofline: bool = True, variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = supports_case(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    case = build_case(cfg, shape, mesh, variant=variant)
    t0 = time.time()
    with mesh:
        jitted = jax.jit(case["fn"],
                         in_shardings=case["in_shardings"],
                         out_shardings=case.get("out_shardings"),
                         donate_argnums=case.get("donate_argnums", ()))
        kwargs = case.get("kwargs", {})
        lowered = jitted.lower(*case["args"], **kwargs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant,
        "status": "ok",
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": int(
                getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes_per_device": int(
                getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes_per_device": int(
                getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        },
    }
    if want_roofline:
        mf = roofline.model_flops_estimate(cfg, shape)
        af, ab = roofline.analytic_floors(cfg, shape, mesh.size)
        terms = roofline.analyze(compiled, n_chips=mesh.size, model_flops=mf,
                                 analytic_flops_dev=af,
                                 analytic_bytes_dev=ab)
        result["roofline"] = terms.as_dict()
    return result


def run_serving_case(arch: str) -> dict:
    """Serving-path dry-run (ISSUE 5): lower + execute the online
    ``ServingEngine`` hot path (bucketed prefill, donated decode step,
    staged swap) for one smoke arch through the PUBLIC API — add a
    couple of requests, step to completion, abort one mid-flight — and
    report wall time plus the compiled-variant counts of the decode
    step.  Catches serving-stack compile regressions the mesh cases
    can't see."""
    from repro.configs import get_smoke_config
    from repro.core import (DecodeRunner, EngineConfig, SamplingParams,
                            ServingEngine)
    from repro.data.priority import PriorityTrace
    from repro.data.sharegpt import synth_prompt_ids
    from repro.models import transformer as T
    from repro.models.paged import supports_paged

    cfg = get_smoke_config(arch)
    if not supports_paged(cfg):
        return {"arch": arch, "case": "serving", "status": "skipped",
                "reason": "no paged-pool support (needs uniform GQA)"}
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ec = EngineConfig(mode="real", num_gpu_blocks=64, num_cpu_blocks=128,
                      max_running=4, max_batch=4).with_policy("fastswitch")
    t0 = time.time()
    eng = ServingEngine(ec, trace=PriorityTrace("random", 1e-9, seed=0),
                        model_bundle={"cfg": cfg, "params": params})
    handles = [eng.add_request(synth_prompt_ids(i, 0, 12, cfg.vocab_size),
                               SamplingParams(max_tokens=6))
               for i in range(3)]
    it = 0
    while eng.has_work() and it < 2000:
        eng.step()
        if it == 2:
            eng.abort(handles[-1])
        it += 1
    eng.shutdown()
    ok = not eng.has_work() and eng.metrics.total_tokens > 0
    return {"arch": arch, "case": "serving",
            "status": "ok" if ok else "FAIL",
            "t_total_s": round(time.time() - t0, 2),
            "tokens": eng.metrics.total_tokens,
            "aborted": eng.metrics.aborted,
            "decode_jit_variants": DecodeRunner.jit_cache_size()}


def run_jit_audit(arch: str) -> dict:
    """Runtime cross-check of fslint's FS002 jit-variant budget
    (DESIGN.md §8): run the static pass to get the degrees-of-freedom
    table for every hot jitted function, run the serving compile smoke,
    then compare the LIVE jit-cache sizes against the static upper
    bound ``(log2(max_tokens) + 2) ** max(degrees, 2)``.  A runtime
    count above the bound means shapes reached a jitted hot function
    without pow2 bucketing — a cache explosion neither the linter (it
    only sees static routes) nor the smoke (it only sees counts) can
    prove alone."""
    from pathlib import Path

    from repro.analysis.driver import AnalysisResult, jit_budget
    from repro.core import DecodeRunner
    from repro.kernels import ops

    src_root = Path(__file__).resolve().parents[2]   # .../src
    degrees = jit_budget([str(src_root / "repro")],
                         repo_root=str(src_root.parent))
    serving = run_serving_case(arch)
    if serving["status"] != "ok":
        return {"arch": arch, "case": "jit-audit", "status": "FAIL",
                "reason": f"serving smoke {serving['status']}", **serving}

    # the smoke's pool budget: EngineConfig(num_gpu_blocks=64) * block 16
    max_tokens = 64 * 16
    metrics = {
        "models.paged.paged_decode_step_device":
            DecodeRunner.jit_cache_size(),
        "kernels.ops._gather_swap": ops.swap_gather_cache_size(),
        "kernels.ops._scatter_swap": ops.swap_scatter_cache_size(),
        "kernels.ops._insert_prefill": ops.insert_prefill_cache_size(),
        "models.paged.prefill_kv_chunk": ops.prefill_chunk_cache_size(),
    }
    rows = {}
    violations = []
    for suffix, live in metrics.items():
        d = max((deg for qual, deg in degrees.items()
                 if qual.endswith(suffix)), default=0)
        bound = AnalysisResult.variant_bound(d, max_tokens)
        rows[suffix] = {"live_variants": live, "static_degrees": d,
                        "bound": bound}
        if live > bound:
            violations.append(f"{suffix}: {live} > {bound}")
    return {"arch": arch, "case": "jit-audit",
            "status": "FAIL" if violations else "ok",
            "max_tokens": max_tokens,
            "functions": rows,
            "violations": violations,
            "t_total_s": serving.get("t_total_s")}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help="architecture id (repeatable)")
    ap.add_argument("--shape", action="append", default=None,
                    help="input shape name (repeatable)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="off")
    ap.add_argument("--out", default=None, help="JSON output path")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    help="'+'-combinable: tp-params, kv-int8, moe-cap-shard")
    ap.add_argument("--serving", action="store_true",
                    help="also dry-run the online serving hot path "
                         "(ServingEngine add_request/step/abort)")
    ap.add_argument("--audit-jit", action="store_true",
                    help="compare live jit-variant counts after the "
                         "serving compile smoke against fslint FS002's "
                         "static bounds; fail on any excess")
    args = ap.parse_args()

    archs = args.arch or (list_archs() if args.all else ["qwen2-1.5b"])
    shapes = args.shape or ([s.name for s in INPUT_SHAPES] if args.all
                            else ["train_4k"])
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]

    results = []
    n_fail = 0
    if args.audit_jit:
        for arch in archs:
            r = run_jit_audit(arch)
            results.append(r)
            if r["status"] == "FAIL":
                n_fail += 1
            print(f"{r['status']:4s} {arch} x jit-audit "
                  + json.dumps({k: v for k, v in r.items()
                                if k in ("functions", "violations")}),
                  flush=True)
        if not (args.serving or args.all or args.arch or args.shape):
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                print(f"wrote {args.out}")
            return 1 if n_fail else 0
    if args.serving:
        for arch in archs:
            r = run_serving_case(arch)
            results.append(r)
            if r["status"] == "FAIL":
                n_fail += 1
            print(f"{r['status']:4s} {arch} x serving "
                  + json.dumps({k: v for k, v in r.items()
                                if k not in ("arch", "case", "status")}),
                  flush=True)
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    r = run_case(arch, shape, mp,
                                 want_roofline=not args.no_roofline,
                                 variant=args.variant)
                except Exception as e:  # noqa: BLE001
                    r = {"arch": arch, "shape": shape,
                         "mesh": "2x16x16" if mp else "16x16",
                         "status": "FAIL",
                         "error": f"{type(e).__name__}: {e}",
                         "trace": traceback.format_exc()[-2000:]}
                    n_fail += 1
                results.append(r)
                if r["status"] == "ok":
                    mem = r["memory"]
                    rf = r.get("roofline", {})
                    print(f"OK   {tag:60s} args={mem['argument_bytes_per_device']/2**30:6.2f}GiB "
                          f"temp={mem['temp_bytes_per_device']/2**30:6.2f}GiB "
                          f"dom={rf.get('dominant', '-'):10s} "
                          f"lower={r['t_lower_s']}s compile={r['t_compile_s']}s",
                          flush=True)
                elif r["status"] == "skipped":
                    print(f"SKIP {tag:60s} {r['reason']}", flush=True)
                else:
                    print(f"FAIL {tag:60s} {r['error']}", flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
