"""Host<->device transfer cost model.

This container has no PCIe/TPU DMA to measure, so latency accounting is
explicit and hardware-parameterized (the paper's own evaluation fixes the
hardware and derives latency from measured transfer behaviour).  The model
captures exactly the effect FastSwitch exploits:

    t(op) = dispatch_overhead + bytes / effective_bw(bytes)

with an efficiency ramp below the optimal transfer size — small per-block
copies are dispatch-bound (paper: dispatch is 90-95 % of a 128 KB copy's
total time on PCIe 4.0), large block-group copies amortize it.

Presets:
  * ``A10_PCIE4``  — the paper's LLaMA-8B testbed (32 GB/s uni, ~10 us
    dispatch, 320 KB optimal transfer size);
  * ``A100_PCIE4`` — the paper's Qwen-32B testbed;
  * ``TPU_V5E_HOST`` — the adaptation target: host DMA issue cost + host
    link bandwidth per chip.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    # I/O
    dispatch_us: float            # per-transfer issue/dispatch overhead
    h2d_bw_gbps: float            # unidirectional host->device GB/s
    d2h_bw_gbps: float
    optimal_transfer_bytes: int   # full-bandwidth threshold (PCIe4: ~320KB)
    # compute (for iteration-time modelling, bf16)
    peak_tflops: float
    hbm_gbps: float
    # per-iteration fixed overhead (kernel launch path, sampler, ...)
    iter_overhead_us: float = 300.0


# Paper (Fig. 3): a 128 KB per-block copy has ~10 us *execution* but its
# Python-call-stack dispatch is 90-95 % of total transmission time, i.e.
# O(100 us) per cudaMemcpyAsync issued from the vLLM loop.
A10_PCIE4 = HardwareSpec(
    name="A10-PCIe4", dispatch_us=100.0, h2d_bw_gbps=32.0, d2h_bw_gbps=32.0,
    optimal_transfer_bytes=320 * 1024, peak_tflops=125.0, hbm_gbps=600.0)

A100_PCIE4 = HardwareSpec(
    name="A100-PCIe4", dispatch_us=100.0, h2d_bw_gbps=32.0, d2h_bw_gbps=32.0,
    optimal_transfer_bytes=320 * 1024, peak_tflops=312.0, hbm_gbps=2039.0)

TPU_V5E_HOST = HardwareSpec(
    name="TPUv5e-host", dispatch_us=8.0, h2d_bw_gbps=32.0, d2h_bw_gbps=32.0,
    optimal_transfer_bytes=512 * 1024, peak_tflops=197.0, hbm_gbps=819.0)

PRESETS = {h.name: h for h in (A10_PCIE4, A100_PCIE4, TPU_V5E_HOST)}


def transfer_time_us(hw: HardwareSpec, nbytes: int, h2d: bool) -> float:
    """Cost of ONE transfer op of ``nbytes`` contiguous bytes."""
    return dispatch_time_us(hw) + exec_time_us(hw, nbytes, h2d)


def dispatch_time_us(hw: HardwareSpec) -> float:
    return hw.dispatch_us


def exec_time_us(hw: HardwareSpec, nbytes: int, h2d: bool) -> float:
    bw = hw.h2d_bw_gbps if h2d else hw.d2h_bw_gbps
    eff = min(1.0, max(nbytes / hw.optimal_transfer_bytes, 0.05))
    return nbytes / (bw * eff * 1e9) * 1e6


@dataclass
class IterationCostModel:
    """Decode/prefill iteration time for the *serving* hardware model.

    decode:  t = overhead + max(flops_term, hbm_term)   (memory-bound mostly)
    prefill: t = overhead + flops / peak
    """
    hw: HardwareSpec
    model_params: int             # N parameters of the served model
    kv_bytes_per_token: int       # per-token KV footprint (all layers)

    def decode_iter_us(self, batch_size: int, total_context: int) -> float:
        if batch_size == 0:
            return 0.0
        flops = 2.0 * self.model_params * batch_size
        t_compute = flops / (self.hw.peak_tflops * 1e12) * 1e6
        bytes_moved = 2.0 * self.model_params + self.kv_bytes_per_token * total_context
        t_mem = bytes_moved / (self.hw.hbm_gbps * 1e9) * 1e6
        return self.hw.iter_overhead_us + max(t_compute, t_mem)

    def prefill_us(self, n_tokens: int) -> float:
        flops = 2.0 * self.model_params * n_tokens
        return self.hw.iter_overhead_us + flops / (self.hw.peak_tflops * 1e12) * 1e6
