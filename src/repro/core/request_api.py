"""Public serving-API types — the open-world request contract.

The ServingEngine (core/serving.py) speaks vLLM's proven request shape:
clients submit work with ``add_request(prompt, SamplingParams, slo)``,
drive the engine with ``step()`` and receive incremental
``RequestOutput`` deltas per iteration plus a ``RequestEvent`` stream
for observability.  Per-request SLO deadlines (``SLOSpec``) are folded
into per-turn attainment records (``RequestSLOStats``) — the
fairness-aware metric FastSwitch optimizes for (a tail percentile says
nothing about WHICH users missed; attainment accounting does, cf. the
VTC fairness line of work in PAPERS.md).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class SamplingParams:
    """Per-request generation parameters.

    ``max_tokens`` bounds the response.  ``stop_token_ids`` ends the
    turn early when a decoded token matches (``finish_reason="stop"``
    instead of ``"length"``); the stop token itself stays in the history
    and the streamed delta — truncation is presentation, the bit-exact
    token history is the engine's parity anchor.  Sim mode has no token
    ids, so stop sets are validated but can never fire there.
    ``temperature``/``top_k``/``top_p`` default to ``None`` = inherit
    the engine-wide sampling config; real mode fuses sampling into the
    batched decode step as a per-row traced ``(B, 3)`` array
    (DESIGN.md §3.6), so per-request overrides mix freely in one batch
    without adding a compiled variant — greedy rows stay bit-exact next
    to sampled rows (sim mode never samples, so values are validated
    but unused)."""
    max_tokens: int = 16
    temperature: Optional[float] = None
    top_k: Optional[int] = None
    top_p: Optional[float] = None
    stop_token_ids: Tuple[int, ...] = ()


@dataclass(frozen=True)
class SLOSpec:
    """Per-request latency deadlines (``None`` = no deadline)."""
    ttft_ms: Optional[float] = None     # time-to-first-token deadline
    tbt_ms: Optional[float] = None      # per-token time-between-tokens

    @property
    def ttft_us(self) -> Optional[float]:
        return None if self.ttft_ms is None else self.ttft_ms * 1e3

    @property
    def tbt_us(self) -> Optional[float]:
        return None if self.tbt_ms is None else self.tbt_ms * 1e3


@dataclass
class RequestOutput:
    """One step's incremental result for one request (vLLM's
    ``RequestOutput`` shape).  ``new_tokens`` counts tokens credited
    this step (a request admitted AND decoded in the same iteration can
    emit 2); ``token_ids`` carries the actual ids only when the engine
    runs with ``stream_tokens`` (real mode — materializing ids costs the
    deferred-sync overlap, see DESIGN.md §6.2) — sim mode has no ids."""
    handle: int
    turn: int
    new_tokens: int = 0
    token_ids: Optional[List[int]] = None
    generated: int = 0                  # cumulative response tokens (turn)
    context_tokens: int = 0
    first_token: bool = False           # this step emitted the first token
    ttft_us: Optional[float] = None     # set when first_token
    finished: bool = False
    finish_reason: Optional[str] = None  # "length" | "stop" | "abort" |
    #                                      "dropped" | "error" | "shed"
    error: Optional[str] = None         # human-readable fault cause when
    #                                     finish_reason == "error"
    t_us: float = 0.0                   # engine clock at emission


@dataclass
class RequestEvent:
    """One lifecycle transition, for the per-request event log
    (JSONL-friendly: ``as_dict`` is flat and json-serializable)."""
    t_us: float
    handle: int
    kind: str        # arrive|continue|admit|resume|first_token|preempt|
    #                  swap_in|promote|finish|release|abort|drop|
    #                  error|shed|retry|drain|migrate_in|migrate_out
    data: Dict[str, object] = field(default_factory=dict)

    def as_dict(self) -> Dict[str, object]:
        return {"t_us": self.t_us, "handle": self.handle,
                "kind": self.kind, **self.data}


EVENT_KINDS = frozenset({
    "arrive", "continue", "admit", "resume", "first_token", "preempt",
    "swap_in", "promote", "finish", "release", "abort", "drop",
    # robustness layer (DESIGN.md §7): request fault, overload shed,
    # swap-copy retry, engine drain toggle (drain uses handle -1 — it is
    # an engine-level event, not a request transition)
    "error", "shed", "retry", "drain",
    # cross-replica session migration (DESIGN.md §11): a parked session
    # leaves one replica's log with migrate_out and re-enters another's
    # with migrate_in — the pair is how the router's affinity audit
    # reconstructs ownership across engines
    "migrate_in", "migrate_out"})


@dataclass
class RequestSLOStats:
    """Per-turn SLO attainment record, folded into ``EngineMetrics``.

    ``ttft_ok`` / ``tbt_ok_frac`` are ``None`` when the request carried
    no deadline for that dimension (or never reached first token /
    second token)."""
    handle: int
    turn: int
    prompt_tokens: int
    generated: int
    ttft_us: Optional[float]
    mean_tbt_us: float
    max_tbt_us: float
    ttft_ok: Optional[bool]
    tbt_ok_frac: Optional[float]
    finish_reason: str

    @property
    def attained(self) -> Optional[bool]:
        """Fully attained = TTFT met and EVERY token met its TBT
        deadline; None when no deadline applied at all."""
        parts = [p for p in (self.ttft_ok,
                             None if self.tbt_ok_frac is None
                             else self.tbt_ok_frac >= 1.0)
                 if p is not None]
        return all(parts) if parts else None


def jain_index(xs: Sequence[float]) -> Optional[float]:
    """Jain's fairness index over per-request values: 1.0 = perfectly
    even, 1/n = maximally concentrated.  None for an empty input; an
    all-zero input is trivially even."""
    xs = list(xs)
    if not xs:
        return None
    sq = sum(x * x for x in xs)
    if sq == 0.0:
        return 1.0
    s = sum(xs)
    return (s * s) / (len(xs) * sq)
