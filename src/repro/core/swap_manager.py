"""Multithreading Swap Manager — FastSwitch §3.2, Algorithm 1.

Responsibilities:
  * asynchronous swap-in/out dispatch on a worker pool (the paper offloads
    CUDA API dispatch to C++ threads; here workers perform the actual pool
    copies while the *latency* of dispatch+execution is accounted on a
    simulated swap-stream timeline — see DESIGN.md §2.3);
  * adaptive sync/async decision from a recent-swap profiler (Step 4);
  * KV-conflict detection between in-flight swap-ins and newly allocated
    GPU blocks, resolved by fine-grained synchronization (Step 3.1);
  * dispatch-order coherence: after ``sync_every`` queued dispatches a
    fine-grained sync point is inserted so higher-priority copies can enter
    the queue (its small cost is part of the call-stack overhead budget).

The simulated clock makes every latency metric deterministic and
hardware-parameterized while the data plane stays real.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.cache.paged import PagedPools
from repro.io.cost_model import HardwareSpec, dispatch_time_us, exec_time_us


class SimClock:
    def __init__(self):
        self.now_us = 0.0

    def advance(self, dt_us: float) -> None:
        assert dt_us >= -1e-9, dt_us
        self.now_us += max(dt_us, 0.0)

    def advance_to(self, t_us: float) -> None:
        self.now_us = max(self.now_us, t_us)


@dataclass
class SwapTask:
    req_id: int
    direction: str                    # "in" | "out"
    n_ops: int
    n_blocks: int
    bytes_total: int
    issued_at: float
    done_at: float
    gpu_blocks: Set[int] = field(default_factory=set)
    cpu_blocks: Set[int] = field(default_factory=set)
    future: Optional[Future] = None
    synchronous: bool = False
    # failure containment (DESIGN.md §7): the copy closure is retained so
    # retries and the watchdog's synchronous rescue can re-run it
    copy_fn: Optional[object] = None
    retries: int = 0                  # failed attempts absorbed by retry
    failed: Optional[str] = None      # terminal copy error (retries spent)
    stalled_us: float = 0.0           # injected completion-signal delay

    def is_completed(self, now_us: float) -> bool:
        if self.future is not None and not self.future.done():
            return False        # data plane must also be finished
        return now_us >= self.done_at


@dataclass
class SwapRecord:
    """r_info entry (recent swapping information, Algorithm 1)."""
    t_us: float
    direction: str
    n_ops: int
    n_blocks: int
    duration_us: float


class MultithreadingSwapManager:
    def __init__(self, hw: HardwareSpec, pools: Optional[PagedPools] = None,
                 *, async_enabled: bool = True, adaptive: bool = True,
                 n_threads: int = 4, sync_every: int = 16,
                 sync_point_us: float = 5.0, r_info_window: int = 64,
                 sync_stall_frac: float = 0.04,
                 max_copy_retries: int = 2,
                 retry_backoff_us: float = 200.0):
        self.hw = hw
        self.pools = pools
        # Mesh sharding (DESIGN.md §9): under a model-parallel mesh the
        # KV pool is HEAD-sharded, so block ids stay shard-GLOBAL — every
        # shard holds the same block layout over its local heads.  The
        # conflict sets, copy_deps and dispatch ordering below are
        # therefore mesh-invariant; only the data plane fans out (one
        # host transfer per chunk PER SHARD, each 1/n_shards the bytes,
        # over per-shard links — so modelled latency stays
        # mesh-independent and sim/real parity holds by construction).
        self.n_shards = 1 if pools is None else pools.n_shards
        self.async_enabled = async_enabled
        self.adaptive = adaptive
        self.sync_every = sync_every
        self.sync_point_us = sync_point_us
        # adaptive decision: a swap whose predicted stall is below this
        # fraction of one decode iteration is dispatched synchronously
        self.sync_stall_frac = sync_stall_frac
        self._executor = ThreadPoolExecutor(max_workers=n_threads) \
            if pools is not None and pools.with_data else None
        self._pool_lock = threading.Lock()
        # swap-stream timeline (I/O resource occupancy)
        self.stream_free_at = 0.0
        self._dispatches_since_sync = 0
        # queues (Algorithm 1)
        self.ongoing_swap_in: List[SwapTask] = []
        # in-flight async swap-outs: their source GPU blocks must not be
        # overwritten until the d2h copy completes (paper §3.2: conflicts
        # involve "ongoing swapping requests" in BOTH directions)
        self.ongoing_swap_out: List[SwapTask] = []
        self.r_info: List[SwapRecord] = []
        self.r_info_window = r_info_window
        # recent decode-iteration durations (the overlap window an async
        # swap hides in), fed by the engine via note_decode_iter
        self.iter_info: List[float] = []
        # metrics
        self.total_ops = 0
        self.total_blocks = 0
        self.total_bytes = 0
        self.ops_by_dir = {"in": 0, "out": 0}
        self.blocks_by_dir = {"in": 0, "out": 0}
        self.total_stall_us = 0.0          # main-thread (GPU-idle) stall
        self.total_io_us = 0.0             # swap-stream busy time
        self.n_conflicts = 0
        self.n_syncs = 0
        self.callstack_overhead_us = 0.0   # fine-grained sync points etc.
        # failure containment (DESIGN.md §7): copy errors never escape a
        # worker — a copy is retried with backoff (charged to the task's
        # simulated ``done_at``); a task whose retries are spent lands on
        # ``failed_tasks`` for the engine's recovery ladder to process.
        self.max_copy_retries = max_copy_retries
        self.retry_backoff_us = retry_backoff_us
        self._fail_lock = threading.Lock()
        self.failed_tasks: List[SwapTask] = []
        self.retry_log: List[Dict[str, object]] = []   # engine drains ->
        #                                                "retry" events
        self.n_retries = 0
        self.n_copy_failures = 0
        self.n_watchdog = 0

    # ------------------------------------------------------------------
    # cost helpers
    # ------------------------------------------------------------------

    def _op_costs(self, runs: Sequence[Tuple[int, int]], block_bytes: int,
                  h2d: bool) -> Tuple[int, int, int, float, float]:
        """runs: [(start_block, n_blocks)] contiguous transfer ops.
        Returns (n_ops, n_blocks, bytes, dispatch_us, exec_us)."""
        n_ops = len(runs)
        n_blocks = sum(n for _, n in runs)
        total_bytes = n_blocks * block_bytes
        disp = n_ops * dispatch_time_us(self.hw)
        ex = sum(exec_time_us(self.hw, n * block_bytes, h2d) for _, n in runs)
        return n_ops, n_blocks, total_bytes, disp, ex

    def _sync_points(self, n_ops: int) -> float:
        """Dispatch-order coherence: a sync point every ``sync_every`` ops."""
        self._dispatches_since_sync += n_ops
        n_sync = self._dispatches_since_sync // self.sync_every
        self._dispatches_since_sync %= self.sync_every
        cost = n_sync * self.sync_point_us
        self.callstack_overhead_us += cost
        return cost

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------

    def dispatch(self, clock: SimClock, req_id: int, direction: str,
                 runs: Sequence[Tuple[int, int]], block_bytes: int,
                 gpu_blocks: Sequence[int], *, asynchronous: bool,
                 copy_fn=None, copy_deps: Sequence[Future] = (),
                 cpu_blocks: Sequence[int] = (),
                 extra_latency_us: float = 0.0) -> SwapTask:
        """Issue one swap (all ops of one request, one direction).

        ``copy_deps``: data-plane futures that must complete before
        ``copy_fn`` runs (any copy touching CPU blocks a still-queued
        swap-out writes — see ``data_deps``).  Awaited BEFORE the pool
        lock is taken — a dependency's own copy needs that lock, so
        waiting inside it would deadlock.  ``cpu_blocks``: the host
        blocks this task's copy writes (out) or reads (in), tracked so
        later copies can order behind it.

        ``extra_latency_us``: injected completion-signal delay (fault
        injection): extends the task's ``done_at`` but NOT the stream
        timeline — a stuck signal does not occupy the link; the watchdog
        is what rescues it."""
        h2d = direction == "in"
        n_ops, n_blocks, nbytes, disp, ex = self._op_costs(
            runs, block_bytes, h2d)
        sync_cost = self._sync_points(n_ops)
        # capture the issue time BEFORE any synchronous stall advances the
        # clock, or sync tasks would record issued_at == done_at
        issued_at = clock.now_us
        start = max(clock.now_us, self.stream_free_at)
        duration = disp + ex + sync_cost
        done_at = start + duration
        self.stream_free_at = done_at
        self.total_io_us += duration
        done_at += extra_latency_us

        if asynchronous:
            # dispatch happens on a worker thread: main thread not blocked
            stall = 0.0
        else:
            # main thread dispatches AND waits: inference stalls until done
            stall = done_at - clock.now_us
            clock.advance_to(done_at)
        self.total_stall_us += stall

        task = SwapTask(req_id=req_id, direction=direction, n_ops=n_ops,
                        n_blocks=n_blocks, bytes_total=nbytes,
                        issued_at=issued_at, done_at=done_at,
                        gpu_blocks=set(gpu_blocks),
                        cpu_blocks=set(cpu_blocks),
                        synchronous=not asynchronous,
                        copy_fn=copy_fn, stalled_us=extra_latency_us)
        if copy_fn is not None:
            if asynchronous and self._executor is not None \
                    and direction == "out":
                # only d2h gathers run on workers: they READ the pool
                # (forced before return) and never donate.  Pool-MUTATING
                # swap-in copies always run on the dispatching thread so
                # the pool's donation chain (decode, prefill insert,
                # swap-in scatter) stays single-threaded — cross-thread
                # donation of in-flight buffers tears KV (DESIGN.md §4.3).
                task.future = self._executor.submit(
                    self._run_copy_guarded, task, copy_deps)
            else:
                self._run_copy_guarded(task, copy_deps)
                if task.synchronous and task.retries:
                    # inline retries pushed done_at out by the backoff:
                    # the dispatching thread waited that out too
                    extra = max(0.0, task.done_at - clock.now_us)
                    self.total_stall_us += extra
                    clock.advance_to(task.done_at)
        self.total_ops += n_ops
        self.total_blocks += n_blocks
        self.total_bytes += nbytes
        self.ops_by_dir[direction] += n_ops
        self.blocks_by_dir[direction] += n_blocks
        # record at ISSUE time: a synchronous stall has already advanced
        # the clock here, and the adaptive profiler must see issue-time
        # ordering (a sync task would otherwise appear to start at its
        # own completion)
        self.r_info.append(SwapRecord(issued_at, direction, n_ops,
                                      n_blocks, duration))
        if len(self.r_info) > self.r_info_window:
            self.r_info = self.r_info[-self.r_info_window:]
        if asynchronous:
            if direction == "in":
                self.ongoing_swap_in.append(task)
            else:
                self.ongoing_swap_out.append(task)
        return task

    def _locked(self, fn):
        with self._pool_lock:
            return fn()

    def _run_copy_guarded(self, task: SwapTask,
                          deps: Sequence[Future]) -> None:
        """Run one task's data-plane copy with bounded retry.  NEVER
        raises: an exception from a copy must not escape a worker future
        into whatever unrelated request later awaits it (``synchronize``,
        ``data_deps``) — that is the exact failure-amplification this
        layer removes.  Each retry pushes the task's simulated ``done_at``
        out by a linear backoff; spent retries mark the task ``failed``
        and queue it for the engine's recovery ladder."""
        for f in deps:              # data ordering only — no sim-clock cost
            try:
                f.result()
            except BaseException:
                pass                # dep failures are handled by THEIR task
        if task.copy_fn is None:
            return
        attempt = 0
        while True:
            try:
                with self._pool_lock:
                    task.copy_fn()
                return
            except Exception as e:
                attempt += 1
                task.retries = attempt
                with self._fail_lock:
                    self.n_retries += 1
                    self.retry_log.append({
                        "rid": task.req_id, "direction": task.direction,
                        "attempt": attempt,
                        "error": f"{type(e).__name__}: {e}"})
                if attempt > self.max_copy_retries:
                    task.failed = f"{type(e).__name__}: {e}"
                    with self._fail_lock:
                        self.n_copy_failures += 1
                        self.failed_tasks.append(task)
                    return
                task.done_at += self.retry_backoff_us * attempt

    def data_deps(self, cpu_blocks: Sequence[int]) -> List[Future]:
        """Data-plane futures a new copy touching ``cpu_blocks`` must
        order behind: any still-in-flight swap-out WRITING an overlapping
        host block.  Covers a swap-in reading blocks its own queued
        swap-out writes AND a contamination reallocation handing a
        victim's CPU blocks to another request while the victim's d2h is
        still queued (late worker write would clobber the new owner).
        GPU-side ordering is covered by block-conflict syncs — the
        simulated stream serializes *latency*, but worker execution
        order is not FIFO."""
        s = set(cpu_blocks)
        return [t.future for t in self.ongoing_swap_out
                if t.future is not None and t.cpu_blocks & s]

    # ------------------------------------------------------------------
    # Algorithm 1 steps
    # ------------------------------------------------------------------

    def poll_completed(self, clock: SimClock) -> List[SwapTask]:
        """Step 1: move finished swap-ins out of ongoing_swap_in (and prune
        finished swap-outs)."""
        done = [t for t in self.ongoing_swap_in if t.is_completed(clock.now_us)]
        self.ongoing_swap_in = [t for t in self.ongoing_swap_in
                                if not t.is_completed(clock.now_us)]
        self.ongoing_swap_out = [t for t in self.ongoing_swap_out
                                 if not t.is_completed(clock.now_us)]
        return done

    def detect_conflicts(self, gpu_blocks: Sequence[int]) -> List[SwapTask]:
        """Step 3.1: in-flight swaps whose GPU blocks intersect
        ``gpu_blocks`` (about to be written by running requests): swap-in
        targets AND swap-out sources both conflict."""
        s = set(gpu_blocks)
        return [t for t in self.ongoing_swap_in + self.ongoing_swap_out
                if t.gpu_blocks & s]

    def synchronize(self, clock: SimClock, tasks: Optional[List[SwapTask]]
                    = None) -> None:
        """Fine-grained sync: wait for specific tasks (or all)."""
        tasks = self.ongoing_swap_in if tasks is None else tasks
        if not tasks:
            return
        target = max(t.done_at for t in tasks)
        stall = max(0.0, target - clock.now_us)
        self.total_stall_us += stall
        clock.advance_to(target)
        for t in tasks:
            if t.future is not None:
                t.future.result()
        done_ids = {id(t) for t in tasks}
        self.ongoing_swap_in = [t for t in self.ongoing_swap_in
                                if id(t) not in done_ids]
        self.ongoing_swap_out = [t for t in self.ongoing_swap_out
                                 if id(t) not in done_ids]
        self.n_syncs += 1

    def retire_request(self, rid: int) -> int:
        """Abort support: drop an aborted request's in-flight swap-IN
        chunk tasks.  Their data-plane copies already ran inline on the
        dispatching thread (pool-mutating h2d copies never go to workers
        — DESIGN.md §4.3), so only simulated latency is outstanding and
        nothing dangles.  Its swap-OUT tasks are deliberately LEFT on
        ``ongoing_swap_out``: their worker d2h gathers may still be
        writing the request's (now released) CPU blocks, and later
        copies reallocating those blocks order behind the listed futures
        via ``data_deps`` — dropping the task would drop that ordering.
        They retire on completion through ``poll_completed`` as usual.
        Returns the number of swap-in tasks dropped."""
        before = len(self.ongoing_swap_in)
        self.ongoing_swap_in = [t for t in self.ongoing_swap_in
                                if t.req_id != rid]
        return before - len(self.ongoing_swap_in)

    # ------------------------------------------------------------------
    # failure containment (DESIGN.md §7)
    # ------------------------------------------------------------------

    def has_failed(self, rid: int, direction: Optional[str] = None) -> bool:
        """True if an unprocessed copy failure is queued for ``rid``."""
        with self._fail_lock:
            return any(t.req_id == rid
                       and (direction is None or t.direction == direction)
                       for t in self.failed_tasks)

    def take_failed(self) -> List[SwapTask]:
        """Drain the failed-task queue (engine step 0: the recovery
        ladder processes each failure exactly once)."""
        with self._fail_lock:
            out, self.failed_tasks = self.failed_tasks, []
        # a failed task's data never arrived — drop it from the ongoing
        # lists so it neither blocks promotion forever nor orders later
        # copies behind a write that will not happen
        dead = {id(t) for t in out}
        self.ongoing_swap_in = [t for t in self.ongoing_swap_in
                                if id(t) not in dead]
        self.ongoing_swap_out = [t for t in self.ongoing_swap_out
                                 if id(t) not in dead]
        return out

    def take_failed_for(self, rid: int) -> List[SwapTask]:
        """Drain (and de-list) queued copy failures for one request —
        the inline-detection path (``_swap_in`` / prefix restore) and
        request teardown, which must not leave stale failures for a
        later reuse of the handle."""
        with self._fail_lock:
            mine = [t for t in self.failed_tasks if t.req_id == rid]
            self.failed_tasks = [t for t in self.failed_tasks
                                 if t.req_id != rid]
        dead = {id(t) for t in mine}
        self.ongoing_swap_in = [t for t in self.ongoing_swap_in
                                if id(t) not in dead]
        self.ongoing_swap_out = [t for t in self.ongoing_swap_out
                                 if id(t) not in dead]
        return mine

    def drain_retries(self) -> List[Dict[str, object]]:
        """Drain the retry log (engine -> "retry" events)."""
        with self._fail_lock:
            out, self.retry_log = self.retry_log, []
        return out

    def watchdog_check(self, clock: SimClock,
                       watchdog_us: float) -> List[SwapTask]:
        """Escalate stuck in-flight tasks (DESIGN.md §7 ladder step 2):
        a task still incomplete ``watchdog_us`` after issue gets its data
        plane forced synchronously on the engine thread — if its copy had
        already failed terminally, one last synchronous retry runs here —
        and its stuck completion signal clamped to now (+ one sync-point
        charge).  Returns the tasks rescued; a task whose synchronous
        retry also failed stays ``failed`` for ``take_failed``."""
        if watchdog_us <= 0:
            return []
        rescued: List[SwapTask] = []
        for t in list(self.ongoing_swap_in) + list(self.ongoing_swap_out):
            if t.is_completed(clock.now_us) or t.failed is not None:
                continue
            if clock.now_us - t.issued_at < watchdog_us:
                continue
            if t.future is not None:
                t.future.result()       # guarded runner: never raises
            if t.failed is not None:
                # terminal copy failure surfaced while we waited: one
                # synchronous retried copy on the engine thread
                try:
                    with self._pool_lock:
                        if t.copy_fn is not None:
                            t.copy_fn()
                    t.failed = None
                    with self._fail_lock:
                        if t in self.failed_tasks:
                            self.failed_tasks.remove(t)
                except Exception:
                    continue            # stays failed; ladder escalates
            stall = self.sync_point_us
            self.total_stall_us += stall
            self.callstack_overhead_us += stall
            clock.advance(stall)
            t.done_at = min(t.done_at, clock.now_us)
            self.n_watchdog += 1
            rescued.append(t)
        return rescued

    def resolve_conflicts(self, clock: SimClock,
                          gpu_blocks: Sequence[int]) -> int:
        conflicts = self.detect_conflicts(gpu_blocks)
        if conflicts:
            self.n_conflicts += len(conflicts)
            self.synchronize(clock, conflicts)
        return len(conflicts)

    # ------------------------------------------------------------------
    # Step 4: adaptive strategy
    # ------------------------------------------------------------------

    def note_decode_iter(self, duration_us: float) -> None:
        """Feed the adaptive profiler one decode-iteration duration — the
        overlap window an asynchronous swap can hide in."""
        self.iter_info.append(duration_us)
        if len(self.iter_info) > self.r_info_window:
            self.iter_info = self.iter_info[-self.r_info_window:]

    def predicted_stall_us(self, runs: Sequence[Tuple[int, int]],
                           block_bytes: int, h2d: bool,
                           now_us: Optional[float] = None) -> float:
        """What a SYNCHRONOUS dispatch of ``runs`` would stall the main
        thread: queue wait behind in-flight swaps on the stream, plus
        dispatch and execution of every op."""
        _, _, _, disp, ex = self._op_costs(runs, block_bytes, h2d)
        queue = max(0.0, self.stream_free_at - now_us) \
            if now_us is not None else 0.0
        return queue + disp + ex

    def decide_async(self, running_batch: int, pending_swap_blocks: int,
                     *, runs: Optional[Sequence[Tuple[int, int]]] = None,
                     block_bytes: Optional[int] = None, h2d: bool = False,
                     now_us: Optional[float] = None) -> bool:
        """Dynamic swapping decision (paper: async is NOT always best —
        with many short requests the swap is small relative to the tokens
        a sync swap would unblock), driven by the cost model: compare the
        PREDICTED synchronous stall (queue wait + dispatch + execution,
        ``exec_time_us``) against the PREDICTED overlap window (the mean
        of recent decode-iteration durations).  A swap whose stall is a
        negligible fraction of one iteration (``sync_stall_frac``,
        calibrated so the paper's "<8 blocks at batch>=32" region maps to
        ~4% of an A10 iteration) is cheaper done synchronously — no
        conflict-sync risk, no bookkeeping; a larger one pays for the
        overlap.  Larger running batches mean longer iterations, widening
        the sync-preferred region exactly as the paper observes.

        When the caller has no runs/bytes at hand (legacy call sites,
        tests), the per-block transfer cost is estimated from the full
        recent-swap profile (``r_info``, bounded by ``r_info_window`` —
        not a hardcoded sub-window)."""
        if not self.async_enabled:
            return False
        if not self.adaptive:
            return True
        if runs and block_bytes:
            stall = self.predicted_stall_us(runs, block_bytes, h2d, now_us)
        else:
            if not self.r_info:
                return True
            recent = self.r_info            # full profiler window
            per_block = (sum(r.duration_us for r in recent)
                         / max(1, sum(r.n_blocks for r in recent)))
            stall = pending_swap_blocks * per_block
            if now_us is not None:
                stall += max(0.0, self.stream_free_at - now_us)
        if self.iter_info:
            window = sum(self.iter_info) / len(self.iter_info)
        else:
            # no decode history yet: iteration time grows roughly with
            # the batch; scale the fixed overhead as a coarse stand-in
            window = self.hw.iter_overhead_us * max(1.0, running_batch / 8.0)
        return stall > self.sync_stall_frac * window

    # ------------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        return {
            "total_ops": self.total_ops,
            "total_blocks": self.total_blocks,
            "total_bytes": self.total_bytes,
            "ops_in": self.ops_by_dir["in"],
            "ops_out": self.ops_by_dir["out"],
            "blocks_in": self.blocks_by_dir["in"],
            "blocks_out": self.blocks_by_dir["out"],
            "total_stall_us": self.total_stall_us,
            "total_io_us": self.total_io_us,
            "n_conflicts": self.n_conflicts,
            "n_syncs": self.n_syncs,
            "ongoing": len(self.ongoing_swap_in),
            "callstack_overhead_us": self.callstack_overhead_us,
            "copy_retries": self.n_retries,
            "copy_failures": self.n_copy_failures,
            "watchdog_rescues": self.n_watchdog,
        }

    def shutdown(self):
        if self._executor is not None:
            self._executor.shutdown(wait=True)
