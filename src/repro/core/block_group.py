"""Dynamic Block Group Manager — FastSwitch §3.1.

KV cache memory is preallocated as vLLM-style fixed blocks, then managed in
*block groups*: contiguous runs of blocks allocated buddy-style.  Each
request holds an ordered list of groups; the most recently allocated group
is *active* and its unused tail can be split off to serve other requests
(the paper's "steal from a randomly selected request's active group").

The manager exposes exactly what the paper measures:
  * per-request swap ops == number of contiguous groups (vs per-block ops),
  * average swap granularity (blocks per group),
  * split/merge bookkeeping with adjacency merging of free groups.

``group_size_blocks=1`` degenerates to the vLLM per-block baseline policy.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


class OutOfBlocksError(Exception):
    """No free GPU blocks; the scheduler must preempt a victim."""


@dataclass
class BlockGroup:
    start: int                 # first block id (contiguous range)
    length: int                # number of blocks
    owner: Optional[int] = None   # request id
    used: int = 0              # blocks holding live KV

    @property
    def end(self) -> int:
        return self.start + self.length

    @property
    def free_tail(self) -> int:
        return self.length - self.used

    def block_ids(self) -> range:
        return range(self.start, self.start + self.used)


@dataclass
class _ReqState:
    groups: List[BlockGroup] = field(default_factory=list)

    @property
    def active(self) -> Optional[BlockGroup]:
        return self.groups[-1] if self.groups else None

    def used_blocks(self) -> int:
        return sum(g.used for g in self.groups)


class DynamicBlockGroupManager:
    """Buddy-style contiguous block-group allocator over a flat block pool."""

    def __init__(self, num_blocks: int, block_size_tokens: int = 16,
                 initial_group_blocks: int = 60, seed: int = 0):
        self.num_blocks = num_blocks
        self.block_size_tokens = block_size_tokens
        self.initial_group_blocks = max(1, initial_group_blocks)
        self._rng = random.Random(seed)
        # free space as {start: length}, kept merged
        self.free: Dict[int, int] = {0: num_blocks}
        self.requests: Dict[int, _ReqState] = {}
        self._token_counts: Dict[int, int] = {}
        # per-block sharer refcounts (prefix cache): a block with a nonzero
        # refcount is mapped into at least one request's block table beyond
        # its owner and must never reach the free list
        self._block_refs: Dict[int, int] = {}
        # counters
        self.n_splits = 0
        self.n_merges = 0
        self.n_steals = 0

    # ------------------------------------------------------------------
    # free-list primitives
    # ------------------------------------------------------------------

    def free_blocks(self) -> int:
        return sum(self.free.values())

    def used_blocks(self) -> int:
        return sum(r.used_blocks() for r in self.requests.values())

    def _take_free(self, want: int) -> Optional[BlockGroup]:
        """Best-fit: smallest free group >= want; else None."""
        best = None
        for start, length in self.free.items():
            if length >= want and (best is None or length < self.free[best]):
                best = start
        if best is None:
            return None
        length = self.free.pop(best)
        if length > want:
            self.free[best + want] = length - want     # split
            self.n_splits += 1
        return BlockGroup(start=best, length=want)

    def _take_largest(self) -> Optional[BlockGroup]:
        if not self.free:
            return None
        start = max(self.free, key=lambda s: self.free[s])
        length = self.free.pop(start)
        return BlockGroup(start=start, length=length)

    def _release(self, start: int, length: int) -> None:
        """Return a contiguous range to the free list, merging neighbours."""
        if length <= 0:
            return
        for b in range(start, start + length):
            assert not self._block_refs.get(b), \
                f"freeing block {b} with refcount {self._block_refs[b]}"
        # merge with successor
        end = start + length
        if end in self.free:
            length += self.free.pop(end)
            self.n_merges += 1
        # merge with predecessor
        for s in list(self.free):
            if s + self.free[s] == start:
                self.free[s] += length
                self.n_merges += 1
                # possibly also merged with successor already handled
                return
        self.free[start] = length

    # ------------------------------------------------------------------
    # allocation API
    # ------------------------------------------------------------------

    def register(self, req_id: int) -> None:
        self.requests.setdefault(req_id, _ReqState())

    def expected_group_blocks(self, req_id: int) -> int:
        """Dynamic sizing: start from the configured initial size, shrink
        with availability (paper: 'dynamically adjusts this size ... taking
        into account the current availability of free KV cache')."""
        avail = self.free_blocks()
        want = self.initial_group_blocks
        if avail < want * 4:                  # pressure: shrink expectation
            want = max(1, avail // 4)
        return max(1, want)

    def allocate_tokens(self, req_id: int, n_tokens: int) -> List[BlockGroup]:
        """Ensure capacity for ``n_tokens`` *additional* tokens.  Returns the
        list of groups that gained blocks (for swap bookkeeping).

        TRANSACTIONAL: on OutOfBlocksError every block acquired during this
        call is returned (partial allocations must never leak — a request
        that cannot be fully placed holds nothing extra)."""
        self.register(req_id)
        n_blocks = self._blocks_for(req_id, n_tokens)
        touched: List[BlockGroup] = []
        acquired: List[BlockGroup] = []            # new groups this call
        used_increments: Dict[int, int] = {}       # id(group) -> blocks taken
        st = self.requests[req_id]
        while n_blocks > 0:
            g = st.active
            if g is not None and g.free_tail > 0:
                take = min(g.free_tail, n_blocks)
                g.used += take
                used_increments[id(g)] = used_increments.get(id(g), 0) + take
                n_blocks -= take
                if g not in touched:
                    touched.append(g)
                continue
            # grab a whole expected-size group when possible (leaves growth
            # room and keeps future swaps coarse), else whatever fits/exists.
            # Per-block policy (vLLM baseline) always takes single blocks.
            if self.initial_group_blocks == 1:
                want = 1
            else:
                want = max(n_blocks, self.expected_group_blocks(req_id))
            ng = (self._take_free(want)
                  or self._take_free(n_blocks)           # exact-fit attempt
                  or self._take_largest()                # partial
                  or self._steal(n_blocks))              # steal a free tail
            if ng is None:
                self._rollback(st, acquired, used_increments)
                raise OutOfBlocksError(
                    f"need {n_blocks} blocks, none free (req {req_id})")
            ng.owner = req_id
            ng.used = 0
            st.groups.append(ng)
            acquired.append(ng)
            touched.append(ng)
        return touched

    def _rollback(self, st: _ReqState, acquired: List[BlockGroup],
                  used_increments: Dict[int, int]) -> None:
        for g in acquired:
            st.groups.remove(g)
            self._release(g.start, g.length)
            used_increments.pop(id(g), None)
        for g in st.groups:
            inc = used_increments.get(id(g))
            if inc:
                g.used -= inc

    def _blocks_for(self, req_id: int, n_tokens: int) -> int:
        """Blocks needed for n_tokens more tokens given current tail slack."""
        st = self.requests[req_id]
        used_tokens = self.request_tokens(req_id)
        cap_tokens = st.used_blocks() * self.block_size_tokens
        slack = cap_tokens - used_tokens
        # NOTE: the manager tracks capacity at block granularity; token-level
        # occupancy is tracked by the engine.  Here n_tokens are *new* tokens
        # beyond current capacity.
        need_tokens = max(0, n_tokens - slack)
        return (need_tokens + self.block_size_tokens - 1) // self.block_size_tokens

    def request_tokens(self, req_id: int) -> int:
        return self._token_counts.get(req_id, 0)

    def note_tokens(self, req_id: int, n_tokens: int) -> None:
        self._token_counts[req_id] = self._token_counts.get(req_id, 0) + n_tokens

    def _steal(self, n_blocks: int) -> Optional[BlockGroup]:
        """Take the unused tail of a randomly selected request's active
        group (paper §3.1)."""
        candidates = [r for r, st in self.requests.items()
                      if st.active is not None and st.active.free_tail > 0]
        if not candidates:
            return None
        victim = self._rng.choice(candidates)
        vg = self.requests[victim].active
        take = min(vg.free_tail, max(n_blocks, 1))
        # split the tail off the victim's active group
        new_start = vg.end - take
        vg.length -= take
        self.n_steals += 1
        return BlockGroup(start=new_start, length=take)

    # ------------------------------------------------------------------
    # freeing / swap bookkeeping
    # ------------------------------------------------------------------

    def release_request(self, req_id: int) -> List[Tuple[int, int]]:
        """Free all groups of a request.  Returns [(start, used_blocks)]
        runs that were live (for swap-out op accounting)."""
        st = self.requests.pop(req_id, None)
        if st is None:
            return []
        runs = [(g.start, g.used) for g in st.groups if g.used > 0]
        for g in st.groups:
            self._release(g.start, g.length)
        self._token_counts.pop(req_id, None)
        return runs

    def request_runs(self, req_id: int) -> List[Tuple[int, int]]:
        """Contiguous (start, n_blocks) runs of LIVE blocks for swapping.
        Adjacent groups merge into one run (that is the whole point)."""
        st = self.requests.get(req_id)
        if st is None:
            return []
        spans = sorted((g.start, g.used) for g in st.groups if g.used > 0)
        runs: List[Tuple[int, int]] = []
        for start, used in spans:
            if runs and runs[-1][0] + runs[-1][1] == start:
                runs[-1] = (runs[-1][0], runs[-1][1] + used)
            else:
                runs.append((start, used))
        return runs

    def request_block_ids(self, req_id: int) -> List[int]:
        """Logical->physical block table (token order)."""
        st = self.requests.get(req_id)
        if st is None:
            return []
        ids: List[int] = []
        for g in st.groups:
            ids.extend(g.block_ids())
        return ids

    def release_tail_group(self, req_id: int) -> Optional[Tuple[int, int]]:
        """Free the *last* (most recently allocated) group of ``req_id``.

        Public tail-release API shared by KV-reuse contamination
        (``reuse._contaminate_one``) and the prefix-cache evictor — the
        suffix of a request's allocation is always the cheapest part to
        sacrifice (FastSwitch §3.3 contaminates tail-first; prefix-cache
        nodes own exactly one single-block group, so their "tail" is the
        whole node).  Returns the freed ``(start, length)`` range, or
        ``None`` when the request holds no groups.  Refuses (returns
        ``None``) if any block in the tail group is still refcounted by a
        sharer.
        """
        st = self.requests.get(req_id)
        if st is None or not st.groups:
            return None
        g = st.groups[-1]
        if any(self._block_refs.get(b) for b in range(g.start, g.end)):
            return None
        st.groups.pop()
        self._release(g.start, g.length)
        self._token_counts[req_id] = max(
            0, self._token_counts.get(req_id, 0)
            - g.length * self.block_size_tokens)
        if not st.groups:
            self.requests.pop(req_id, None)
            self._token_counts.pop(req_id, None)
        return (g.start, g.length)

    # ------------------------------------------------------------------
    # prefix-cache support: per-block refcounts + block donation
    # ------------------------------------------------------------------

    def ref_block(self, block: int) -> None:
        self._block_refs[block] = self._block_refs.get(block, 0) + 1

    def unref_block(self, block: int) -> None:
        n = self._block_refs.get(block, 0) - 1
        assert n >= 0, f"unref of unreferenced block {block}"
        if n:
            self._block_refs[block] = n
        else:
            self._block_refs.pop(block, None)

    def block_refcount(self, block: int) -> int:
        return self._block_refs.get(block, 0)

    def transfer_prefix_blocks(self, req_id: int,
                               owners: List[int]) -> List[int]:
        """Donate the first ``len(owners)`` used blocks of ``req_id``'s
        block table to new single-block groups owned by ``owners[i]``
        (prefix-cache node insertion).  The physical blocks do not move —
        only ownership and token accounting change, so the request's
        composed block table (shared prefix + private suffix) stays
        byte-identical.  Returns the donated physical block ids in token
        order."""
        n_blocks = len(owners)
        st = self.requests.get(req_id)
        assert st is not None, f"transfer from unknown request {req_id}"
        assert sum(g.used for g in st.groups) >= n_blocks, \
            f"request {req_id} holds fewer than {n_blocks} used blocks"
        out: List[int] = []
        while len(out) < n_blocks:
            g = st.groups[0]
            assert g.used > 0, "leading group with no live blocks"
            take = min(n_blocks - len(out), g.used)
            for i in range(take):
                owner = owners[len(out)]
                self.register(owner)
                self.requests[owner].groups.append(
                    BlockGroup(start=g.start + i, length=1,
                               owner=owner, used=1))
                self._token_counts[owner] = (
                    self._token_counts.get(owner, 0)
                    + self.block_size_tokens)
                out.append(g.start + i)
            if take == g.used and g.length == g.used:
                st.groups.pop(0)
            else:
                # keep the (possibly unused) tail of the group with the
                # donating request
                g.start += take
                g.length -= take
                g.used -= take
        self._token_counts[req_id] = max(
            0, self._token_counts.get(req_id, 0)
            - n_blocks * self.block_size_tokens)
        return out

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------

    def granularity_stats(self) -> Dict[str, float]:
        sizes = [g.used for st in self.requests.values()
                 for g in st.groups if g.used > 0]
        if not sizes:
            return {"avg_group_blocks": 0.0, "n_groups": 0}
        return {"avg_group_blocks": sum(sizes) / len(sizes),
                "n_groups": len(sizes)}

    def check_invariants(self) -> None:
        """Paranoid validation used by property tests."""
        claimed = []
        for start, length in self.free.items():
            assert length > 0
            claimed.append((start, start + length, "free"))
        for rid, st in self.requests.items():
            for g in st.groups:
                assert 0 <= g.used <= g.length, (rid, g)
                assert g.owner == rid
                claimed.append((g.start, g.end, f"req{rid}"))
        claimed.sort()
        prev_end = 0
        covered = 0
        for s, e, who in claimed:
            assert s >= prev_end, f"overlap at {s} ({who})"
            prev_end = e
            covered += e - s
        assert covered <= self.num_blocks
        # free list must be merged (no adjacent free ranges)
        starts = sorted(self.free)
        for a, b in zip(starts, starts[1:]):
            assert a + self.free[a] < b, "unmerged adjacent free groups"
        # refcounted blocks must be live (owned + used), never free
        if self._block_refs:
            owned = set()
            for st in self.requests.values():
                for g in st.groups:
                    owned.update(g.block_ids())
            for blk, n in self._block_refs.items():
                assert n > 0, f"zero refcount retained for block {blk}"
                assert blk in owned, f"refcounted block {blk} is not live"
