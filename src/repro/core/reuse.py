"""KV Cache Reuse Mechanism — FastSwitch §3.3.

Keeps a persistent CPU-side copy of each conversation's KV cache across
preemptions and turns, tracks *contamination* (CPU blocks reclaimed by
higher-priority requests), and computes the minimal swap-out increment.

CPU space is managed by a second DynamicBlockGroupManager so that the next
turn's increment can be *preallocated adjacent* to the existing copy
(paper: "preallocates additional memory space for the next turn's swap out
increment ... improves memory continuity").

Invariant (tested property): a request never reuses a contaminated block —
``valid_tokens`` only counts the uncontaminated *prefix* of the copy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.block_group import DynamicBlockGroupManager, OutOfBlocksError


@dataclass
class CpuCopy:
    valid_tokens: int = 0          # uncontaminated prefix length (tokens)
    stored_tokens: int = 0         # tokens physically written to CPU
    prealloc_tokens: int = 0       # reserved-ahead space (adjacent)


class KVCacheReuseManager:
    def __init__(self, num_cpu_blocks: int, block_size_tokens: int = 16,
                 initial_group_blocks: int = 60, enabled: bool = True,
                 prealloc_blocks: int = 16):
        self.mgr = DynamicBlockGroupManager(
            num_cpu_blocks, block_size_tokens,
            initial_group_blocks=initial_group_blocks)
        self.block_size = block_size_tokens
        self.enabled = enabled
        self.prealloc_blocks = prealloc_blocks
        self.copies: Dict[int, CpuCopy] = {}
        # priority snapshot used to pick contamination victims
        self.priorities: Dict[int, float] = {}
        # live-priority fallback for rids never seen by update_priority
        # (the engine points this at scheduler.priority); without it an
        # unseen rid would default to 0.0 and become a preferential
        # contamination victim regardless of its true priority
        self.priority_fn: Optional[Callable[[int], float]] = None
        self.n_contaminations = 0

    # ------------------------------------------------------------------

    def update_priority(self, req_id: int, priority: float) -> None:
        self.priorities[req_id] = priority

    def _priority_of(self, req_id: int) -> float:
        p = self.priorities.get(req_id)
        if p is not None:
            return p
        if self.priority_fn is not None:
            return float(self.priority_fn(req_id))
        return 0.0

    def valid_tokens(self, req_id: int) -> int:
        c = self.copies.get(req_id)
        return c.valid_tokens if (c and self.enabled) else 0

    def plan_swap_out(self, req_id: int, total_tokens: int) -> int:
        """Tokens that actually need transfer (the increment)."""
        if not self.enabled:
            return total_tokens
        return max(0, total_tokens - self.valid_tokens(req_id))

    def record_swap_out(self, req_id: int, total_tokens: int,
                        requesting_priority: float = 0.0,
                        floor_tokens: int = 0
                        ) -> Tuple[int, List[Tuple[int, int]]]:
        """Allocate CPU space for the increment and mark the copy valid up
        to ``total_tokens``.  Returns (increment_tokens, cpu_runs) where
        cpu_runs are the contiguous CPU block runs written.

        ``floor_tokens``: positions ``[0, floor)`` are pinned GPU-resident
        (a shared prefix-cache prefix) and never transferred; the copy is
        considered valid from position 0 anyway so all block-index math
        stays unchanged — the CPU blocks below the floor are phantoms that
        are allocated but never written or read."""
        copy = self.copies.setdefault(req_id, CpuCopy())
        if not self.enabled:
            # baseline: the whole context is re-written every preemption
            # (same in-place CPU blocks — the allocation only grows)
            self._ensure_cpu_tokens(req_id, total_tokens, requesting_priority)
            copy.valid_tokens = total_tokens
            copy.stored_tokens = total_tokens
            return total_tokens, self.mgr.request_runs(req_id)
        if floor_tokens:
            f = min(floor_tokens, total_tokens)
            copy.valid_tokens = max(copy.valid_tokens, f)
            copy.stored_tokens = max(copy.stored_tokens, copy.valid_tokens)
        inc = max(0, total_tokens - copy.valid_tokens)
        if inc == 0:
            return 0, []
        self._ensure_cpu_tokens(req_id, total_tokens, requesting_priority)
        # allocation may have been refused (only higher-priority copies
        # left to contaminate): the valid prefix is capped by what is
        # physically stored on CPU.  The pinned floor stays valid even
        # when the phantom blocks below it were contaminated away.
        cap = self.mgr.request_tokens(req_id)
        new_valid = max(min(total_tokens, cap), copy.valid_tokens)
        inc = max(0, new_valid - copy.valid_tokens)
        copy.valid_tokens = new_valid
        copy.stored_tokens = new_valid
        # adjacent preallocation for the NEXT turn's increment
        try:
            self.mgr.allocate_tokens(req_id,
                                     self.prealloc_blocks * self.block_size)
            self.mgr.note_tokens(req_id, self.prealloc_blocks * self.block_size)
            copy.prealloc_tokens = self.prealloc_blocks * self.block_size
        except OutOfBlocksError:
            pass
        return inc, self.mgr.request_runs(req_id)

    def record_swap_in(self, req_id: int) -> int:
        """Swap-in reads the valid prefix; the CPU copy is RETAINED.
        Returns tokens transferred h2d."""
        return self.valid_tokens(req_id)

    def invalidate(self, req_id: int) -> None:
        """Failure containment (DESIGN.md §7): a failed d2h increment
        left the CPU copy's tail unwritten — nothing beyond what was
        previously valid can be trusted, and since the failed increment's
        extent within the allocation is unknown the whole copy is
        conservatively voided.  The ALLOCATION is kept (the request may
        still be live and swap out again later); only the trusted extent
        drops to zero, so ``valid_tokens`` never advertises bytes that
        never arrived."""
        c = self.copies.get(req_id)
        if c is not None:
            c.valid_tokens = 0
            c.stored_tokens = 0
            # nothing valid is stored, so nothing is "reserved ahead" of
            # it either: a stale reserve would make the next
            # record_swap_out under-report the adjacent preallocation and
            # a later contamination over-shrink the victim's valid prefix
            c.prealloc_tokens = 0

    def release(self, req_id: int) -> None:
        """Conversation finished: drop the copy."""
        self.mgr.release_request(req_id)
        self.copies.pop(req_id, None)
        self.priorities.pop(req_id, None)

    # ------------------------------------------------------------------
    # cross-replica migration (DESIGN.md §11)
    # ------------------------------------------------------------------

    def export_copy(self, req_id: int) -> Optional[Dict[str, object]]:
        """Metadata of one copy for migration to another replica's reuse
        manager: the trusted prefix extent plus the token-ordered CPU
        block ids backing it (the engine reads the actual bytes out of
        ``PagedPools.cpu`` — block ids are meaningless across pools).
        The local copy is NOT released here; the engine owns the
        exactly-once handoff."""
        c = self.copies.get(req_id)
        if c is None:
            return None
        return {"valid_tokens": c.valid_tokens,
                "block_ids": list(self.mgr.request_block_ids(req_id))}

    def import_copy(self, req_id: int, valid_tokens: int,
                    priority: float = 0.0) -> List[int]:
        """Install a migrated copy: allocate CPU space for the imported
        prefix (contaminating lower-priority copies if the pool is full,
        same as a local swap-out) and mark it valid up to what was
        actually allocated.  Returns the token-ordered CPU block ids the
        engine must write the migrated KV bytes into; the caller trims
        its write — and the advertised prefix — to the returned
        capacity."""
        if req_id in self.copies:
            raise ValueError(f"request {req_id} already has a CPU copy")
        copy = self.copies.setdefault(req_id, CpuCopy())
        if valid_tokens <= 0 or not self.enabled:
            return []
        self._ensure_cpu_tokens(req_id, valid_tokens, priority)
        cap = self.mgr.request_tokens(req_id)
        copy.valid_tokens = min(valid_tokens, cap)
        copy.stored_tokens = copy.valid_tokens
        self.priorities[req_id] = priority
        return list(self.mgr.request_block_ids(req_id))

    # ------------------------------------------------------------------
    # space management & contamination
    # ------------------------------------------------------------------

    def _ensure_cpu_tokens(self, req_id: int, total_tokens: int,
                           requesting_priority: float) -> None:
        """Grow the request's CPU allocation to ``total_tokens`` (both
        the reuse increment and the disabled-baseline rewrite only ever
        GROW — rewrites land in the same blocks), contaminating
        lower-priority copies when the pool is full."""
        copy = self.copies[req_id]
        have = self.mgr.request_tokens(req_id)
        need = total_tokens - have
        if need <= 0:
            # the increment fits inside already-reserved space: whatever
            # part of the preallocation it consumes is no longer
            # reserved-ahead (stale prealloc bookkeeping made
            # contamination over-shrink a victim's valid prefix)
            copy.prealloc_tokens = min(copy.prealloc_tokens,
                                       have - total_tokens)
            return
        while need > 0:
            try:
                self.mgr.allocate_tokens(req_id, need)
                self.mgr.note_tokens(req_id, need)
                if copy.prealloc_tokens:
                    copy.prealloc_tokens = 0   # consumed by growth
                return
            except OutOfBlocksError:
                if not self._contaminate_one(requesting_priority, req_id):
                    # cannot make space: copy is best-effort truncated —
                    # the fill consumes the whole reserve, so nothing
                    # stays preallocated-ahead
                    copy.prealloc_tokens = 0
                    return

    def _contaminate_one(self, requesting_priority: float,
                         requester: int) -> bool:
        """Reclaim CPU space from the lowest-priority other copy; shrink its
        valid prefix (tail-first eviction keeps the longest usable prefix)."""
        victims = [r for r in self.copies if r != requester
                   and self.mgr.request_tokens(r) > 0]
        if not victims:
            return False
        victim = min(victims, key=self._priority_of)
        if self._priority_of(victim) >= requesting_priority:
            # only strictly-lower-priority copies may be contaminated
            # (paper §2.2); an equal-priority victim would let two peers
            # ping-pong each other's prefixes away
            return False
        vcopy = self.copies[victim]
        # release the victim's LAST group (tail-first)
        if self.mgr.release_tail_group(victim) is None:
            return False
        remaining_cap = self.mgr.request_tokens(victim)
        vcopy.valid_tokens = min(vcopy.valid_tokens,
                                 max(0, remaining_cap - vcopy.prealloc_tokens))
        vcopy.stored_tokens = min(vcopy.stored_tokens, vcopy.valid_tokens)
        vcopy.prealloc_tokens = 0
        self.n_contaminations += 1
        return True

    # ------------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        g = self.mgr.granularity_stats()
        return {"cpu_copies": len(self.copies),
                "cpu_free_blocks": self.mgr.free_blocks(),
                "contaminations": self.n_contaminations,
                **{f"cpu_{k}": v for k, v in g.items()}}
