"""Engine invariant sanitizer — the robustness layer's tripwire
(DESIGN.md §7).

``check_engine_invariants(engine)`` cross-validates the four state
machines that must agree for the engine to be correct — scheduler
queues, GPU block pool, CPU reuse pool, swap-task lists and (real mode)
the decode runner's row maps — and raises a structured
``InvariantViolation`` carrying every violated clause plus a compact
state dump.  It is pure read-only inspection: safe to run every step
(``EngineConfig.check_invariants_every``), in CI chaos smokes, and from
property tests after every mutation.

Why a separate sanitizer when ``DynamicBlockGroupManager`` already has
``check_invariants``?  The allocator can be internally consistent while
the *cross-layer* state is corrupt — a released request still listed in
``running``, a runner row pointing at freed blocks, a swap task pinning
blocks of a dead request.  Containment bugs (this PR's subject) are
exactly cross-layer: a half-torn-down request passes every single-module
check and still leaks.

Invariant catalog (each clause is one numbered check below):
  Q1  queue/state coherence: each rid appears in exactly the queue its
      ``state`` names; queues are disjoint; every queued rid is live.
  B1  pool accounting: free + used group lengths tile [0, num_blocks)
      with no overlap (delegated to the allocator's own check).
  B2  GPU block ownership ⊆ live rids: no blocks held by finished /
      aborted requests.
  B3  token-capacity bounds: each live request's noted tokens fit its
      block capacity; a RUNNING request's ``context_tokens`` never
      exceeds its noted tokens.
  R1  reuse copies: ``valid_tokens <= stored_tokens`` and valid +
      prealloc fits the CPU allocation.
  R2  CPU pool accounting (allocator self-check).
  S1  incomplete ongoing swap-IN tasks' rids are live and SWAPPING_IN
      (sync and retired tasks excluded; the reverse is NOT an invariant:
      a task can complete a poll before its request promotes).
  S2  swap-task GPU block ids are within the pool range.
  D1  runner row maps partition: registered rows ∪ free rows is exactly
      the batch bucket; no row is both.
  D2  registered rows belong to live rids; freed rows point at the
      trash sentinel (empty host mirror).
  P1  prefill carry: every open runner prefill belongs to a live rid
      with ``prefill_remaining > 0``, and vice versa for real mode.
  C1  prefix-cache refcount conservation: every cached block's refcount
      equals the number of live/parked requests mapping it; every
      mapping belongs to a live or parked rid and is a root path.
  C2  prefix-cache block ownership/pinning: every tree node owns exactly
      its one block (single-block group under the node's negative owner
      rid); refcounts exist only on node blocks; no swap task ever
      references a cached (pinned) block.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.scheduler import ReqState


class InvariantViolation(AssertionError):
    """One or more engine invariants failed.  ``violations`` lists every
    failed clause; ``state_dump`` is a compact serializable snapshot for
    postmortems (queue contents, pool counters, task lists)."""

    def __init__(self, violations: List[str], state_dump: Dict):
        self.violations = violations
        self.state_dump = state_dump
        lines = "\n  - ".join(violations)
        super().__init__(
            f"{len(violations)} engine invariant(s) violated:\n  - {lines}\n"
            f"state: {state_dump}")


def _state_dump(eng) -> Dict:
    sched = eng.sched
    return {
        "t_us": eng.clock.now_us,
        "iteration": eng.metrics.iterations,
        "waiting": list(sched.waiting),
        "running": list(sched.running),
        "swapped": list(sched.swapped),
        "swapping_in": list(sched.swapping_in),
        "parked": sorted(eng.parked),
        "gpu_free_blocks": eng.gpu_mgr.free_blocks(),
        "gpu_used_blocks": eng.gpu_mgr.used_blocks(),
        "cpu_free_blocks": eng.reuse.mgr.free_blocks(),
        "ongoing_swap_in": [(t.req_id, t.n_blocks, t.done_at)
                            for t in eng.swap.ongoing_swap_in],
        "ongoing_swap_out": [(t.req_id, t.n_blocks, t.done_at)
                             for t in eng.swap.ongoing_swap_out],
    }


def check_engine_invariants(eng) -> None:
    """Validate the full cross-layer state of a ``ServingEngine``.
    Raises ``InvariantViolation`` listing EVERY failed clause (not just
    the first — a corruption postmortem needs the whole picture)."""
    v: List[str] = []
    sched = eng.sched
    live = set(sched.requests)

    # Q1: queue/state coherence ---------------------------------------
    queues = {ReqState.WAITING: sched.waiting,
              ReqState.RUNNING: sched.running,
              ReqState.SWAPPED: sched.swapped,
              ReqState.SWAPPING_IN: sched.swapping_in}
    seen: Dict[int, str] = {}
    for state, q in queues.items():
        for rid in q:
            if rid in seen:
                v.append(f"Q1: rid {rid} in both {seen[rid]} and "
                         f"{state.value} queues")
            seen[rid] = state.value
            if rid not in live:
                v.append(f"Q1: rid {rid} in {state.value} queue but not "
                         "a live request")
            elif sched.requests[rid].state is not state:
                v.append(f"Q1: rid {rid} in {state.value} queue but "
                         f"state={sched.requests[rid].state.value}")
    for rid, req in sched.requests.items():
        if req.state in queues and rid not in queues[req.state]:
            v.append(f"Q1: live rid {rid} state={req.state.value} missing "
                     "from its queue")

    # B1/B2/B3: GPU pool ----------------------------------------------
    try:
        eng.gpu_mgr.check_invariants()
    except AssertionError as e:
        v.append(f"B1: gpu pool accounting: {e}")
    for rid in list(eng.gpu_mgr.requests):
        # negative rids are engine-internal phantom owners (injected
        # allocation-pressure reserves), not requests
        if rid not in live and rid >= 0:
            v.append(f"B2: gpu blocks held by dead rid {rid}")
    prefix = getattr(eng, "prefix", None)
    for rid in live:
        cap = len(eng.gpu_mgr.request_block_ids(rid)) \
            * eng.config.block_size
        noted = eng.gpu_mgr.request_tokens(rid)
        if noted > cap:
            v.append(f"B3: rid {rid} noted {noted} tokens > block "
                     f"capacity {cap}")
        req = sched.requests[rid]
        # a mapped shared prefix is resident but not noted against the
        # request (its blocks belong to the tree's node owners)
        shared = prefix.shared_tokens(rid) if prefix is not None else 0
        if req.state is ReqState.RUNNING and req.prefill_remaining == 0 \
                and req.context_tokens > noted + shared:
            v.append(f"B3: running rid {rid} context_tokens="
                     f"{req.context_tokens} > noted tokens {noted} + "
                     f"shared {shared}")

    # R1/R2: reuse copies ---------------------------------------------
    try:
        eng.reuse.mgr.check_invariants()
    except AssertionError as e:
        v.append(f"R2: cpu pool accounting: {e}")
    for rid, copy in eng.reuse.copies.items():
        cap = eng.reuse.mgr.request_tokens(rid)
        if copy.valid_tokens > copy.stored_tokens:
            v.append(f"R1: rid {rid} reuse valid {copy.valid_tokens} > "
                     f"stored {copy.stored_tokens}")
        # a GPU-pinned shared prefix keeps valid_tokens at its floor even
        # when the phantom CPU blocks below it were contaminated away
        # (they are never read — see reuse.record_swap_out floor_tokens)
        floor = prefix.shared_tokens(rid) if prefix is not None else 0
        if copy.valid_tokens + copy.prealloc_tokens > cap \
                and copy.valid_tokens > floor:
            v.append(f"R1: rid {rid} reuse valid {copy.valid_tokens} + "
                     f"prealloc {copy.prealloc_tokens} > cpu capacity "
                     f"{cap}")

    # S1/S2: swap tasks ------------------------------------------------
    n_pool = eng.config.num_gpu_blocks
    swapping = set(sched.swapping_in)
    for t in eng.swap.ongoing_swap_in:
        if not t.is_completed(eng.clock.now_us) and not t.failed:
            if t.req_id not in live:
                v.append(f"S1: in-flight swap-in task for dead rid "
                         f"{t.req_id}")
            elif t.req_id not in swapping:
                v.append(f"S1: in-flight swap-in task for rid {t.req_id} "
                         f"not in SWAPPING_IN (state="
                         f"{sched.requests[t.req_id].state.value})")
    for t in eng.swap.ongoing_swap_in + eng.swap.ongoing_swap_out:
        bad = [b for b in t.gpu_blocks if not 0 <= b < n_pool]
        if bad:
            v.append(f"S2: swap task (rid {t.req_id}, {t.direction}) "
                     f"references out-of-pool gpu blocks {bad}")

    # C1/C2: prefix-cache refcounts / ownership / pinning -------------
    if prefix is not None:
        node_blocks = set()
        for node in prefix.iter_nodes():
            node_blocks.add(node.block)
            # every node owns exactly its one block: a single-block group
            # registered under the node's negative owner rid
            groups = eng.gpu_mgr.requests.get(node.owner)
            if groups is None or len(groups.groups) != 1:
                v.append(f"C2: prefix node owner {node.owner} holds "
                         f"{0 if groups is None else len(groups.groups)} "
                         "groups (want exactly 1)")
            else:
                g = groups.groups[0]
                if (g.start, g.length, g.used) != (node.block, 1, 1):
                    v.append(f"C2: prefix node owner {node.owner} group "
                             f"(start={g.start}, len={g.length}, "
                             f"used={g.used}) != block {node.block}")
        # refcount conservation: each cached block's refcount equals the
        # number of live/parked requests mapping it
        mapper_counts: Dict[int, int] = {}
        for rid, path in prefix.mappings().items():
            if rid not in live and rid not in eng.parked:
                v.append(f"C1: prefix mapping held by dead rid {rid}")
            prev = None
            for node in path:
                if node.parent is not prev:
                    v.append(f"C1: rid {rid} mapping is not a root path "
                             f"at block {node.block}")
                prev = node
                mapper_counts[node.block] = \
                    mapper_counts.get(node.block, 0) + 1
        for b in node_blocks | set(mapper_counts):
            have = eng.gpu_mgr.block_refcount(b)
            want = mapper_counts.get(b, 0)
            if have != want:
                v.append(f"C1: block {b} refcount {have} != mapper "
                         f"count {want}")
        for b in list(getattr(eng.gpu_mgr, "_block_refs", {})):
            if b not in node_blocks:
                v.append(f"C1: refcount on non-cached block {b}")
        # pinning: shared blocks never ride a swap task (the engine only
        # swaps the private suffix — this is the tripwire for it)
        for t in eng.swap.ongoing_swap_in + eng.swap.ongoing_swap_out:
            pinned = node_blocks.intersection(t.gpu_blocks)
            if pinned:
                v.append(f"C2: swap task (rid {t.req_id}, {t.direction}) "
                         f"touches pinned cached blocks {sorted(pinned)}")

    # D1/D2 + P1: runner row maps / prefill carry ---------------------
    if eng.runner is not None:
        for msg in eng.runner.invariant_report(live):
            v.append(msg)
        open_prefills = set(eng.runner._prefills)
        carrying = {rid for rid in live
                    if sched.requests[rid].prefill_remaining > 0}
        for rid in open_prefills - live:
            v.append(f"P1: runner prefill carry for dead rid {rid}")
        for rid in carrying - open_prefills:
            v.append(f"P1: rid {rid} has prefill_remaining="
                     f"{sched.requests[rid].prefill_remaining} but no "
                     "runner carry")
    else:
        for rid in live:
            req = sched.requests[rid]
            if req.prefill_remaining > 0 \
                    and req.state is not ReqState.RUNNING:
                v.append(f"P1: rid {rid} prefill_remaining="
                         f"{req.prefill_remaining} in state "
                         f"{req.state.value}")

    if v:
        raise InvariantViolation(v, _state_dump(eng))
