"""FastSwitch core — the paper's primary contribution.

Dynamic Block Group Manager (block_group), Multithreading Swap Manager
(swap_manager), KV Cache Reuse Mechanism (reuse), Priority Scheduler
(scheduler), the open-world serving core (serving: ``ServingEngine``
with the ``add_request/step/abort/continue_session`` API) and the
trace-replay client (engine: ``FastSwitchEngine``) that drives it.
"""
from repro.core.block_group import (  # noqa: F401
    BlockGroup,
    DynamicBlockGroupManager,
    OutOfBlocksError,
)
from repro.core.decode_runner import (  # noqa: F401
    DecodeRequestView,
    DecodeRunner,
)
from repro.core.engine import FastSwitchEngine  # noqa: F401
from repro.core.faults import (  # noqa: F401
    EngineDrainingError,
    EngineOverloadError,
    FaultInjector,
    FaultPlan,
    FatalSwapFault,
    InjectedFault,
    PermanentSwapFault,
    PoisonError,
    TransientSwapFault,
)
from repro.core.invariants import (  # noqa: F401
    InvariantViolation,
    check_engine_invariants,
)
from repro.core.request_api import (  # noqa: F401
    RequestEvent,
    RequestOutput,
    RequestSLOStats,
    SamplingParams,
    SLOSpec,
)
from repro.core.serving import EngineMetrics, ServingEngine  # noqa: F401
from repro.core.policies import (  # noqa: F401
    DBG_ONLY,
    DBG_REUSE,
    FASTSWITCH,
    POLICIES,
    VLLM_BASELINE,
    EngineConfig,
    EnginePolicy,
)
from repro.core.reuse import KVCacheReuseManager  # noqa: F401
from repro.core.scheduler import PriorityScheduler, Request, ReqState  # noqa: F401
from repro.core.swap_manager import MultithreadingSwapManager, SimClock  # noqa: F401
