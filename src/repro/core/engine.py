"""Trace-replay client of the open-world serving core.

``FastSwitchEngine`` used to BE the engine: it consumed a pre-sorted
conversation trace and ran the iteration loop itself, with arrivals and
turn wake-ups hardwired into ``step()``.  The engine core now lives in
``core/serving.py`` (``ServingEngine`` — vLLM-shaped
``add_request()/step() -> RequestOutput`` with runtime cancellation and
session continuation); this module keeps the old trace-driven interface
as a thin CLIENT of that API:

  * arrivals: conversations whose ``arrival_s`` has passed are submitted
    with ``add_request`` (real mode synthesizes the deterministic
    per-(conv, turn) prompt ids the engine used to make internally);
  * wake-ups: a finished turn with a successor parks its KV in the core
    (``retain_kv``) and sleeps client-side for ``think_time_s``; the
    wake-up is a ``continue_session`` follow-up through the KV-reuse
    path — exactly what an interactive user does;
  * idle time: the client passes its next known event (arrival or wake)
    as ``step(until_us=...)`` so the core's idle clock advances exactly
    as the pre-refactor engine's did (bit-exact replay parity).

Everything else — queues, swaps, metrics, the GPU pools — is the core's;
attribute access falls through to it, so existing callers (benchmarks,
tests) keep working unchanged.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.faults import EngineDrainingError, EngineOverloadError
from repro.core.request_api import RequestOutput, SamplingParams, SLOSpec
from repro.core.serving import EngineMetrics, ServingEngine  # noqa: F401
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import Conversation, prompt_for_turn


class _Wake:
    """One parked conversation awaiting its next-turn wake-up."""
    __slots__ = ("wake_s", "conv", "turn_idx")

    def __init__(self, wake_s: float, conv: Conversation, turn_idx: int):
        self.wake_s, self.conv, self.turn_idx = wake_s, conv, turn_idx


class FastSwitchEngine:
    """Replay a conversation trace through the serving API.

    Same constructor and surface as the pre-refactor engine; ``run()``
    drives ``ServingEngine.add_request / continue_session / step`` and
    is bit-exact with the pre-refactor replay (test_decode_consistency).
    """

    def __init__(self, config, conversations: List[Conversation],
                 trace: Optional[PriorityTrace] = None,
                 model_bundle: Optional[dict] = None,
                 slo: Optional[SLOSpec] = None):
        # keep_events=False: a closed-world replay never reads the event
        # stream, and a 300k-iteration benchmark run would accumulate an
        # unbounded RequestEvent list for nothing
        self.core = ServingEngine(config, trace=trace,
                                  model_bundle=model_bundle,
                                  keep_events=False)
        self.pending = sorted(conversations, key=lambda c: c.arrival_s)
        self.sleeping: List[_Wake] = []
        self.default_slo = slo
        self._convs = {c.conv_id: c for c in conversations}
        self.dropped_submits = 0

    # attribute fall-through: the core owns all engine state (sched,
    # gpu_mgr, swap, reuse, clock, metrics, pools, runner, config, ...)
    def __getattr__(self, name):
        return getattr(object.__getattribute__(self, "core"), name)

    # ------------------------------------------------------------------

    def _prompt_for(self, conv: Conversation, turn_idx: int):
        """Real mode: the deterministic per-(conv, turn) synthetic prompt
        ids (same stream the engine used to generate internally — replay
        parity).  Sim mode: just the token count."""
        vocab = None if self.core.pools is None \
            else self.core.model_bundle["cfg"].vocab_size
        return prompt_for_turn(conv, turn_idx, vocab)

    def _submit(self, conv: Conversation, turn_idx: int) -> None:
        turn = conv.turns[turn_idx]
        sp = SamplingParams(max_tokens=turn.response_tokens)
        retain = turn_idx + 1 < len(conv.turns)
        try:
            if turn_idx == 0:
                self.core.add_request(self._prompt_for(conv, turn_idx), sp,
                                      slo=self.default_slo,
                                      handle=conv.conv_id, retain_kv=retain)
            else:
                self.core.continue_session(conv.conv_id,
                                           self._prompt_for(conv, turn_idx),
                                           sp, slo=self.default_slo,
                                           retain_kv=retain)
        except (EngineOverloadError, EngineDrainingError):
            # closed-world replay with admission control on: the trace
            # has no retry loop, so a refused submit is simply dropped
            # (counted by the core's ``rejected`` metric).  The default
            # config has no waiting bound, so replays are unaffected.
            self.dropped_submits += 1

    def _next_event_us(self) -> Optional[float]:
        events = [w.wake_s * 1e6 for w in self.sleeping]
        if self.pending:
            events.append(self.pending[0].arrival_s * 1e6)
        return min(events) if events else None

    # ------------------------------------------------------------------

    def step(self) -> List[RequestOutput]:
        core = self.core
        # arrivals, then wake-ups — same order the engine ran them when
        # they lived inside step()
        now_s = core.clock.now_us / 1e6
        while self.pending and self.pending[0].arrival_s <= now_s:
            self._submit(self.pending.pop(0), 0)
        for w in list(self.sleeping):
            if w.wake_s <= now_s:
                self.sleeping.remove(w)
                self._submit(w.conv, w.turn_idx)
        outs = core.step(until_us=self._next_event_us())
        for out in outs:
            if out.finished and out.finish_reason == "length":
                conv = self._convs[out.handle]
                if out.turn + 1 < len(conv.turns):
                    # think time counts from the FINISH instant
                    # (out.t_us), not the step's end — a later request's
                    # sync swap stall in the same iteration must not
                    # postpone this wake-up (replay parity)
                    self.sleeping.append(_Wake(
                        out.t_us / 1e6 + conv.think_time_s,
                        conv, out.turn + 1))
        return outs

    def done(self) -> bool:
        return (not self.pending and not self.sleeping
                and not self.core.sched.requests)

    def run(self, max_iterations: int = 2_000_000) -> EngineMetrics:
        it = 0
        while not self.done() and it < max_iterations:
            self.step()
            it += 1
        if self.core.runner is not None:
            self.core.runner.flush()
        self.core.swap.shutdown()
        return self.core.metrics
