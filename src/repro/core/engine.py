"""FastSwitch serving engine — the iteration loop tying together the
priority scheduler, Dynamic Block Group Manager, Multithreading Swap
Manager and KV Cache Reuse Mechanism (paper Fig. 5).

Two execution modes share the full control plane:
  * ``sim``  — token bookkeeping only; latency from the hardware cost
               model.  Used for thousand-conversation benchmark traces
               (the paper's own priority traces are offline simulations).
  * ``real`` — a reduced model decodes actual tokens against the paged
               GPU pool through the Pallas paged-attention kernel, and
               swaps move real KV bytes between pools.

Per-iteration flow (Algorithm 1 embedded):
  1. poll completed async swap-ins -> running
  2. admit arrivals / wake sleeping conversations
  3. priority-trace step; on update: rebalance queues (preempt / swap-in /
     admit) under the GPU block budget
  4. opportunistic admission of waiting requests
  5. prefill newly admitted requests (prefill-with-prefix accounting)
  6. decode one token for the running batch (+ block allocation with
     conflict resolution)
  7. finish turns: retain KV copy per policy; schedule next turn
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cache.paged import PagedPools, PoolSpec
from repro.core.block_group import (DynamicBlockGroupManager,
                                    OutOfBlocksError)
from repro.core.decode_runner import DecodeRequestView, DecodeRunner
from repro.core.policies import EngineConfig
from repro.kernels.block_copy import runs_to_indices, split_runs, trim_runs
from repro.core.reuse import KVCacheReuseManager
from repro.core.scheduler import PriorityScheduler, Request, ReqState
from repro.core.swap_manager import MultithreadingSwapManager, SimClock
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import Conversation
from repro.io.cost_model import IterationCostModel


@dataclass
class EngineMetrics:
    ttfts_us: List[float] = field(default_factory=list)
    tbts_us: List[float] = field(default_factory=list)
    total_tokens: int = 0
    total_time_us: float = 0.0
    iterations: int = 0
    prefills: int = 0
    preemptions: int = 0
    swap_in_count: int = 0
    swap_out_count: int = 0
    ctx_switch_stall_us: float = 0.0
    callstack_wall_s: float = 0.0      # REAL wall time of the control plane
    # (t_end_us, batch, t_iter_us, prefills_in_iter, stall_so_far_us)
    iter_records: List[Tuple[float, int, float, int, float]] = \
        field(default_factory=list)

    def percentile(self, xs: Sequence[float], p: float) -> float:
        if not xs:
            return 0.0
        return float(np.percentile(np.asarray(xs), p))

    def summary(self) -> Dict[str, float]:
        return {
            "p50_ttft_ms": self.percentile(self.ttfts_us, 50) / 1e3,
            "p95_ttft_ms": self.percentile(self.ttfts_us, 95) / 1e3,
            "p99_ttft_ms": self.percentile(self.ttfts_us, 99) / 1e3,
            "p999_ttft_ms": self.percentile(self.ttfts_us, 99.9) / 1e3,
            "p99_tbt_ms": self.percentile(self.tbts_us, 99) / 1e3,
            "p999_tbt_ms": self.percentile(self.tbts_us, 99.9) / 1e3,
            "throughput_tok_s": (self.total_tokens
                                 / max(self.total_time_us / 1e6, 1e-9)),
            "total_tokens": self.total_tokens,
            "iterations": self.iterations,
            "preemptions": self.preemptions,
            "ctx_switch_stall_us": self.ctx_switch_stall_us,
            "callstack_wall_s": self.callstack_wall_s,
        }


class FastSwitchEngine:
    def __init__(self, config: EngineConfig, conversations: List[Conversation],
                 trace: Optional[PriorityTrace] = None,
                 model_bundle: Optional[dict] = None):
        self.config = config
        pol = config.policy
        self.clock = SimClock()
        self.metrics = EngineMetrics()

        group_blocks = pol.initial_group_blocks if pol.use_block_groups else 1
        self.gpu_mgr = DynamicBlockGroupManager(
            config.num_gpu_blocks - 1,     # last block reserved as trash
            config.block_size, initial_group_blocks=group_blocks,
            seed=config.seed)
        self.reuse = KVCacheReuseManager(
            config.num_cpu_blocks, config.block_size,
            initial_group_blocks=group_blocks, enabled=pol.use_reuse,
            prealloc_blocks=pol.prealloc_blocks if pol.use_reuse else 0)

        self.model_bundle = model_bundle
        self.pools: Optional[PagedPools] = None
        if config.mode == "real":
            assert model_bundle is not None, "real mode needs a model bundle"
            cfg = model_bundle["cfg"]
            spec = PoolSpec.from_config(cfg, config.num_gpu_blocks,
                                        config.num_cpu_blocks,
                                        config.block_size)
            self.pools = PagedPools(spec, with_data=True)
            self.block_bytes = spec.block_bytes()
            from repro.models.params import count_params_analytic
            model_params = count_params_analytic(cfg)
            kv_tok = spec.block_bytes() // spec.block_size
        else:
            # sim mode: modelled LLaMA-8B-like footprint
            self.block_bytes = config.kv_bytes_per_token * config.block_size
            model_params = config.model_params
            kv_tok = config.kv_bytes_per_token
        # beyond-paper wire compression (int8 KV on the PCIe/DMA link)
        self.block_bytes = self.block_bytes * pol.swap_wire_bytes_per_elem // 2

        self.swap = MultithreadingSwapManager(
            config.hardware, self.pools,
            async_enabled=pol.use_async_swap,
            adaptive=pol.adaptive_async,
            r_info_window=config.r_info_window)
        self.iter_cost = IterationCostModel(
            config.hardware, model_params=model_params,
            kv_bytes_per_token=kv_tok)

        self.trace = trace or PriorityTrace()
        self.sched = PriorityScheduler(self.trace, config.max_running)
        self.pending = sorted(conversations, key=lambda c: c.arrival_s)
        self.sleeping: List[Request] = []
        self._token_hist_by_conv: Dict[int, List[int]] = {}
        # per-request CPU block-id mirror for the data plane
        self._trash_block = config.num_gpu_blocks - 1
        # batch-bucket-aware admission: iterations the engine has held a
        # boundary against under-pressure growth (bounded, see
        # _admission_target)
        self._bucket_hold = 0
        self._bucket_hold_iter = -1
        # device-resident decode hot path (real mode): persistent block
        # tables, bucketed shapes, donated pool — see DESIGN.md §3
        self.runner: Optional[DecodeRunner] = None
        if self.pools is not None:
            self.runner = DecodeRunner(
                model_bundle, block_size=config.block_size,
                trash_block=self._trash_block,
                temperature=config.temperature, top_k=config.top_k,
                top_p=config.top_p, seed=config.seed)

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------

    def _budget_tokens(self) -> int:
        return self.gpu_mgr.num_blocks * self.config.block_size

    def _req(self, rid: int) -> Request:
        return self.sched.requests[rid]

    def _transfer_runs(self, runs: List[Tuple[int, int]]
                       ) -> List[Tuple[int, int]]:
        """The vLLM baseline issues ONE memcpy per block regardless of
        physical adjacency (Fig. 3a); block-group policies transfer whole
        contiguous runs (Fig. 3b); the Llumnix baseline merges per-block
        copies through a small staging buffer (bounded granularity, one
        transfer per buffer-full — paper §2.2)."""
        pol = self.config.policy
        if pol.use_block_groups:
            return runs
        blocks = runs_to_indices(runs)
        mb = max(1, pol.merge_buffer_blocks)
        if mb == 1:
            return [(b, 1) for b in blocks]
        # staging-buffer merge: one op per <=mb blocks (the buffer copy
        # itself runs at HBM speed — negligible next to the PCIe leg)
        return [(blocks[i], min(mb, len(blocks) - i))
                for i in range(0, len(blocks), mb)]

    def _runs_for_tokens(self, rid: int, t0: int, t1: int
                         ) -> List[Tuple[int, int]]:
        """Contiguous GPU block runs covering tokens [t0, t1)."""
        if t1 <= t0:
            return []
        bs = self.config.block_size
        ids = self.gpu_mgr.request_block_ids(rid)
        b0, b1 = t0 // bs, (t1 + bs - 1) // bs
        blocks = ids[b0:b1]
        runs: List[Tuple[int, int]] = []
        for b in blocks:
            if runs and runs[-1][0] + runs[-1][1] == b:
                runs[-1] = (runs[-1][0], runs[-1][1] + 1)
            else:
                runs.append((b, 1))
        return runs

    # ------------------------------------------------------------------
    # swap operations
    # ------------------------------------------------------------------

    def _swap_out(self, rid: int, keep_copy: bool,
                  last_slot_written: bool = False) -> None:
        """Preempt: move KV to CPU.  With reuse, only the increment beyond
        the valid CPU copy is transferred.  In recompute mode the KV is
        simply dropped (resumption re-prefills the whole context)."""
        req = self._req(rid)
        if self.config.policy.preemption_mode == "recompute":
            self.gpu_mgr.release_request(rid)
            req.resume_tokens = req.context_tokens
            self.metrics.preemptions += 1
            return
        # Only context_tokens - 1 positions hold written KV: the last
        # slot's K/V is produced by the NEXT decode step (which consumes
        # the pending token as input).  Claiming it would freeze garbage
        # into the CPU copy — once the reuse increment pointer moves past
        # that slot it is never re-copied, and a later swap-in would
        # restore the garbage into attended positions (token corruption
        # whenever a preemption lands on a block-aligned context).  The
        # now-valid slot is picked up by the NEXT increment instead.
        # ``last_slot_written``: a mid-prefill abort has NO pending decode
        # token — every context_tokens position holds chunk-inserted KV,
        # so the whole processed prefix is claimable.
        total = req.context_tokens if last_slot_written \
            else max(req.context_tokens - 1, 0)
        self.reuse.update_priority(rid, self.sched.priority(rid))
        inc, _cpu_runs = self.reuse.record_swap_out(
            rid, total, requesting_priority=self.sched.priority(rid))
        valid_before = total - inc
        gpu_runs = self._runs_for_tokens(rid, valid_before, total)
        gpu_blocks = runs_to_indices(gpu_runs)
        if gpu_runs:
            # conflicts: blocks we're about to read may be swap-in targets
            self.swap.resolve_conflicts(self.clock, gpu_blocks)
            bs = self.config.block_size
            cpu_ids = self.reuse.mgr.request_block_ids(rid)[
                valid_before // bs:(total + bs - 1) // bs] \
                if self.pools is not None else []
            asynchronous = self.swap.decide_async(
                len(self.sched.running), sum(n for _, n in gpu_runs),
                runs=self._transfer_runs(gpu_runs),
                block_bytes=self.block_bytes, h2d=False,
                now_us=self.clock.now_us)
            self._dispatch_swap(rid, "out", gpu_runs, cpu_ids, asynchronous)
            self.metrics.swap_out_count += 1
        self.gpu_mgr.release_request(rid)
        self.metrics.preemptions += 1

    def _swap_in(self, rid: int) -> bool:
        """Bring a swapped request's KV back to GPU.  Returns True if the
        request is immediately RUNNING (sync), False if in flight."""
        req = self._req(rid)
        tokens = req.context_tokens
        try:
            self.gpu_mgr.allocate_tokens(rid, tokens)
            self.gpu_mgr.note_tokens(rid, tokens)
        except OutOfBlocksError:
            # roll back the PARTIAL allocation (allocate_tokens acquires
            # groups incrementally) or the blocks leak into a deadlock
            self.gpu_mgr.release_request(rid)
            return False                     # stays swapped; retry later
        # TOKEN-ordered runs (not request_runs, which sorts by physical
        # start): the data plane pairs these positionally with the
        # token-ordered CPU block list, and a fragmented allocation can
        # hand out groups with descending starts — sorted runs would
        # restore every block into the wrong slot of the block table
        gpu_runs = self._runs_for_tokens(rid, 0, tokens)
        gpu_blocks = runs_to_indices(gpu_runs)
        # the newly allocated target blocks may still be the SOURCE of an
        # in-flight swap-out — synchronize before overwriting them
        self.swap.resolve_conflicts(self.clock, gpu_blocks)
        self.reuse.record_swap_in(rid)
        bs = self.config.block_size
        nblk = (tokens + bs - 1) // bs
        cpu_ids = self.reuse.mgr.request_block_ids(rid)[:nblk] \
            if self.pools is not None else []
        asynchronous = self.swap.decide_async(
            len(self.sched.running), sum(n for _, n in gpu_runs),
            runs=self._transfer_runs(gpu_runs),
            block_bytes=self.block_bytes, h2d=True, now_us=self.clock.now_us)
        self._dispatch_swap(rid, "in", gpu_runs, cpu_ids, asynchronous)
        self.metrics.swap_in_count += 1
        if asynchronous:
            self.sched.move(rid, ReqState.SWAPPING_IN)
            return False
        self.sched.move(rid, ReqState.RUNNING)
        return True

    def _dispatch_swap(self, rid: int, direction: str,
                       gpu_runs: List[Tuple[int, int]], cpu_ids: List[int],
                       asynchronous: bool) -> None:
        """Dispatch one logical swap as ``swap_chunk_blocks``-sized chunk
        tasks (DESIGN.md §4.3).  Each chunk is its own task on the
        simulated stream with its own GPU-block conflict set and its own
        data-plane future, so (a) the pool lock is released between chunk
        copies — decode steps interleave with a long transfer — and (b) a
        fine-grained conflict sync waits only for the chunk whose blocks
        actually overlap, not the whole swap.  The data plane runs the
        staged run-coalesced path (``PagedPools.copy_*_staged``); a chunk
        whose CPU backing is shorter than its GPU runs (contamination
        capped the reuse copy) trims the copy to the backed prefix, and
        the sim cost still accounts the full dispatched runs.

        Data ordering: a copy touching CPU blocks that a still-queued
        swap-out writes (its own request's increment, or a contamination
        reallocation of a victim's blocks) must wait for that write;
        worker execution is not FIFO, so each chunk carries the
        overlapping out-futures as explicit dependencies (awaited before
        the pool lock — see ``MultithreadingSwapManager.data_deps``)."""
        pools = self.pools
        pos = 0
        for runs_c in split_runs(gpu_runs, self.config.swap_chunk_blocks):
            cnt = sum(n for _, n in runs_c)
            copy_fn = None
            cpu_c: List[int] = []
            deps: List = []
            if pools is not None:
                cpu_c = cpu_ids[pos:pos + cnt]
                if cpu_c:
                    deps = self.swap.data_deps(cpu_c)
                    data_runs = trim_runs(runs_c, len(cpu_c))
                    if direction == "out":
                        copy_fn = (lambda r=data_runs, c=cpu_c:
                                   pools.copy_out_staged(r, c))
                    else:
                        copy_fn = (lambda r=data_runs, c=cpu_c:
                                   pools.copy_in_staged(c, r))
            pos += cnt
            self.swap.dispatch(self.clock, rid, direction,
                               self._transfer_runs(runs_c), self.block_bytes,
                               runs_to_indices(runs_c),
                               asynchronous=asynchronous, copy_fn=copy_fn,
                               copy_deps=deps, cpu_blocks=cpu_c)

    # ------------------------------------------------------------------
    # admission / prefill
    # ------------------------------------------------------------------

    def _preempt(self, rid: int) -> None:
        """Swap mode: KV to CPU, request -> SWAPPED.  Recompute mode: KV
        dropped, request -> WAITING for re-prefill.  A real-mode request
        caught MID chunked prefill has no pending decode token to resume
        from — it aborts to WAITING instead (the processed prefix is kept
        as a CPU reuse copy; re-admission opens a fresh prefill)."""
        req = self._req(rid)
        if self.pools is not None and req.prefill_remaining > 0:
            self._abort_chunked_prefill(rid)
            return
        self._swap_out(rid, keep_copy=True)
        if self.config.policy.preemption_mode == "recompute":
            self.sched.move(rid, ReqState.WAITING)
        else:
            self.sched.move(rid, ReqState.SWAPPED)

    def _abort_chunked_prefill(self, rid: int) -> None:
        """Mid-prefill preemption (real mode, DESIGN.md §5): drop the
        runner's carry buffers, keep the processed prefix as a CPU reuse
        copy (``context_tokens`` counts exactly the chunk-inserted
        tokens), roll back the turn's prompt extension and return the
        request to WAITING — the next ``_admit`` regenerates the
        deterministic prompt and opens a fresh chunked prefill, reusing
        the saved prefix up to ``prefix_tokens``."""
        req = self._req(rid)
        self.runner.prefill_abort(rid)
        self._swap_out(rid, keep_copy=True, last_slot_written=True)
        req.prefill_remaining = 0
        req.resume_tokens = 0          # recompute mode: fresh _admit, not
        #                                a resume (no first token emitted)
        n_prompt = req.current_turn().prompt_tokens
        del req.token_history[len(req.token_history) - n_prompt:]
        self.sched.move(rid, ReqState.WAITING)

    def _admit(self, rid: int) -> bool:
        """WAITING -> RUNNING via prefill (+prefix swap-in if CPU copy).
        Recompute-preempted requests re-prefill their whole context."""
        req = self._req(rid)
        if req.resume_tokens:
            return self._admit_resume(rid)
        turn = req.current_turn()
        reused = min(self.reuse.valid_tokens(rid), req.prefix_tokens)
        new_ctx = req.prefix_tokens + turn.prompt_tokens
        try:
            self.gpu_mgr.allocate_tokens(rid, new_ctx)
            self.gpu_mgr.note_tokens(rid, new_ctx)
        except OutOfBlocksError:
            self.gpu_mgr.release_request(rid)   # roll back partial alloc
            return False
        gpu_runs = self.gpu_mgr.request_runs(rid)
        gpu_blocks = runs_to_indices(gpu_runs)
        self.swap.resolve_conflicts(self.clock, gpu_blocks)
        # prefix-with-prefill: reused tokens are swapped in, the rest computed
        if reused > 0:
            bs = self.config.block_size
            n_reused_blocks = (reused + bs - 1) // bs
            runs_in = self._runs_for_tokens(rid, 0, reused)  # token order
            cpu_ids = self.reuse.mgr.request_block_ids(rid)[:n_reused_blocks] \
                if self.pools is not None else []
            self._dispatch_swap(rid, "in", runs_in, cpu_ids,
                                asynchronous=False)  # prefill needs it NOW
        # prefill compute for the non-reused tokens
        new_tokens = new_ctx - reused
        chunk = self.config.policy.chunked_prefill_tokens
        if chunk and self.pools is None and new_tokens > chunk:
            # BEYOND-PAPER (Sarathi-style): spread the prefill over
            # iterations so long prompts stop stalling the decode batch
            req.prefill_remaining = new_tokens
            req.context_tokens = new_ctx
            self.metrics.prefills += 1
            self.sched.move(rid, ReqState.RUNNING)
            return True
        if chunk and self.pools is not None \
                and new_ctx - (reused - reused % self.config.block_size) \
                > chunk:
            # REAL-mode chunked prefill (DESIGN.md §5): the runner opens a
            # chunked-prefill state machine; step 5 advances it one
            # bucketed chunk per iteration between decode steps, so the
            # long prompt never freezes the decode batch.  The carry is
            # seeded from the restored ``reused`` prefix (bit-identical
            # to recomputing it), so the gate — like the compute and the
            # billing — covers only the tail beyond the block-aligned
            # reused prefix.
            self._begin_real_chunked_prefill(req, reused)
            self.metrics.prefills += 1
            self.sched.move(rid, ReqState.RUNNING)
            return True
        t_prefill = self.iter_cost.prefill_us(max(new_tokens, 1))
        self.clock.advance(t_prefill)
        req.context_tokens = new_ctx
        self.metrics.prefills += 1
        if self.pools is not None:
            self._real_prefill(req)
        self.sched.move(rid, ReqState.RUNNING)
        self._emit_first_token(rid)
        return True

    def _allocate_token_slot(self, rid: int, skipped: Optional[set] = None
                             ) -> bool:
        """Allocate the one-token block slot the next decode will write
        KV into: on OutOfBlocksError preempt a victim (recorded in
        ``skipped`` so the caller drops it from this iteration's decode
        set) and retry; synchronize swap conflicts on any block the
        allocation acquired — it may be a just-freed block an async d2h
        copy is still reading (torn victim KV otherwise).  Returns False
        when the pool stays full."""
        before = set(self.gpu_mgr.request_block_ids(rid))
        try:
            self.gpu_mgr.allocate_tokens(rid, 1)
            self.gpu_mgr.note_tokens(rid, 1)
        except OutOfBlocksError:
            victim = self._find_victim(exclude={rid})
            if victim is None:
                return False
            self._preempt(victim)
            if skipped is not None:
                skipped.add(victim)
            try:
                self.gpu_mgr.allocate_tokens(rid, 1)
                self.gpu_mgr.note_tokens(rid, 1)
            except OutOfBlocksError:
                return False
        grown = [b for b in self.gpu_mgr.request_block_ids(rid)
                 if b not in before]
        if grown:
            self.swap.resolve_conflicts(self.clock, grown)
        return True

    def _emit_first_token(self, rid: int) -> None:
        """The prompt's last position produced the response's first token."""
        req = self._req(rid)
        req.context_tokens += 1
        if not self._allocate_token_slot(rid):
            # a rebalance-time admission landed on a pool that stays full
            # even after the victim fallback: bounce THIS request; the
            # emitted token stays in its history and the resumption path
            # (swap-in / re-prefill) allocates its next-token slot
            req.finish_token(self.clock.now_us)
            self.metrics.ttfts_us.append(req.ttfts_us[-1])
            self.metrics.total_tokens += 1
            self._preempt(rid)
            return
        req.finish_token(self.clock.now_us)
        self.metrics.ttfts_us.append(req.ttfts_us[-1])
        self.metrics.total_tokens += 1

    def _admit_resume(self, rid: int) -> bool:
        """Re-admit a recompute-preempted request: re-prefill the full
        context (the recomputation cost the paper's swap mode avoids)."""
        req = self._req(rid)
        ctx = req.resume_tokens
        try:
            self.gpu_mgr.allocate_tokens(rid, ctx)
            self.gpu_mgr.note_tokens(rid, ctx)
        except OutOfBlocksError:
            self.gpu_mgr.release_request(rid)   # roll back partial alloc
            return False
        gpu_blocks = self.gpu_mgr.request_block_ids(rid)
        self.swap.resolve_conflicts(self.clock, gpu_blocks)
        self.clock.advance(self.iter_cost.prefill_us(max(ctx, 1)))
        self.metrics.prefills += 1
        if self.pools is not None:
            # recompute: regenerate KV for the already-known history
            self._real_reprefill(req)
        req.resume_tokens = 0
        self.sched.move(rid, ReqState.RUNNING)
        return True

    def _real_reprefill(self, req: Request) -> None:
        """Recompute-preemption resume: the runner regenerates KV for the
        already-known history (all but the last token — its K/V is written
        by the next decode step, which consumes hist[-1] as input) and
        inserts it through its persistent block tables."""
        view = DecodeRequestView(req.rid,
                                 self.gpu_mgr.request_block_ids(req.rid),
                                 req.token_history)
        # KV compute runs OUTSIDE the pool lock (it never touches the
        # pool); only the scatter + rebind serialize with swap copies
        staged = self.runner.prefill_compute(view, emit_first=False)
        with self.swap._pool_lock:
            self.pools.gpu = self.runner.prefill_insert(
                view, self.pools.gpu, staged)

    # ------------------------------------------------------------------
    # real-model data plane
    # ------------------------------------------------------------------

    def _extend_prompt(self, req: Request) -> DecodeRequestView:
        """Synthesize the turn's prompt (deterministic per (conv, turn))
        into the token history and build the runner view for its prefill."""
        cfg = self.model_bundle["cfg"]
        rid = req.rid
        hist = req.token_history
        self.runner.flush()          # history must be current before extend
        turn = req.current_turn()
        rng = np.random.RandomState((rid * 1009 + req.turn_idx) % (2 ** 31))
        prompt = rng.randint(1, cfg.vocab_size,
                             size=turn.prompt_tokens).tolist()
        hist.extend(prompt)
        return DecodeRequestView(rid, self.gpu_mgr.request_block_ids(rid),
                                 hist)

    def _real_prefill(self, req: Request) -> None:
        """Runner-managed prefill: synthesize the turn's prompt, then the
        runner computes KV, inserts it through its persistent block tables
        (device-side scatter — no host KV round-trip) and emits the first
        response token (device-side sampling; greedy at temperature 0)."""
        view = self._extend_prompt(req)
        # KV compute + first-token draw run OUTSIDE the pool lock; only
        # the scatter + rebind serialize with swap copies
        staged = self.runner.prefill_compute(view, emit_first=True)
        with self.swap._pool_lock:
            self.pools.gpu = self.runner.prefill_insert(
                view, self.pools.gpu, staged)

    def _begin_real_chunked_prefill(self, req: Request,
                                    reused: int) -> None:
        """Open the runner's chunked-prefill state machine for a newly
        admitted request (DESIGN.md §5).  The carry is seeded from the
        ``reused`` prefix the admission just restored into the pool, so
        only the non-reused tail is computed AND billed — matching the
        sim-mode chunked accounting (the prefix's transfer cost was
        already charged by the synchronous swap-in).  ``context_tokens``
        tracks the tokens whose KV is resident and claimable (seeded
        prefix + chunk inserts), so a mid-prefill preemption swaps out
        exactly the processed prefix; ``prefill_remaining`` counts the
        tokens left to compute — step 5 advances one chunk per
        iteration."""
        view = self._extend_prompt(req)
        with self.swap._pool_lock:      # the carry seed reads the pool
            req.prefill_remaining = self.runner.prefill_begin(
                view, emit_first=True, reused_tokens=reused,
                pool=self.pools.gpu)
        req.context_tokens = len(req.token_history) - req.prefill_remaining

    def _real_prefill_chunk(self, rid: int) -> int:
        """Advance one request's in-flight chunked prefill by one chunk:
        compute OUTSIDE the pool lock (the forward touches no pool
        state), insert the chunk's KV under it, and on the final chunk
        emit the first token.  Non-final chunks are trimmed to block-size
        multiples so every insert stays block-aligned.  Returns the chunk
        token count (charged to the sim clock by the caller)."""
        req = self._req(rid)
        bs = self.config.block_size
        n = min(self.config.policy.chunked_prefill_tokens,
                req.prefill_remaining)
        if n < req.prefill_remaining:
            n -= n % bs
            if n == 0:                 # chunk smaller than one block
                n = min(bs, req.prefill_remaining)
        staged = self.runner.prefill_chunk_compute(rid, n)
        with self.swap._pool_lock:
            self.pools.gpu = self.runner.prefill_chunk_insert(
                rid, self.pools.gpu, staged)
        req.prefill_remaining -= n
        req.context_tokens += n
        if req.prefill_remaining == 0:
            self.runner.prefill_finish(rid)
            self._emit_first_token(rid)
        return n

    def _real_decode(self, rids: List[int]) -> None:
        """Batched paged decode through the device-resident runner: only
        changed block-table rows are uploaded, the pool is donated, and
        the next-token host sync is deferred to the next iteration's
        decode (overlapping this step with the next control plane)."""
        views = [DecodeRequestView(r, self.gpu_mgr.request_block_ids(r),
                                   self._req(r).token_history)
                 for r in rids]
        with self.swap._pool_lock:
            self.pools.gpu = self.runner.decode(views, self.pools.gpu)

    # ------------------------------------------------------------------
    # the iteration
    # ------------------------------------------------------------------

    def step(self) -> None:
        t_wall0 = time.perf_counter()
        m = self.metrics
        bs = self.config.block_size
        prefills_before = m.prefills

        # Step 1: completed async swap-ins -> running.  A swap-in may
        # consist of several chunk tasks, and a fine-grained conflict sync
        # (resolve_conflicts) can retire tasks between polls; a request is
        # resident — promote it — exactly when NO in-flight swap-in task
        # remains for it (it would otherwise be stranded in SWAPPING_IN).
        self.swap.poll_completed(self.clock)
        if self.sched.swapping_in:
            ongoing = {t.req_id for t in self.swap.ongoing_swap_in}
            for rid in list(self.sched.swapping_in):
                if rid not in ongoing:
                    self.sched.move(rid, ReqState.RUNNING)

        # Step 2: arrivals & wake-ups
        now_s = self.clock.now_us / 1e6
        while self.pending and self.pending[0].arrival_s <= now_s:
            conv = self.pending.pop(0)
            req = Request(conv=conv)
            req.begin_turn(self.clock.now_us)
            self.sched.add_request(req)
        for req in list(self.sleeping):
            if req.next_event_s <= now_s:
                self.sleeping.remove(req)
                req.turn_idx += 1
                req.begin_turn(self.clock.now_us)
                self.sched.add_request(req)

        # Safeguard: a request whose working set exceeds the whole GPU pool
        # can never be served — fail it instead of deadlocking the queue.
        budget = self._budget_tokens()
        for rid in list(self.sched.waiting):
            req = self._req(rid)
            need = max(req.target_tokens,
                       req.prefix_tokens + req.current_turn().prompt_tokens
                       + bs)
            if need > budget:
                import warnings
                warnings.warn(f"request {rid} needs {need} tokens "
                              f"> pool budget {budget}; dropping")
                self.sched.waiting.remove(rid)
                req.state = ReqState.DONE
                self.reuse.release(rid)
                del self.sched.requests[rid]

        # Step 3: priority update -> rebalance
        updated = self.sched.step_trace()
        if updated:
            desired = self.sched.desired_running(
                self._budget_tokens(), bs,
                batch_bucket=(self.runner.batch_bucket
                              if self.runner is not None else 0))
            to_preempt, to_swap_in, to_admit = \
                self.sched.classify_rebalance(desired)
            for rid in to_preempt:
                self._preempt(rid)
            for rid in to_swap_in:
                self._swap_in(rid)
            for rid in to_admit:
                self._admit(rid)

        # Step 4: opportunistic admission (space permitting), capped at
        # the batch-bucket-aware target instead of max_running outright
        for rid in sorted(list(self.sched.waiting),
                          key=self.sched.priority, reverse=True):
            free_tok = self.gpu_mgr.free_blocks() * bs
            req = self._req(rid)
            need = req.prefix_tokens + req.current_turn().prompt_tokens + bs
            if need > free_tok \
                    or len(self.sched.running) + len(self.sched.swapping_in) \
                    >= self._admission_target():
                break
            self._admit(rid)
        for rid in list(self.sched.swapped):
            if len(self.sched.running) + len(self.sched.swapping_in) \
                    >= self._admission_target():
                break
            free_tok = self.gpu_mgr.free_blocks() * bs
            if self._req(rid).context_tokens + bs > free_tok:
                break
            self._swap_in(rid)

        # Step 5: decode one token for the running batch.  Requests with
        # an in-flight chunked prefill advance their prefill instead of
        # decoding (one chunk per iteration, piggybacked on the batch).
        rids = [r for r in self.sched.running
                if self._req(r).prefill_remaining == 0]
        prefilling = [r for r in self.sched.running
                      if self._req(r).prefill_remaining > 0]
        chunk_tokens = 0
        if prefilling:
            # at most ONE prompt chunk per iteration (highest priority
            # first) interleaved with the decode batch — the Sarathi-style
            # fairness lever bounding tail TBT during admission bursts
            chunk = self.config.policy.chunked_prefill_tokens
            rid_p = max(prefilling, key=self.sched.priority)
            reqp = self._req(rid_p)
            if self.pools is not None:
                chunk_tokens = self._real_prefill_chunk(rid_p)
            else:
                chunk_tokens = min(chunk, reqp.prefill_remaining)
                reqp.prefill_remaining -= chunk_tokens
                if reqp.prefill_remaining == 0:
                    self._emit_first_token(rid_p)
        if rids or prefilling:
            # block allocation for the new token (conflict-checked in
            # _allocate_token_slot).  Iterate over a SNAPSHOT and track a
            # ``skipped`` set: a victim preempted from inside the batch
            # must not shift the iteration (the old in-place
            # ``rids.remove`` silently skipped the next request's
            # allocation while still decoding and crediting it), and a
            # request whose allocation failed must sit this iteration out
            # entirely — decoding it anyway would advance
            # ``context_tokens`` past its block table (desync).
            skipped: set = set()
            for rid in list(rids):
                if rid in skipped or rid not in self.sched.running:
                    continue       # preempted as a victim earlier this loop
                if not self._allocate_token_slot(rid, skipped):
                    skipped.add(rid)           # retry next iteration
            decode_rids = [r for r in rids if r not in skipped
                           and r in self.sched.running]
            if decode_rids and self.pools is not None:
                self._real_decode(decode_rids)
            total_ctx = sum(self._req(r).context_tokens for r in decode_rids)
            t_iter = self.iter_cost.decode_iter_us(len(decode_rids),
                                                   total_ctx)
            if chunk_tokens:
                t_iter += self.iter_cost.prefill_us(chunk_tokens) \
                    - self.iter_cost.hw.iter_overhead_us
            if not decode_rids and not chunk_tokens:
                # everyone was skipped (pool exhausted, no victim): charge
                # the iteration overhead so the sim clock still advances
                t_iter = self.iter_cost.hw.iter_overhead_us
            if decode_rids:
                # feed the adaptive swap profiler the overlap window one
                # decode iteration offers (decide_async cost model)
                self.swap.note_decode_iter(t_iter)
            self.clock.advance(t_iter)
            for rid in decode_rids:
                req = self._req(rid)
                req.context_tokens += 1
                req.finish_token(self.clock.now_us)
                m.total_tokens += 1
                if req.tbts_us:
                    m.tbts_us.append(req.tbts_us[-1])
                if req.turn_done():
                    self._finish_turn(rid)
            m.iter_records.append((self.clock.now_us, len(decode_rids),
                                   t_iter, m.prefills - prefills_before,
                                   self.swap.total_stall_us))
        else:
            # idle: advance to the next event
            self._advance_idle()

        m.iterations += 1
        m.total_time_us = self.clock.now_us
        m.ctx_switch_stall_us = self.swap.total_stall_us
        m.callstack_wall_s += time.perf_counter() - t_wall0

    def _admission_target(self) -> int:
        """Batch-bucket-aware admission cap (real mode).  The decode step
        executes the next pow2 batch regardless of occupancy, so filling
        the compiled bucket is FREE (padded rows already run) while
        spilling a boundary doubles the padded batch and compiles a new
        variant.  Admission therefore targets the current bucket and only
        crosses a boundary when the candidates would fill at least half
        of the next bucket's new rows — with a bounded hold (16
        iterations) so a lone straggler is never starved; the priority
        rebalance path is never gated.  Sim mode — and a cold runner with
        no compiled variant to protect yet — keeps the plain
        ``max_running`` cap."""
        cap = self.config.max_running
        if self.runner is None or self.runner.batch_bucket == 0:
            return cap
        cur = len(self.sched.running) + len(self.sched.swapping_in)
        bucket = self.runner.batch_bucket
        while bucket < cur:
            bucket *= 2
        if cur < min(bucket, cap):
            self._bucket_hold = 0       # not at a boundary: no hold episode
            return min(bucket, cap)
        waiting = len(self.sched.waiting) + len(self.sched.swapped)
        if waiting == 0:
            self._bucket_hold = 0       # episode ended without crossing
            return min(bucket, cap)
        if waiting >= max(1, bucket // 2) or self._bucket_hold >= 16:
            self._bucket_hold = 0
            return min(bucket * 2, cap)
        if self.metrics.iterations != self._bucket_hold_iter:
            # count the hold once per engine iteration, not per call
            self._bucket_hold += 1
            self._bucket_hold_iter = self.metrics.iterations
        return min(bucket, cap)

    def _find_victim(self, exclude) -> Optional[int]:
        victims = self.sched.victims_for_space(exclude)
        return victims[0] if victims else None

    def _finish_turn(self, rid: int) -> None:
        req = self._req(rid)
        if self.runner is not None:
            self.runner.flush()      # materialize the turn's last tokens
        if req.token_history:
            self._token_hist_by_conv[rid] = list(req.token_history)
        # retain the KV copy for the next turn (reuse mechanism); baseline
        # swaps the whole context out; recompute mode just frees
        self._swap_out(rid, keep_copy=True)
        req.resume_tokens = 0       # the next turn is a fresh prefill
        for q in (self.sched.waiting, self.sched.running,
                  self.sched.swapped, self.sched.swapping_in):
            if rid in q:
                q.remove(rid)
        if req.turn_idx + 1 < len(req.conv.turns):
            req.state = ReqState.SLEEPING
            req.next_event_s = self.clock.now_us / 1e6 + req.conv.think_time_s
            self.sleeping.append(req)
            del self.sched.requests[rid]
        else:
            req.state = ReqState.DONE
            self.reuse.release(rid)
            del self.sched.requests[rid]

    def _advance_idle(self) -> None:
        events = []
        if self.pending:
            events.append(self.pending[0].arrival_s * 1e6)
        events.extend(r.next_event_s * 1e6 for r in self.sleeping)
        events.extend(t.done_at for t in self.swap.ongoing_swap_in)
        if events:
            self.clock.advance_to(max(min(events), self.clock.now_us + 100.0))
        else:
            self.clock.advance(1000.0)

    # ------------------------------------------------------------------

    def done(self) -> bool:
        return (not self.pending and not self.sleeping
                and not self.sched.requests)

    def run(self, max_iterations: int = 2_000_000) -> EngineMetrics:
        it = 0
        while not self.done() and it < max_iterations:
            self.step()
            it += 1
        if self.runner is not None:
            self.runner.flush()
        self.swap.shutdown()
        return self.metrics
