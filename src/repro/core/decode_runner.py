"""Device-resident decode runner — the real-mode token hot path.

The engine's original decode loop paid three per-token costs that dwarf
the swap overheads FastSwitch optimizes: (1) ``paged_decode_step``
recompiled whenever the longest running request crossed a page boundary
(the block-table width was the exact max page count), (2) the whole KV
pool was copied every step because the jitted step returned it without
buffer donation, and (3) every iteration rebuilt block tables in Python,
re-uploaded them, and blocked on a device->host sync to pull each next
token out with ``int(nxt[i])``.

The DecodeRunner keeps the entire per-step decode state ON DEVICE and
fixed-shape (DESIGN.md §3):

  * **Shape bucketing** — the block-table width (pages) and the batch
    dimension are rounded up to powers of two with high-water retention,
    so a context that grows across P page boundaries triggers
    O(log2(P)) compilations instead of O(P).
  * **Persistent block tables** — a (B_bucket, pages_bucket) int32 array
    lives on device; each step only the rows whose block lists changed
    since the last step are scattered in (typically one row per bs
    tokens per request).  Context lengths and last-token ids advance on
    device inside the jitted step (``active`` mask), so steady state
    uploads nothing at all.
  * **Pool donation** — ``paged_decode_step_device`` donates pool,
    context and token arrays; the per-layer KV write is in-place.
  * **Deferred host sync** — the next-token array is NOT pulled to the
    host at dispatch.  It is materialized lazily (``flush``) at the
    start of the NEXT decode — after the engine's control plane for that
    iteration has already run — so scheduling overlaps the in-flight
    device step.  Anyone reading ``token_history`` must flush first.

The runner is also the single owner of the rest of the real-mode token
pipeline (DESIGN.md §3.5/§3.6):

  * **Runner-managed prefill insertion** — ``prefill()`` computes KV for
    a (re-)admitted request and scatters it into the donated pool
    through the block table with a jitted, shape-bucketed insert
    (``kernels.ops.insert_prefill``), then registers the row directly in
    the persistent device tables; the engine no longer round-trips
    prefill KV through the host (``PagedPools.write_tokens``).
  * **Chunked prefill state machine** (DESIGN.md §5) — ``prefill_begin /
    prefill_chunk_compute / prefill_chunk_insert / prefill_finish /
    prefill_abort``: long prompts are processed as pow2-bucketed,
    position-masked chunks (``kernels.ops.prefill_chunk``) whose KV is
    carried chunk to chunk on device and inserted block-aligned into the
    pool, so the engine can interleave decode iterations between chunks
    and prompt-length variety compiles O(log^2) prefill variants instead
    of one per length.  The whole-prompt ``prefill()`` path is the same
    machinery run as a single chunk — one bit-exact forward for both.
  * **Device-side sampling** — temperature/top-k/top-p sampling is fused
    into the decode step with a per-row on-device array of base PRNG
    keys; the step folds the position in, so the random stream is a pure
    function of (seed, rid, position).  The parameters ride a PER-ROW
    traced (B, 3) array maintained with the row state, so every request
    carries its own ``SamplingParams`` while greedy (temperature 0,
    bit-exact argmax) and sampled rows share one compiled variant per
    bucket and the deferred sync stays one token array per step.
  * **Mesh sharding** (DESIGN.md §9) — with a ``mesh`` the decode step,
    chunked prefill and fused sampler run tensor-parallel under
    ``shard_map``: q/k/v projections and the KV pool are head-sharded
    over ``model``, head outputs are all-gathered (a pure concat) ahead
    of the replicated output projection, and no float reduction ever
    crosses shards — token streams are bit-identical to single-device.

Row-occupancy invariant: a row is either *registered* (owned by a live
request, block table = its pages) or *freed* (block table = trash page,
context 0) — freed rows still execute the step, but their masked output
is discarded and their KV write lands in the reserved trash block.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.paged import (paged_decode_step_device,
                                paged_decode_step_device_sharded,
                                sample_tokens)


def next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


@dataclass
class DecodeRequestView:
    """What the runner needs to know about one decoding request."""
    rid: int
    block_ids: Sequence[int]       # GPU pages covering context+1 tokens
    token_history: List[int]       # shared list; flush() appends to it
    # per-request (temperature, top_k, top_p); None = runner default.
    # Rides the row state as one (3,) f32 slot of the (B, 3) sampling
    # array the fused sampler traces — any mix of per-request configs
    # shares ONE compiled variant per bucket.
    sampling: Optional[Tuple[float, float, float]] = None


@dataclass
class RunnerStats:
    steps: int = 0
    rebuilds: int = 0              # bucket growth -> full state re-upload
    rows_updated: int = 0          # incremental row scatters
    host_syncs: int = 0            # deferred next-token materializations
    prefills: int = 0              # runner-managed prefill insertions
    prefill_chunks: int = 0        # chunked-prefill forward launches
    prefill_tokens: int = 0        # prompt tokens actually forwarded
    prefill_aborts: int = 0        # mid-prefill preemptions


@dataclass
class _PrefillState:
    """One in-flight (possibly chunked) prefill (DESIGN.md §5).

    ``k_carry``/``v_carry`` hold the per-layer K/V computed so far —
    pow2-bucketed device buffers the chunk forward appends to and
    attends against; ``pos`` counts real tokens processed.  The state
    lives across engine iterations while decode steps interleave with
    the remaining chunks, and is dropped whole on a mid-prefill
    preemption (``prefill_abort``)."""
    view: DecodeRequestView
    toks: List[int]                # tokens to process (hist, or hist[:-1])
    emit_first: bool
    pos: int = 0                   # real tokens already processed
    k_carry: Optional[jnp.ndarray] = None     # (L, S_pad, Hkv, D)
    v_carry: Optional[jnp.ndarray] = None
    last_logits: Optional[jnp.ndarray] = None # (V,) at the last real pos
    emitted: bool = False          # first token already appended to hist


class DecodeRunner:
    def __init__(self, model_bundle: dict, *, block_size: int,
                 trash_block: int, min_pages_bucket: int = 1,
                 temperature: float = 0.0, top_k: int = 0,
                 top_p: float = 1.0, seed: int = 0, mesh=None):
        self.mb = model_bundle
        self.bs = block_size
        self.trash = trash_block
        self._min_pages = max(1, min_pages_bucket)
        # ``mesh``: a ("data", "model") jax mesh — the decode / prefill
        # steps then run tensor-parallel under ``shard_map`` with the
        # q/k/v projections and the KV pool head-sharded (DESIGN.md §9).
        # A 1-device mesh is normalized to None: the single-device step
        # is byte-identical to the pre-mesh code and the sharded path
        # degrades to it bit-exactly.
        if mesh is not None and mesh.size == 1:
            mesh = None
        self._mesh = mesh
        self._params = model_bundle["params"]
        if mesh is not None:
            from repro.models.paged import shardable_heads
            from repro.models.sharding import serving_param_pspecs
            cfg = model_bundle["cfg"]
            assert shardable_heads(cfg, mesh.shape["model"]), (
                cfg.name, dict(mesh.shape))
            self._params = jax.tree.map(
                lambda x, s: jax.device_put(
                    x, jax.sharding.NamedSharding(mesh, s)),
                self._params, serving_param_pspecs(self._params))
        # sampling config: the runner-wide default row of the per-row
        # (B, 3) [temperature, top_k, top_p] array the fused sampler
        # traces (values never force a compile — the shape follows the
        # bucket), + the base PRNG key the per-row keys fold from
        self._default_sampling = np.asarray(
            [temperature, float(top_k), top_p], np.float32)
        self._base_key = jax.random.PRNGKey(seed)
        # bucket high-water marks (never shrink: shrinking would thrash
        # the jit cache for no memory win at these sizes)
        self._pages_bucket = 0
        self._batch_bucket = 0
        # host mirrors of device state
        self._rows: Dict[int, int] = {}               # rid -> row
        self._row_blocks: List[Tuple[int, ...]] = []  # what device bt holds
        self._row_ctx: List[int] = []
        self._free: List[int] = []
        # device state
        self._bt = None                               # (B, P) int32
        self._ctx = None                              # (B,) int32
        self._tok = None                              # (B,) int32
        self._keys = None                             # (B, 2) uint32
        self._active = None                           # (B,) bool
        self._sampling = None                         # (B, 3) f32
        self._active_rows: frozenset = frozenset()
        # deferred next-token sync: ([(row, token_history)], device array)
        self._pending: Optional[Tuple[list, jnp.ndarray]] = None
        # in-flight chunked prefills, keyed by rid (DESIGN.md §5)
        self._prefills: Dict[int, _PrefillState] = {}
        self.stats = RunnerStats()

    @property
    def batch_bucket(self) -> int:
        """Compiled decode-batch bucket (0 before the first step).  The
        step always executes this many padded rows, so admitting requests
        up to the bucket adds NO compile and NO step cost — the engine's
        batch-bucket-aware admission targets exactly this size."""
        return self._batch_bucket

    def _row_key(self, rid: int, salt: int = 0):
        """Position-independent per-row base PRNG key, folded from
        (seed, rid).  The decode step folds the position in on device
        (``sample_tokens``), so the sampled stream is a pure function of
        (seed, rid, position) — reproducible under any preemption order,
        row re-registration or bucket rebuild.  ``salt`` separates the
        prefill first-token draw from the row's decode stream."""
        k = jax.random.fold_in(self._base_key, rid)
        return jax.random.fold_in(k, salt) if salt else k

    def _row_sampling(self, view: DecodeRequestView) -> np.ndarray:
        """The (3,) f32 [temperature, top_k, top_p] row for ``view`` —
        its per-request override, or the runner default."""
        if view.sampling is None:
            return self._default_sampling
        t, k, p = view.sampling
        return np.asarray([t, float(k), p], np.float32)

    # ------------------------------------------------------------------
    # deferred host sync
    # ------------------------------------------------------------------

    def flush(self) -> None:
        """Materialize the previous step's next tokens into the request
        histories.  One device sync for the whole batch; by the time the
        engine calls this (start of the next decode, or before reading a
        history) the device step has usually already finished."""
        if self._pending is None:
            return
        rows_hist, nxt = self._pending
        self._pending = None
        # fslint: disable=FS003(the deferred-sync point: ONE batched d2h pull per step, by design)
        vals = np.asarray(nxt)
        self.stats.host_syncs += 1
        for row, hist in rows_hist:
            hist.append(int(vals[row]))

    # ------------------------------------------------------------------
    # device-state maintenance
    # ------------------------------------------------------------------

    def _rebuild(self, views: List[DecodeRequestView],
                 pages_bucket: int, batch_bucket: int) -> None:
        """Bucket grew: re-upload the whole (small) control state."""
        self._pages_bucket, self._batch_bucket = pages_bucket, batch_bucket
        self.stats.rebuilds += 1
        self._rows = {}
        self._row_blocks = [()] * batch_bucket
        self._row_ctx = [0] * batch_bucket
        bt = np.full((batch_bucket, pages_bucket), self.trash, np.int32)
        ctx = np.zeros((batch_bucket,), np.int32)
        tok = np.zeros((batch_bucket,), np.int32)
        keys = np.zeros((batch_bucket, 2), np.uint32)
        act = np.zeros((batch_bucket,), bool)
        smp = np.zeros((batch_bucket, 3), np.float32)
        for i, v in enumerate(views):
            ids = tuple(v.block_ids)
            self._rows[v.rid] = i
            self._row_blocks[i] = ids
            self._row_ctx[i] = len(v.token_history) - 1
            bt[i, :len(ids)] = ids
            ctx[i] = self._row_ctx[i]
            tok[i] = v.token_history[-1]
            # fslint: disable=FS003(rebuild-time row-key pull, a few bytes outside the steady-state step)
            keys[i] = np.asarray(self._row_key(v.rid))
            act[i] = True
            smp[i] = self._row_sampling(v)
        self._free = list(range(len(views), batch_bucket))
        self._bt = jnp.asarray(bt)
        self._ctx = jnp.asarray(ctx)
        self._tok = jnp.asarray(tok)
        self._keys = jnp.asarray(keys)
        self._active = jnp.asarray(act)
        self._sampling = jnp.asarray(smp)
        self._active_rows = frozenset(range(len(views)))

    def _scatter_rows(self, pending: Dict[int, Tuple[Tuple[int, ...],
                                                     Optional[int],
                                                     Optional[int],
                                                     Optional[np.ndarray],
                                                     Optional[np.ndarray]]]
                      ) -> None:
        """One batched device scatter for the changed rows.  Entry value
        is (block_ids, ctx, tok, key_data, sampling_row); the trailing
        four are None for rows whose device counters are already right
        (block-table-only write)."""
        if not pending:
            return
        pb = self._pages_bucket
        entries = [(r, ids, c, t, kd, sr)
                   for r, (ids, c, t, kd, sr) in sorted(pending.items())]
        rows = jnp.asarray([e[0] for e in entries], jnp.int32)
        btrows = np.full((len(entries), pb), self.trash, np.int32)
        for j, (_, ids, _, _, _, _) in enumerate(entries):
            btrows[j, :len(ids)] = ids
        self._bt = self._bt.at[rows].set(jnp.asarray(btrows))
        full = [(r, c, t, kd, sr)
                for r, _, c, t, kd, sr in entries if c is not None]
        if full:
            frows = jnp.asarray([f[0] for f in full], jnp.int32)
            self._ctx = self._ctx.at[frows].set(
                jnp.asarray([f[1] for f in full], jnp.int32))
            self._tok = self._tok.at[frows].set(
                jnp.asarray([f[2] for f in full], jnp.int32))
            self._keys = self._keys.at[frows].set(
                jnp.asarray(np.stack([np.asarray(f[3], np.uint32)
                                      for f in full])))
            self._sampling = self._sampling.at[frows].set(
                jnp.asarray(np.stack([np.asarray(f[4], np.float32)
                                      for f in full])))
        self.stats.rows_updated += len(entries)

    def _update_rows(self, views: List[DecodeRequestView]) -> None:
        """Incremental path: scatter in only the rows that changed."""
        current = {v.rid for v in views}
        # per-row pending write, keyed by row so a free + immediate
        # re-register of the same row collapses to one write (duplicate
        # scatter indices have undefined order)
        pending: Dict[int, Tuple[Tuple[int, ...], Optional[int],
                                 Optional[int], Optional[np.ndarray],
                                 Optional[np.ndarray]]] = {}
        zero_key = np.zeros((2,), np.uint32)
        zero_smp = np.zeros((3,), np.float32)
        for rid in [r for r in self._rows if r not in current]:
            row = self._rows.pop(rid)
            self._row_blocks[row] = ()
            self._row_ctx[row] = 0
            self._free.append(row)
            # point at trash, mask off
            pending[row] = ((), 0, 0, zero_key, zero_smp)
        for v in views:
            ids = tuple(v.block_ids)
            row = self._rows.get(v.rid)
            hist_ctx = len(v.token_history) - 1
            if row is None:
                row = self._free.pop()
                self._rows[v.rid] = row
                self._row_blocks[row] = ids
                self._row_ctx[row] = hist_ctx
                pending[row] = (ids, hist_ctx, v.token_history[-1],
                                self._row_key(v.rid),
                                self._row_sampling(v))
            elif self._row_ctx[row] != hist_ctx:
                # context jumped outside the decode loop: a turn-boundary
                # re-admission extends the history and rewrites prefill KV
                # without the rid ever leaving the batch (no decode ran
                # while it slept, so the row was never freed) — the device
                # ctx/token are stale; full re-register
                self._row_blocks[row] = ids
                self._row_ctx[row] = hist_ctx
                pending[row] = (ids, hist_ctx, v.token_history[-1],
                                self._row_key(v.rid),
                                self._row_sampling(v))
            elif ids != self._row_blocks[row]:
                self._row_blocks[row] = ids       # page-boundary growth or
                pending[row] = (ids, None, None, None, None)  # swap-in move
        self._scatter_rows(pending)
        active = frozenset(self._rows[v.rid] for v in views)
        if active != self._active_rows:
            self._active_rows = active
            act = np.zeros((self._batch_bucket,), bool)
            act[list(active)] = True
            self._active = jnp.asarray(act)

    # ------------------------------------------------------------------
    # the step
    # ------------------------------------------------------------------

    def decode(self, views: List[DecodeRequestView], pool):
        """Launch one decode step for ``views`` against ``pool``.

        Returns the new pool (the passed-in pool is DONATED — the caller
        must rebind its reference).  Next tokens stay on device until
        ``flush()``."""
        assert views, "decode() needs at least one request"
        self.flush()
        need_pages = max(len(v.block_ids) for v in views)
        pages_bucket = max(self._pages_bucket,
                           next_pow2(max(need_pages, self._min_pages)))
        batch_bucket = max(self._batch_bucket, next_pow2(len(views)))
        if (pages_bucket != self._pages_bucket
                or batch_bucket != self._batch_bucket):
            self._rebuild(views, pages_bucket, batch_bucket)
        else:
            self._update_rows(views)

        if self._mesh is None:
            nxt, pool, self._ctx, self._tok = \
                paged_decode_step_device(
                    self._params, pool, self._bt, self._ctx, self._tok,
                    self._active, self._keys, self._sampling,
                    cfg=self.mb["cfg"])
        else:
            nxt, pool, self._ctx, self._tok = \
                paged_decode_step_device_sharded(
                    self._params, pool, self._bt, self._ctx, self._tok,
                    self._active, self._keys, self._sampling,
                    cfg=self.mb["cfg"], mesh=self._mesh)
        self._pending = ([(self._rows[v.rid], v.token_history)
                          for v in views], nxt)
        for v in views:
            self._row_ctx[self._rows[v.rid]] += 1
        self.stats.steps += 1
        return pool

    # ------------------------------------------------------------------
    # runner-managed prefill insertion
    # ------------------------------------------------------------------

    def _register(self, view: DecodeRequestView) -> bool:
        """Write a (re-)admitted request's row state straight through the
        persistent device tables so the next decode uploads nothing.
        Returns False when the current buckets can't hold the row — the
        next decode()'s rebuild picks it up from the views instead."""
        if self._bt is None:
            return False
        ids = tuple(view.block_ids)
        hist_ctx = len(view.token_history) - 1
        if len(ids) > self._pages_bucket:
            return False
        row = self._rows.get(view.rid)
        if row is None:
            if not self._free:
                return False
            row = self._free.pop()
            self._rows[view.rid] = row
        self._row_blocks[row] = ids
        self._row_ctx[row] = hist_ctx
        self._scatter_rows({row: (ids, hist_ctx, view.token_history[-1],
                                  self._row_key(view.rid),
                                  self._row_sampling(view))})
        return True

    def release(self, rid: int) -> None:
        """Free an aborted request's row immediately: block table back to
        the trash sentinel, context zeroed, row masked off and returned
        to the free list.  Any open chunked-prefill state is dropped too.
        (The lazy path — ``_update_rows`` at the next decode — only frees
        rows for rids absent from the views; an abort must not wait for a
        decode that may never come.)"""
        self._prefills.pop(rid, None)
        row = self._rows.pop(rid, None)
        if row is None:
            return
        self._row_blocks[row] = ()
        self._row_ctx[row] = 0
        self._free.append(row)
        self._scatter_rows({row: ((), 0, 0, np.zeros((2,), np.uint32),
                                  np.zeros((3,), np.float32))})
        if row in self._active_rows:
            self._active_rows = self._active_rows - {row}
            act = np.zeros((self._batch_bucket,), bool)
            act[list(self._active_rows)] = True
            self._active = jnp.asarray(act)

    def invariant_report(self, live_rids) -> List[str]:
        """Row-map validation for the engine sanitizer (DESIGN.md §7).
        Returns violation strings (empty = clean): registered rows and
        free rows must partition the batch bucket exactly; registered
        rows belong to live rids; freed rows carry the trash sentinel's
        empty host mirror; active rows are registered."""
        v: List[str] = []
        if self._batch_bucket == 0:
            if self._rows or self._free:
                v.append("D1: runner rows exist before first bucket build")
            return v
        reg = set(self._rows.values())
        free = set(self._free)
        if len(self._free) != len(free):
            v.append(f"D1: duplicate rows in free list {self._free}")
        if len(reg) != len(self._rows):
            v.append(f"D1: two rids share a runner row {self._rows}")
        if reg & free:
            v.append(f"D1: rows both registered and free: {reg & free}")
        if reg | free != set(range(self._batch_bucket)):
            v.append(f"D1: rows {reg | free} do not partition bucket "
                     f"{self._batch_bucket}")
        live = set(live_rids)
        for rid, row in self._rows.items():
            if rid not in live:
                v.append(f"D2: runner row {row} registered to dead rid "
                         f"{rid}")
        for row in free:
            if self._row_blocks[row] != () or self._row_ctx[row] != 0:
                v.append(f"D2: freed row {row} still carries blocks="
                         f"{self._row_blocks[row]} ctx={self._row_ctx[row]}")
        for row in self._active_rows:
            if row not in reg:
                v.append(f"D2: active row {row} not registered")
        return v

    # -- chunked prefill state machine (DESIGN.md §5) -------------------

    def prefill_begin(self, view: DecodeRequestView, *,
                      emit_first: bool, reused_tokens: int = 0,
                      pool=None) -> int:
        """Open a (possibly chunked) prefill for ``view``: the runner
        will compute KV for the view's history (all of it with
        ``emit_first`` — a fresh turn; all but the pending last token on
        a recompute re-prefill) chunk by chunk through the bucketed
        position-masked forward.

        ``reused_tokens`` > 0 with a ``pool``: the first
        ``reused_tokens`` positions' KV is already RESIDENT in the pool
        (the reuse mechanism's restored prefix) — the carry is seeded
        from it (``ops.seed_prefill_carry``, bit-identical to
        recomputing) and chunking starts at the block-aligned floor of
        ``reused_tokens``, so re-admissions neither recompute nor
        re-bill the prefix.  The caller must hold the pool lock (the
        seed gather reads the pool).

        Returns the token count left TO PROCESS
        (``prefill_chunk_compute`` consumes it)."""
        assert self.bs & (self.bs - 1) == 0, \
            f"chunked prefill needs a pow2 block size, got {self.bs}"
        self.flush()              # history must be current before reading
        hist = view.token_history
        toks = hist if emit_first else hist[:-1]
        start = 0
        k_c = v_c = None
        if reused_tokens > 0 and pool is not None:
            start = min(reused_tokens - reused_tokens % self.bs,
                        len(toks) - 1)      # always >= 1 token to process
            start = max(start - start % self.bs, 0)
            if start > 0:
                k_c, v_c = ops.seed_prefill_carry(
                    pool, view.block_ids, start, trash=self.trash)
        self._prefills[view.rid] = _PrefillState(
            view=view, toks=list(toks), emit_first=emit_first, pos=start,
            k_carry=k_c, v_carry=v_c)
        return len(toks) - start

    def prefill_pending(self, rid: int) -> int:
        """Tokens the open prefill for ``rid`` has left to process."""
        st = self._prefills[rid]
        return len(st.toks) - st.pos

    def prefill_chunk_compute(self, rid: int, n_tokens: int) -> Optional[Tuple]:
        """Compute KV for the next ``n_tokens`` of the open prefill: one
        bucketed chunk forward attending the carry buffers (bit-exact
        with the monolithic path — see ``models.paged.prefill_kv_chunk``).
        Non-final chunks must be block-size multiples so every chunk's
        pool insert stays block-aligned.  Touches NO pool state, so the
        engine runs it OUTSIDE the pool lock.  Returns the staged
        (k, v, blocks) for ``prefill_chunk_insert``."""
        st = self._prefills[rid]
        if n_tokens <= 0:
            return None
        bs = self.bs
        assert st.pos % bs == 0, \
            f"chunk start {st.pos} not block-aligned (bs={bs})"
        assert st.pos + n_tokens <= len(st.toks), (st.pos, n_tokens)
        chunk = st.toks[st.pos:st.pos + n_tokens]
        st.last_logits, st.k_carry, st.v_carry, k_c, v_c = \
            ops.prefill_chunk(self._params, chunk, st.k_carry,
                              st.v_carry, st.pos, cfg=self.mb["cfg"],
                              block_size=bs, mesh=self._mesh)
        c_pad = k_c.shape[1]
        n_pages = -(-n_tokens // bs)
        blocks = np.full((c_pad // bs,), self.trash, np.int32)
        b0 = st.pos // bs
        blocks[:n_pages] = list(st.view.block_ids)[b0:b0 + n_pages]
        st.pos += n_tokens
        self.stats.prefill_chunks += 1
        self.stats.prefill_tokens += n_tokens
        return k_c, v_c, blocks

    def prefill_chunk_insert(self, rid: int, pool, staged):
        """Scatter one staged chunk into the DONATED pool through the
        block table (jitted, shape-bucketed — the existing staged insert
        path).  Run under the pool lock; the caller must rebind its pool
        reference to the returned array."""
        if staged is None:
            return pool
        k, v, blocks = staged
        return ops.insert_prefill(pool, k, v, blocks, self.bs)

    def _prefill_emit(self, st: _PrefillState) -> None:
        """Emit the response's first token from the final chunk's last
        real position (sampled on device per the runner's sampling
        config; bit-exact greedy argmax at temperature 0)."""
        if not st.emit_first or st.emitted:
            return
        hist = st.view.token_history
        first_key = self._row_key(st.view.rid, salt=1)
        smp = jnp.asarray(self._row_sampling(st.view))[None, :]
        tok = sample_tokens(st.last_logits[None, :], first_key[None, :],
                            jnp.asarray([len(hist)], jnp.int32), smp)
        # fslint: disable=FS003(first-token emit must sync: the token gates scheduling and streaming)
        hist.append(int(tok[0]))
        st.emitted = True

    def prefill_finish(self, rid: int) -> None:
        """Close a fully-processed prefill: emit the first token (fresh
        turns), register the row in the persistent device tables, and
        drop the carry buffers."""
        st = self._prefills.pop(rid)
        assert st.pos == len(st.toks), \
            f"prefill_finish with {len(st.toks) - st.pos} tokens pending"
        self._prefill_emit(st)
        self.stats.prefills += 1
        self._register(st.view)

    def prefill_abort(self, rid: int) -> None:
        """Mid-prefill preemption: drop the carry buffers and the state.
        The processed prefix KV already sits in the pool (the engine
        swap-outs what it wants to keep); resumption re-opens a fresh
        prefill."""
        if self._prefills.pop(rid, None) is not None:
            self.stats.prefill_aborts += 1

    def prefill_emit_first(self, rid: int) -> None:
        """Emit the open prefill's first token (public wrapper for
        engines that sequence begin / compute / emit / insert themselves
        to keep the pool lock off the forward — no-op unless the state
        was opened with ``emit_first`` and hasn't emitted yet)."""
        self._prefill_emit(self._prefills[rid])

    # -- monolithic convenience wrappers (engine short-prompt path) -----

    def prefill_compute(self, view: DecodeRequestView, *,
                        emit_first: bool, reused_tokens: int = 0,
                        pool=None) -> Optional[Tuple]:
        """Phase 1 of a whole-prompt prefill: one bucketed chunk over the
        full history (same bit-exact forward, O(log^2) jit variants) plus
        the first-token emit.  With ``reused_tokens``/``pool`` the carry
        is seeded from the pool's restored reuse prefix and only the tail
        chunk is computed (see ``prefill_begin`` — the caller must hold
        the pool lock for the seed gather; single-threaded callers can
        ignore that).  Without a seed this touches NO pool state, so the
        engine runs it OUTSIDE the pool lock.  Returns the staged
        (k, v, blocks) for ``prefill_insert``."""
        total = self.prefill_begin(view, emit_first=emit_first,
                                   reused_tokens=reused_tokens, pool=pool)
        staged = self.prefill_chunk_compute(view.rid, total)
        self._prefill_emit(self._prefills[view.rid])
        return staged

    def prefill_insert(self, view: DecodeRequestView, pool, staged):
        """Phase 2: scatter the staged KV into the DONATED pool and
        register the row in the persistent device tables.  Run under the
        pool lock; returns the new pool — the caller must rebind its
        reference."""
        pool = self.prefill_chunk_insert(view.rid, pool, staged)
        self._prefills.pop(view.rid, None)
        self.stats.prefills += 1
        self._register(view)
        return pool

    def prefill(self, view: DecodeRequestView, pool, *,
                emit_first: bool, reused_tokens: int = 0):
        """Convenience: both prefill phases back to back (single-threaded
        callers — tests, benchmarks).  The pool is DONATED (the seed
        gather, if any, reads it before the donating insert)."""
        staged = self.prefill_compute(view, emit_first=emit_first,
                                      reused_tokens=reused_tokens,
                                      pool=pool if reused_tokens else None)
        return self.prefill_insert(view, pool, staged)

    # ------------------------------------------------------------------

    @staticmethod
    def jit_cache_size() -> int:
        """Compiled-variant count of the decode step, single-device and
        sharded variants combined (all shapes/configs in this process) —
        the recompile metric for decode_hotpath."""
        return int(paged_decode_step_device._cache_size()
                   + paged_decode_step_device_sharded._cache_size())
