"""Cross-request prefix cache — radix tree over the paged GPU pool.

FastSwitch's reuse mechanism (§3.3) only eliminates redundant I/O *within*
a session; at scale the dominant redundancy is *across* users sharing
prompt prefixes (system prompts, few-shot templates, RAG boilerplate).
This module keeps a radix/prefix tree keyed on full-block token-id chunks:
each tree node owns exactly ONE physical GPU block, registered with the
`DynamicBlockGroupManager` as a single-block group under a unique negative
owner id, so the pool's tiling invariants keep holding and eviction goes
through the same public tail-release API contamination uses
(`release_tail_group`).

Sharing model (copy-on-write by construction):
  * only FULL prompt blocks are ever cached — the block holding a
    request's first decode slot is always private, so a sharer never
    writes a cached block; divergence below block granularity simply
    means the walk stops earlier and the tail stays private;
  * a request *maps* a root path of nodes (its shared prefix) and holds a
    per-block refcount via ``mgr.ref_block``; refcounted blocks can never
    reach the free list (asserted in ``mgr._release``);
  * insertion donates a freshly prefilled request's leading full prompt
    blocks to new nodes (``mgr.transfer_prefix_blocks``) — the physical
    blocks don't move, so the donor's composed block table is unchanged.

Eviction is leaf-only and fairness-aware (Locality-aware Fair Scheduling,
arXiv 2501.14312: locality and fairness must be co-designed): only leaves
with refcount 0 (no live mapper) are evictable, scored by
``age / (1 + hits) / (eps + priority_ema)`` — old, rarely-hit prefixes
whose historical users carried little scheduler priority (virtual-token
credit, arXiv 2401.00588) go first.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

# Node owner ids live far below the engine's internal phantom owners
# (e.g. the allocation-pressure rid -7777); negative rids are exempt from
# the live-request block-ownership invariant (B2).
NODE_OWNER_BASE = -100_000

_PRIO_EPS = 0.05
_PRIO_DECAY = 0.8


class PrefixNode:
    __slots__ = ("key", "block", "owner", "parent", "children",
                 "last_use_us", "hits", "prio_ema")

    def __init__(self, key: Tuple[int, ...], block: int, owner: int,
                 parent: Optional["PrefixNode"]):
        self.key = key
        self.block = block
        self.owner = owner
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "PrefixNode"] = {}
        self.last_use_us = 0.0
        self.hits = 0
        self.prio_ema = 0.0

    def depth_path(self) -> List["PrefixNode"]:
        path: List[PrefixNode] = []
        node: Optional[PrefixNode] = self
        while node is not None:
            path.append(node)
            node = node.parent
        path.reverse()
        return path


class PrefixCache:
    """Radix tree of cached full-block prompt prefixes over the GPU pool."""

    def __init__(self, mgr, block_size: int):
        self.mgr = mgr
        self.bs = block_size
        self.roots: Dict[Tuple[int, ...], PrefixNode] = {}
        self._maps: Dict[int, List[PrefixNode]] = {}   # rid -> mapped path
        self._next_owner = NODE_OWNER_BASE
        self.n_nodes = 0
        self.n_hits = 0
        self.n_misses = 0
        self.n_insertions = 0
        self.n_evictions = 0
        self.tokens_saved = 0

    # ------------------------------------------------------------------
    # probing / mapping
    # ------------------------------------------------------------------

    def _cacheable_blocks(self, ids: List[int]) -> int:
        """Full prompt blocks eligible for sharing.  The block containing
        the last prompt token doubles as the first decode slot's block, so
        at least one prompt token always stays private — this also keeps
        the engine's ``reused < context`` prefill precondition true."""
        return max(0, (len(ids) - 1) // self.bs)

    def _walk(self, ids: List[int], limit: int) -> List[PrefixNode]:
        path: List[PrefixNode] = []
        children = self.roots
        for b in range(limit):
            key = tuple(ids[b * self.bs:(b + 1) * self.bs])
            node = children.get(key)
            if node is None:
                break
            path.append(node)
            children = node.children
        return path

    def match_tokens(self, ids: List[int]) -> int:
        """Longest cached prefix (tokens) usable for this prompt."""
        return len(self._walk(ids, self._cacheable_blocks(ids))) * self.bs

    def shared_tokens(self, rid: int) -> int:
        return len(self._maps.get(rid, ())) * self.bs

    def blocks_for(self, rid: int) -> List[int]:
        """Physical blocks of rid's mapped shared prefix, token order."""
        return [n.block for n in self._maps.get(rid, ())]

    def acquire(self, rid: int, ids: List[int], *, now_us: float = 0.0,
                priority: float = 0.0) -> int:
        """Probe the tree for ``ids`` and pin the matched prefix for
        ``rid``.  Returns the shared token count (block-aligned)."""
        assert rid not in self._maps, f"rid {rid} already holds a mapping"
        path = self._walk(ids, self._cacheable_blocks(ids))
        for node in path:
            self.mgr.ref_block(node.block)
            node.last_use_us = now_us
            node.hits += 1
            node.prio_ema = (_PRIO_DECAY * node.prio_ema
                             + (1.0 - _PRIO_DECAY) * priority)
        if path:
            self._maps[rid] = path
            self.n_hits += 1
            self.tokens_saved += len(path) * self.bs
        else:
            self.n_misses += 1
        return len(path) * self.bs

    def release(self, rid: int) -> None:
        """Drop rid's mapping (teardown/finish): unpin its shared blocks."""
        for node in self._maps.pop(rid, ()):
            self.mgr.unref_block(node.block)

    # ------------------------------------------------------------------
    # insertion (block donation after a completed prefill)
    # ------------------------------------------------------------------

    def insert(self, rid: int, ids: List[int], *, now_us: float = 0.0,
               priority: float = 0.0) -> int:
        """Donate rid's leading private full-prompt blocks to the tree and
        remap them as shared for rid.  Returns tokens newly shared.

        If a concurrent identical admission inserted a deeper path since
        rid's match, rid's private copy would fork duplicate nodes at an
        interior position — skip instead (rid keeps its private blocks;
        the next sharer hits the deeper path)."""
        cap = self._cacheable_blocks(ids)
        mapped = self._maps.get(rid, [])
        path = self._walk(ids, cap)
        if len(path) != len(mapped) or cap <= len(mapped):
            return 0
        n_new = cap - len(mapped)
        owners = list(range(self._next_owner,
                            self._next_owner - n_new, -1))
        self._next_owner -= n_new
        blocks = self.mgr.transfer_prefix_blocks(rid, owners)
        parent = mapped[-1] if mapped else None
        children = parent.children if parent else self.roots
        base = len(mapped)
        for i, (owner, block) in enumerate(zip(owners, blocks)):
            b = base + i
            key = tuple(ids[b * self.bs:(b + 1) * self.bs])
            node = PrefixNode(key, block, owner, parent)
            node.last_use_us = now_us
            node.prio_ema = priority
            children[key] = node
            self.mgr.ref_block(block)          # rid keeps using it, shared
            mapped.append(node)
            parent, children = node, node.children
            self.n_nodes += 1
        self._maps[rid] = mapped
        self.n_insertions += n_new
        return n_new * self.bs

    # ------------------------------------------------------------------
    # fairness-aware eviction
    # ------------------------------------------------------------------

    def _score(self, node: PrefixNode, now_us: float) -> float:
        age = max(now_us - node.last_use_us, 0.0) + 1.0
        return age / (1.0 + node.hits) / (_PRIO_EPS + node.prio_ema)

    def _evictable(self) -> List[PrefixNode]:
        out = []
        stack = list(self.roots.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            elif self.mgr.block_refcount(node.block) == 0:
                out.append(node)
        return out

    def evict(self, n_blocks: int, *, now_us: float = 0.0) -> int:
        """Free up to ``n_blocks`` GPU blocks by evicting unreferenced
        leaves, worst fairness score first.  Returns blocks freed."""
        freed = 0
        while freed < n_blocks:
            cands = self._evictable()
            if not cands:
                break
            node = max(cands, key=lambda n: self._score(n, now_us))
            released = self.mgr.release_tail_group(node.owner)
            assert released is not None, \
                f"node owner {node.owner} block {node.block} not releasable"
            if node.parent is not None:
                node.parent.children.pop(node.key, None)
            else:
                self.roots.pop(node.key, None)
            self.n_nodes -= 1
            self.n_evictions += 1
            freed += 1
        return freed

    # ------------------------------------------------------------------

    def iter_nodes(self):
        stack = list(self.roots.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            yield node

    def mappings(self) -> Dict[int, List[PrefixNode]]:
        return self._maps

    def stats(self) -> Dict[str, float]:
        total = self.n_hits + self.n_misses
        return {"nodes": self.n_nodes,
                "blocks": self.n_nodes,
                "hits": self.n_hits,
                "misses": self.n_misses,
                "hit_rate": (self.n_hits / total) if total else 0.0,
                "tokens_saved": self.tokens_saved,
                "insertions": self.n_insertions,
                "evictions": self.n_evictions,
                "mapped_requests": len(self._maps)}
