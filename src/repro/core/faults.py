"""Deterministic fault injection + structured serving errors (DESIGN.md §7).

FastSwitch keeps tail latency bounded under *planned* churn (preemption,
swapping); production churn also includes *failures*: a swap transfer
that errors or stalls, a poison request whose forward pass raises, an
allocation-pressure spike that starves the pool.  This module provides
the chaos substrate the engine's containment layer is tested against:

  * ``FaultPlan`` — a frozen, seeded description of WHICH faults occur
    (rates per fault kind + explicit allocation-pressure windows).
  * ``FaultInjector`` — draws every decision as a pure function of
    ``(plan.seed, site key)`` via a stable hash, so a chaos schedule
    replays bit-exactly regardless of call order, thread timing or
    ``PYTHONHASHSEED``.  An injector built from ``plan=None`` is inert
    (``enabled`` is False and every hook is a cheap no-op).

Fault taxonomy (the degradation ladder in DESIGN.md §7 consumes these):

  swap transient   copy raises ``TransientSwapFault`` for the first
                   ``transient_failures`` attempts, then succeeds —
                   absorbed by the swap manager's bounded retry.
  swap permanent   copy raises ``PermanentSwapFault`` on every attempt —
                   retries exhaust; the engine escalates to a
                   recompute-mode resume (the KV is regenerated from the
                   token history, so the request survives).
  swap fatal       ``FatalSwapFault``: permanent AND marked
                   unrecoverable — the escalation ladder ends in a
                   request fault (``finish_reason="error"``).
  swap stall       the copy succeeds but its completion signal is stuck:
                   the task's ``done_at`` is pushed ``stall_us`` into the
                   simulated future.  The watchdog escalates it to a
                   synchronous retried copy.
  alloc pressure   ``reserved_blocks(iteration)`` > 0 inside a spike
                   window: the engine treats that many GPU blocks as
                   unavailable, forcing preemption/shedding churn.
  poison request   ``poisoned(handle)``: the request's prefill/emit path
                   raises ``PoisonError`` — contained to that request.

The structured overload errors (``EngineOverloadError``,
``EngineDrainingError``) live here too: they are the *admission-level*
half of graceful degradation (bounded waiting queue, drain mode).
"""
from __future__ import annotations

import hashlib
import random
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# structured errors
# ---------------------------------------------------------------------------


class InjectedFault(RuntimeError):
    """Base class for faults raised BY the injector (never by real code)."""


class TransientSwapFault(InjectedFault):
    """Swap copy failure that succeeds on retry."""


class PermanentSwapFault(InjectedFault):
    """Swap copy failure that exhausts every retry (recoverable by
    recompute-mode resume — the KV is regenerated from token history)."""


class FatalSwapFault(PermanentSwapFault):
    """Permanent swap failure marked unrecoverable: the escalation
    ladder must end in a request fault, not a recompute resume."""


class PoisonError(RuntimeError):
    """A poison request's compute path raised (stands in for a NaN
    blow-up, a malformed prompt crashing tokenization, etc.)."""


class EngineOverloadError(RuntimeError):
    """``add_request`` refused: the bounded waiting queue is full and the
    overload policy is ``"reject"`` (or the shed policy picked the new
    request itself).  Structured so a front-end can map it to HTTP 429
    with a meaningful retry hint."""

    def __init__(self, msg: str, *, queue_depth: int, max_waiting: int,
                 predicted_ttft_us: Optional[float] = None):
        super().__init__(msg)
        self.queue_depth = queue_depth
        self.max_waiting = max_waiting
        self.predicted_ttft_us = predicted_ttft_us


class EngineDrainingError(RuntimeError):
    """``add_request``/``continue_session`` refused: the engine is in
    drain mode (running requests finish; no new work is admitted)."""


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FaultPlan:
    """Seeded chaos schedule.  All probabilities are per *decision site*
    (a swap fault decision per dispatched chunk task, a poison decision
    per request handle); ``alloc_spikes`` are explicit windows
    ``(start_iteration, n_iterations, reserved_blocks)``."""
    seed: int = 0
    # swap-transfer fault mix (drawn once per chunk-task dispatch)
    p_swap_transient: float = 0.0
    p_swap_permanent: float = 0.0
    p_swap_fatal: float = 0.0
    p_swap_stall: float = 0.0
    stall_us: float = 200_000.0          # injected completion-signal delay
    transient_failures: int = 1          # failed attempts before success
    # per-request poison decision (drawn once per handle)
    p_poison: float = 0.0
    # allocation-pressure spikes: (start_iter, n_iters, blocks_reserved)
    alloc_spikes: Tuple[Tuple[int, int, int], ...] = ()

    @staticmethod
    def chaos(seed: int = 0, intensity: float = 1.0) -> "FaultPlan":
        """The default chaos mix (serve.py ``--chaos``): all fault kinds
        live at modest rates, two allocation-pressure windows."""
        s = min(max(intensity, 0.0), 4.0)
        return FaultPlan(
            seed=seed,
            p_swap_transient=0.15 * s, p_swap_permanent=0.05 * s,
            p_swap_fatal=0.01 * s, p_swap_stall=0.10 * s,
            p_poison=0.04 * s,
            alloc_spikes=((40, 25, 8), (140, 25, 16)))


@dataclass(frozen=True)
class SwapFaultSpec:
    """One chunk task's drawn fault: ``kind`` in {"transient",
    "permanent", "fatal"} or None (no copy fault), plus an independent
    stall draw."""
    kind: Optional[str] = None
    failures: int = 0                 # attempts that raise (transient)
    stall_us: float = 0.0


# ---------------------------------------------------------------------------
# the injector
# ---------------------------------------------------------------------------


def _site_rng(seed: int, *key) -> random.Random:
    """Deterministic per-site RNG: stable across processes and call
    order (``hash()`` on strings is randomized per process — use a real
    digest)."""
    h = hashlib.blake2b(repr((seed,) + key).encode(), digest_size=8)
    return random.Random(int.from_bytes(h.digest(), "big"))


class FaultInjector:
    """Answers "does a fault fire HERE?" purely from ``(seed, site)``.

    Sites are keyed by stable identifiers the engine already owns
    (request handle, swap direction, per-request dispatch sequence
    number, engine iteration), never by wall clock or object identity —
    that is what makes a chaos schedule replayable."""

    def __init__(self, plan: Optional[FaultPlan]):
        self.plan = plan
        self.enabled = plan is not None and (
            plan.p_swap_transient > 0 or plan.p_swap_permanent > 0
            or plan.p_swap_fatal > 0 or plan.p_swap_stall > 0
            or plan.p_poison > 0 or bool(plan.alloc_spikes))
        # observability: what actually fired (for tests / the event log)
        self.fired = {"transient": 0, "permanent": 0, "fatal": 0,
                      "stall": 0, "poison": 0}

    # -- swap-transfer faults ------------------------------------------

    def swap_fault(self, rid: int, direction: str,
                   seq: int) -> Optional[SwapFaultSpec]:
        """Drawn once per dispatched chunk task.  ``seq`` is the
        engine's per-(rid, direction) dispatch counter."""
        if not self.enabled:
            return None
        p = self.plan
        rng = _site_rng(p.seed, "swap", rid, direction, seq)
        u = rng.random()
        kind = None
        if u < p.p_swap_fatal:
            kind = "fatal"
        elif u < p.p_swap_fatal + p.p_swap_permanent:
            kind = "permanent"
        elif u < p.p_swap_fatal + p.p_swap_permanent + p.p_swap_transient:
            kind = "transient"
        stall = p.stall_us if rng.random() < p.p_swap_stall else 0.0
        if kind is None and stall == 0.0:
            return None
        if kind is not None:
            self.fired[kind] += 1
        if stall:
            self.fired["stall"] += 1
        return SwapFaultSpec(kind=kind,
                             failures=(p.transient_failures
                                       if kind == "transient" else 0),
                             stall_us=stall)

    @staticmethod
    def wrap_copy(spec: SwapFaultSpec, fn):
        """Wrap a data-plane copy so it raises per ``spec``.  The
        attempt counter lives in the closure: a transient fault fails
        the first ``spec.failures`` attempts then runs the real copy; a
        permanent/fatal fault raises on every attempt (the real copy
        never runs — the data genuinely does not arrive)."""
        attempts = [0]

        def wrapped():
            attempts[0] += 1
            if spec.kind == "fatal":
                raise FatalSwapFault(
                    f"injected fatal swap failure (attempt {attempts[0]})")
            if spec.kind == "permanent":
                raise PermanentSwapFault(
                    f"injected permanent swap failure "
                    f"(attempt {attempts[0]})")
            if spec.kind == "transient" and attempts[0] <= spec.failures:
                raise TransientSwapFault(
                    f"injected transient swap failure "
                    f"(attempt {attempts[0]}/{spec.failures})")
            if fn is not None:
                return fn()
            return None

        return wrapped

    # -- poison requests -----------------------------------------------

    def poisoned(self, rid: int) -> bool:
        """Pure per-handle decision: a poisoned request's compute path
        raises ``PoisonError`` at its first prefill chunk / first-token
        emission."""
        if not self.enabled or self.plan.p_poison <= 0:
            return False
        hit = _site_rng(self.plan.seed, "poison", rid).random() \
            < self.plan.p_poison
        return hit

    def note_poison_fired(self) -> None:
        self.fired["poison"] += 1

    # -- allocation pressure -------------------------------------------

    def reserved_blocks(self, iteration: int) -> int:
        """GPU blocks the engine must treat as unavailable during this
        iteration (the max over active spike windows)."""
        if not self.enabled:
            return 0
        r = 0
        for start, length, blocks in self.plan.alloc_spikes:
            if start <= iteration < start + length:
                r = max(r, blocks)
        return r
