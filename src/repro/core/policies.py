"""Engine policy presets: vLLM baseline, incremental opts, full FastSwitch."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

from repro.io.cost_model import A10_PCIE4, HardwareSpec


@dataclass(frozen=True)
class EnginePolicy:
    name: str
    use_block_groups: bool        # Dynamic Block Group Manager (§3.1)
    use_async_swap: bool          # Multithreading Swap Manager (§3.2)
    use_reuse: bool               # KV Cache Reuse Mechanism (§3.3)
    adaptive_async: bool = True
    initial_group_blocks: int = 60
    prealloc_blocks: int = 16
    # BEYOND-PAPER (§Perf): int8-compress KV on the wire — halves every
    # swap transfer's bytes (KV tolerates 8-bit, cf. the kv-int8 decode
    # variant), composing multiplicatively with the paper's three opts.
    swap_wire_bytes_per_elem: int = 2     # 2 = bf16, 1 = int8
    # Preemption mechanism (paper §2.1): "swap" moves KV to host;
    # "recompute" drops it and re-prefills on resumption.
    preemption_mode: str = "swap"
    # Llumnix-style staging buffer (paper §2.2 Challenge #1): per-block
    # copies merged through a small buffer before one transfer — bounded
    # granularity, still dispatch-limited.
    merge_buffer_blocks: int = 1
    # BEYOND-PAPER: Sarathi-style chunked prefill — spread each prefill
    # over iterations (chunk tokens each) so long prompts stop stalling
    # the decode batch (TBT tail).  0 = off (paper-faithful whole-prompt
    # prefill).  In REAL mode the runner executes each chunk as a
    # pow2-bucketed position-masked forward and inserts its KV
    # block-aligned into the pool (DESIGN.md §5) — greedy output stays
    # bit-exact vs the monolithic prefill; sim mode keeps the pure
    # bookkeeping split.
    chunked_prefill_tokens: int = 0


VLLM_BASELINE = EnginePolicy(
    name="vllm", use_block_groups=False, use_async_swap=False,
    use_reuse=False, initial_group_blocks=1, prealloc_blocks=0)

DBG_ONLY = EnginePolicy(
    name="+dbg", use_block_groups=True, use_async_swap=False,
    use_reuse=False)

DBG_REUSE = EnginePolicy(
    name="+dbg+reuse", use_block_groups=True, use_async_swap=False,
    use_reuse=True)

FASTSWITCH = EnginePolicy(
    name="fastswitch", use_block_groups=True, use_async_swap=True,
    use_reuse=True)

FASTSWITCH_ZIP = EnginePolicy(
    name="fastswitch+zip", use_block_groups=True, use_async_swap=True,
    use_reuse=True, swap_wire_bytes_per_elem=1)

VLLM_RECOMPUTE = EnginePolicy(
    name="vllm-recompute", use_block_groups=False, use_async_swap=False,
    use_reuse=False, initial_group_blocks=1, prealloc_blocks=0,
    preemption_mode="recompute")

LLUMNIX = EnginePolicy(
    name="llumnix", use_block_groups=False, use_async_swap=False,
    use_reuse=False, initial_group_blocks=1, prealloc_blocks=0,
    merge_buffer_blocks=2)

FASTSWITCH_CHUNKED = EnginePolicy(
    name="fastswitch+chunked", use_block_groups=True, use_async_swap=True,
    use_reuse=True, chunked_prefill_tokens=512)

POLICIES = {p.name: p for p in (VLLM_BASELINE, DBG_ONLY, DBG_REUSE,
                                FASTSWITCH, FASTSWITCH_ZIP,
                                VLLM_RECOMPUTE, LLUMNIX,
                                FASTSWITCH_CHUNKED)}


@dataclass(frozen=True)
class EngineConfig:
    policy: EnginePolicy = FASTSWITCH
    hardware: HardwareSpec = A10_PCIE4
    num_gpu_blocks: int = 4096
    num_cpu_blocks: int = 16384        # ~60 GB CPU swap space in the paper
    block_size: int = 16
    max_running: int = 48
    max_batch: int = 32                # padded decode batch (real mode)
    mode: str = "sim"                  # "sim" | "real"
    # modelled served-model stats (sim mode; real mode derives from params)
    model_params: int = 8_000_000_000
    kv_bytes_per_token: int = 131072   # LLaMA-8B bf16: 32L*8H*128D*2*2
    seed: int = 0
    # real-mode device-side sampling (DecodeRunner / DESIGN.md §3.6):
    # the ENGINE DEFAULTS a request inherits when its SamplingParams
    # leave a field None.  temperature 0.0 = bit-exact greedy argmax;
    # top_k 0 / top_p 1.0 disable the respective filter.  The values
    # ride a per-row traced (B, 3) array, so neither the defaults nor
    # per-request overrides ever add a compiled decode variant.
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    # Device mesh (data, model) the real-mode engine serves on
    # (DESIGN.md §9): model > 1 shards q/k/v projections, the paged KV
    # pool and the staged swap plane over that many tensor-parallel
    # shards (head-sharded; token streams stay bit-identical to
    # single-device).  (1, 1) — the default — is the single-device
    # engine, byte-for-byte the pre-mesh code path.
    mesh_shape: Tuple[int, int] = (1, 1)
    # Cross-request prefix cache (DESIGN.md §10): radix tree of shared
    # full-block prompt prefixes pinned on the GPU pool, with
    # fairness-aware leaf eviction.  Real mode + reuse-enabled swap
    # policies only; off (the default) leaves every code path untouched.
    prefix_cache: bool = False
    # Swap data plane (DESIGN.md §4): swaps larger than this many blocks
    # are split into chunk tasks the engine interleaves with decode steps
    # (fine-grained conflict syncs then wait only on the overlapping
    # chunk).  0 disables chunking.
    swap_chunk_blocks: int = 64
    # Adaptive swap profiler window: recent-swap records AND recent
    # decode-iteration durations kept for decide_async's cost model.
    r_info_window: int = 64
    # --- robustness / graceful degradation (DESIGN.md §7) -------------
    # Bounded waiting queue: add_request refuses (or sheds) when the
    # waiting queue holds this many requests.  0 = unbounded (legacy).
    max_waiting: int = 0
    # What a full waiting queue does: "reject" raises EngineOverloadError
    # at add_request; "shed" aborts the lowest-value waiting request
    # (SLO-doomed first, then lowest priority, newest first) to make room.
    overload_policy: str = "reject"
    # Run check_engine_invariants every N steps (0 = never).  Cheap
    # enough for CI chaos smokes at N=1; production would sample.
    check_invariants_every: int = 0
    # Swap copy failure handling: bounded retries with linear backoff
    # charged to the task's simulated completion time.
    swap_max_retries: int = 2
    swap_retry_backoff_us: float = 200.0
    # Watchdog: an in-flight swap task still incomplete this long after
    # issue is escalated to a synchronous retried copy.  0 = disabled.
    swap_watchdog_us: float = 0.0
    # Deterministic chaos schedule (core/faults.FaultPlan); None = no
    # injection (all fault hooks are inert no-ops).
    fault_plan: Optional[object] = None

    def with_policy(self, name: str) -> "EngineConfig":
        return replace(self, policy=POLICIES[name])
