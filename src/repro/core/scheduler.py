"""Priority scheduler — FastSwitch's fairness-aware preemptive scheduling.

Maintains the waiting / running / swapped queues, applies the offline
priority trace, and on every priority update reorders requests across the
queues to match the new priorities under the GPU block budget (paper §4:
"the scheduler then reorders requests across waiting, running and swapped
queues to meet the updated priority requirements").
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.data.sharegpt import Conversation


class ReqState(enum.Enum):
    WAITING = "waiting"          # needs (re-)admission + prefill
    RUNNING = "running"          # in the decode batch
    SWAPPED = "swapped"          # preempted; KV on CPU
    SWAPPING_IN = "swapping_in"  # async swap-in in flight
    FINISHED = "finished"        # turn done, KV retained for continue_session
    DONE = "done"


@dataclass
class Request:
    """One conversation being served (state spans turns)."""
    conv: Conversation
    turn_idx: int = 0
    state: ReqState = ReqState.WAITING
    context_tokens: int = 0       # tokens currently represented in KV
    target_tokens: int = 0        # context length when this turn completes
    prefix_tokens: int = 0        # context before this turn's prompt
    # metrics (sim us)
    turn_arrival_us: float = 0.0
    first_token_us: Optional[float] = None
    token_times_us: List[float] = field(default_factory=list)
    ttfts_us: List[float] = field(default_factory=list)
    tbts_us: List[float] = field(default_factory=list)
    generated: int = 0
    token_history: List[int] = field(default_factory=list)  # real mode
    resume_tokens: int = 0   # recompute-preemption: context to re-prefill
    prefill_remaining: int = 0   # chunked prefill: tokens still to process
    prefill_is_resume: bool = False  # chunked RECOMPUTE resume: no first
    #                                  token on completion (serving §6)
    # serving-API surface (core/serving.py): per-request parameters and
    # streaming / SLO bookkeeping
    sampling: object = None        # request_api.SamplingParams
    slo: object = None             # request_api.SLOSpec | None
    retain_kv: bool = False        # park the finished turn for follow-ups
    tbt_mark: int = 0              # len(tbts_us) at begin_turn (turn slice)
    hist_emitted: int = 0          # history prefix already streamed out

    @property
    def rid(self) -> int:
        return self.conv.conv_id

    def current_turn(self):
        return self.conv.turns[self.turn_idx]

    def begin_turn(self, now_us: float) -> None:
        t = self.current_turn()
        self.prefix_tokens = self.context_tokens
        self.target_tokens = self.context_tokens + t.prompt_tokens + t.response_tokens
        self.turn_arrival_us = now_us
        self.first_token_us = None
        self.generated = 0
        self.tbt_mark = len(self.tbts_us)

    def finish_token(self, now_us: float) -> None:
        if self.first_token_us is None:
            self.first_token_us = now_us
            self.ttfts_us.append(now_us - self.turn_arrival_us)
        else:
            self.tbts_us.append(now_us - self.token_times_us[-1])
        self.token_times_us.append(now_us)
        self.generated += 1

    def turn_done(self) -> bool:
        return self.context_tokens >= self.target_tokens


class PriorityScheduler:
    def __init__(self, trace, max_running: int = 48):
        self.trace = trace
        self.max_running = max_running
        self.requests: Dict[int, Request] = {}
        self.waiting: List[int] = []
        self.running: List[int] = []
        self.swapped: List[int] = []
        self.swapping_in: List[int] = []
        # admission-layer priority overrides (DESIGN.md §11): a front-end
        # maps SLO tightness onto scheduler priority here, so deadlines —
        # not the synthetic trace — drive preemption for its requests.
        # Requests without an override keep the trace's priority.
        self.extern: Dict[int, float] = {}

    # ------------------------------------------------------------------

    def add_request(self, req: Request) -> None:
        self.requests[req.rid] = req
        self.waiting.append(req.rid)
        req.state = ReqState.WAITING

    def priority(self, rid: int) -> float:
        p = self.extern.get(rid)
        return p if p is not None else self.trace.priority(rid)

    def set_priority(self, rid: int, priority: float) -> None:
        self.extern[rid] = float(priority)

    def clear_priority(self, rid: int) -> None:
        self.extern.pop(rid, None)

    def active_ids(self) -> List[int]:
        return self.waiting + self.running + self.swapped + self.swapping_in

    def step_trace(self) -> bool:
        return self.trace.step(self.active_ids(), self.running)

    # ------------------------------------------------------------------

    def desired_running(self, block_budget_tokens: int,
                        block_size: int, batch_bucket: int = 0) -> List[int]:
        """Top-priority active requests that fit the GPU token budget.

        ``batch_bucket`` > 0 (the real-mode runner's compiled pow2 decode
        bucket) enables padded-batch economics: the decode step always
        executes the next pow2 rows, so spilling a bucket boundary by a
        straggler or two doubles the padded batch for little useful work.
        The spill is trimmed back to the boundary — lowest-priority
        ADMISSIONS first, never a currently running request (no
        preemption for bucket aesthetics) — unless it fills at least half
        of the next bucket's new rows."""
        cands = sorted(self.active_ids(), key=self.priority, reverse=True)
        chosen: List[int] = []
        budget = block_budget_tokens
        for rid in cands:
            if len(chosen) >= self.max_running:
                break
            req = self.requests[rid]
            # footprint: current context + headroom of one block
            need = max(req.context_tokens,
                       req.prefix_tokens + req.current_turn().prompt_tokens) \
                + block_size
            if need <= budget:
                chosen.append(rid)
                budget -= need
        if batch_bucket > 0 and len(chosen) > batch_bucket:
            boundary = batch_bucket
            while boundary * 2 <= len(chosen):
                boundary *= 2
            spill = len(chosen) - boundary
            if spill < max(1, boundary // 2):
                running = set(self.running)
                # lowest-priority first, skipping (never trimming) running
                # requests wherever they sit in the tail
                for i in range(len(chosen) - 1, -1, -1):
                    if len(chosen) <= boundary:
                        break
                    if chosen[i] not in running:
                        chosen.pop(i)
        return chosen

    def classify_rebalance(self, desired: List[int]
                           ) -> Tuple[List[int], List[int], List[int]]:
        """Returns (to_preempt, to_swap_in, to_admit)."""
        dset = set(desired)
        to_preempt = [r for r in self.running if r not in dset]
        to_swap_in = [r for r in self.swapped if r in dset]
        to_admit = [r for r in self.waiting if r in dset]
        return to_preempt, to_swap_in, to_admit

    # -- state transitions -------------------------------------------------

    def move(self, rid: int, dst: ReqState) -> None:
        req = self.requests[rid]
        for q in (self.waiting, self.running, self.swapped, self.swapping_in):
            if rid in q:
                q.remove(rid)
        req.state = dst
        if dst == ReqState.WAITING:
            self.waiting.append(rid)
        elif dst == ReqState.RUNNING:
            self.running.append(rid)
        elif dst == ReqState.SWAPPED:
            self.swapped.append(rid)
        elif dst == ReqState.SWAPPING_IN:
            self.swapping_in.append(rid)
        # FINISHED / DONE live outside the queues

    def shed_order(self, doomed: Set[int]) -> List[int]:
        """Overload shedding order over the WAITING queue (DESIGN.md §7):
        least valuable first — requests already doomed to miss their TTFT
        SLO (``doomed``, computed by the engine's queue model) before
        viable ones, then lowest priority, then newest arrival (oldest
        waiters have accumulated the most queueing investment; shedding
        them wastes it and is the classic late-drop pathology)."""
        return sorted(self.waiting,
                      key=lambda r: (r not in doomed, self.priority(r),
                                     -self.requests[r].turn_arrival_us))

    def victims_for_space(self, exclude: Set[int]) -> List[int]:
        """Lowest-priority running requests first (preemption order).
        At equal priority a request still mid chunked prefill
        (``prefill_remaining`` > 0) is preempted LAST: aborting it
        forfeits the prefill chunks already computed (real mode inserts
        them into the pool and would recompute them on re-admission),
        while a decoding victim resumes from its swapped KV at full
        value."""
        return sorted((r for r in self.running if r not in exclude),
                      key=lambda r: (self.priority(r),
                                     self.requests[r].prefill_remaining > 0))
