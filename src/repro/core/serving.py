"""Open-world serving core — the engine behind FastSwitch's control plane.

``ServingEngine`` is the vLLM-shaped (``LLMEngine.add_request()/step()``)
open-world core: requests ARRIVE at runtime, stream incremental
``RequestOutput`` deltas, can be CANCELLED in any lifecycle state, and
multi-turn follow-ups continue a retained session through the KV-reuse
path — nothing is pre-sorted or preloaded.  The trace-replay driver the
benchmarks use (``FastSwitchEngine``, core/engine.py) is a thin CLIENT
of this API: arrivals and wake-ups live in the driver, not in ``step()``.

Two execution modes share the full control plane:
  * ``sim``  — token bookkeeping only; latency from the hardware cost
               model.  Used for thousand-conversation benchmark traces.
  * ``real`` — a reduced model decodes actual tokens against the paged
               GPU pool through the Pallas paged-attention kernel, and
               swaps move real KV bytes between pools.

Public API (DESIGN.md §6):
  add_request(prompt, sampling, slo=...) -> handle
  step(until_us=None)                    -> List[RequestOutput]
  abort(handle)                          -> bool   (valid in EVERY state)
  continue_session(handle, prompt, ...)  -> handle (KV-reuse follow-up)
  release_session(handle)                          (drop a retained copy)

Per-iteration flow (Algorithm 1 embedded; arrivals are now the caller's
job between steps):
  1. poll completed async swap-ins -> running
  2. drop requests that can never fit the pool (budget safeguard)
  3. priority-trace step; on update: rebalance queues (preempt / swap-in /
     admit) under the GPU block budget
  4. opportunistic admission of waiting requests
  5. prefill newly admitted requests (prefill-with-prefix accounting)
  6. decode one token for the running batch (+ block allocation with
     conflict resolution)
  7. finish turns: retain KV copy per policy; park the session for
     ``continue_session`` (or release it when ``retain_kv`` is unset)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import (Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

import numpy as np

from repro.cache.paged import PagedPools, PoolSpec
from repro.core.block_group import (DynamicBlockGroupManager,
                                    OutOfBlocksError)
from repro.core.decode_runner import DecodeRequestView, DecodeRunner
from repro.core.faults import (EngineDrainingError, EngineOverloadError,
                               FatalSwapFault, FaultInjector, PoisonError)
from repro.core.invariants import check_engine_invariants
from repro.core.policies import EngineConfig
from repro.core.prefix_cache import PrefixCache
from repro.core.request_api import (RequestEvent, RequestOutput,
                                    RequestSLOStats, SamplingParams,
                                    SLOSpec, jain_index)
from repro.kernels.block_copy import runs_to_indices, split_runs, trim_runs
from repro.core.reuse import KVCacheReuseManager
from repro.core.scheduler import PriorityScheduler, Request, ReqState
from repro.core.swap_manager import MultithreadingSwapManager, SimClock
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import Conversation, Turn
from repro.io.cost_model import IterationCostModel


@dataclass
class EngineMetrics:
    ttfts_us: List[float] = field(default_factory=list)
    tbts_us: List[float] = field(default_factory=list)
    total_tokens: int = 0
    total_time_us: float = 0.0
    iterations: int = 0
    prefills: int = 0
    preemptions: int = 0
    swap_in_count: int = 0
    swap_out_count: int = 0
    ctx_switch_stall_us: float = 0.0
    callstack_wall_s: float = 0.0      # REAL wall time of the control plane
    aborted: int = 0                   # client cancellations
    dropped: int = 0                   # budget-safeguard drops
    # robustness layer (DESIGN.md §7)
    faulted: int = 0                   # request faults (finish_reason=error)
    shed: int = 0                      # overload-shed waiting requests
    rejected: int = 0                  # add_request refusals (overload/drain)
    swap_failure_resumes: int = 0      # permanent swap failure -> recompute
    invariant_checks: int = 0          # sanitizer passes that ran clean
    # cross-request prefix cache (DESIGN.md §10)
    prefix_hits: int = 0               # admissions with a cached prefix
    prefix_misses: int = 0             # admissions probing empty-handed
    prefix_tokens_saved: int = 0       # prompt tokens not recomputed
    prefix_evictions: int = 0          # cached blocks reclaimed by pressure
    # per-turn SLO attainment records (request_api.RequestSLOStats)
    request_stats: List[RequestSLOStats] = field(default_factory=list)
    # (t_end_us, batch, t_iter_us, prefills_in_iter, stall_so_far_us)
    iter_records: List[Tuple[float, int, float, int, float]] = \
        field(default_factory=list)

    def percentile(self, xs: Sequence[float], p: float) -> float:
        if not xs:
            return 0.0
        return float(np.percentile(np.asarray(xs), p))

    def summary(self) -> Dict[str, float]:
        return {
            "p50_ttft_ms": self.percentile(self.ttfts_us, 50) / 1e3,
            "p95_ttft_ms": self.percentile(self.ttfts_us, 95) / 1e3,
            "p99_ttft_ms": self.percentile(self.ttfts_us, 99) / 1e3,
            "p999_ttft_ms": self.percentile(self.ttfts_us, 99.9) / 1e3,
            "p99_tbt_ms": self.percentile(self.tbts_us, 99) / 1e3,
            "p999_tbt_ms": self.percentile(self.tbts_us, 99.9) / 1e3,
            "throughput_tok_s": (self.total_tokens
                                 / max(self.total_time_us / 1e6, 1e-9)),
            "total_tokens": self.total_tokens,
            "iterations": self.iterations,
            "preemptions": self.preemptions,
            "ctx_switch_stall_us": self.ctx_switch_stall_us,
            "callstack_wall_s": self.callstack_wall_s,
            "aborted": self.aborted,
            "dropped": self.dropped,
            "faulted": self.faulted,
            "shed": self.shed,
            "rejected": self.rejected,
            "swap_failure_resumes": self.swap_failure_resumes,
            "invariant_checks": self.invariant_checks,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "prefix_hit_rate": (self.prefix_hits
                                / max(self.prefix_hits
                                      + self.prefix_misses, 1)),
            "prefix_tokens_saved": self.prefix_tokens_saved,
            "prefix_evictions": self.prefix_evictions,
        }

    def slo_summary(self) -> Dict[str, Optional[float]]:
        """Per-request SLO-attainment + fairness rollup (DESIGN.md §6.4).

        Tail percentiles hide WHICH users missed; a fairness-aware
        scheduler is judged on attainment per request and its spread.
        ``jain_fairness_tbt`` is Jain's index over per-turn TBT
        attainment fractions (1.0 = every user equally served)."""
        stats = self.request_stats
        ttft = [s.ttft_ok for s in stats if s.ttft_ok is not None]
        tbt_tok = [(s.tbt_ok_frac, max(s.generated - 1, 0))
                   for s in stats if s.tbt_ok_frac is not None]
        attained = [s.attained for s in stats if s.attained is not None]
        tok_total = sum(n for _, n in tbt_tok)
        return {
            "turns": len(stats),
            "ttft_slo_attainment": (sum(ttft) / len(ttft)) if ttft else None,
            "tbt_slo_attainment": (sum(f * n for f, n in tbt_tok)
                                   / tok_total) if tok_total else None,
            "slo_attainment": (sum(attained) / len(attained))
            if attained else None,
            "jain_fairness_tbt": jain_index(
                [s.tbt_ok_frac for s in stats if s.tbt_ok_frac is not None]),
            "aborted": self.aborted,
            "dropped": self.dropped,
        }


class ServingEngine:
    def __init__(self, config: EngineConfig,
                 trace: Optional[PriorityTrace] = None,
                 model_bundle: Optional[dict] = None,
                 event_sink: Optional[Callable[[RequestEvent], None]] = None,
                 keep_events: bool = True,
                 stream_tokens: bool = False):
        self.config = config
        pol = config.policy
        self.clock = SimClock()
        self.metrics = EngineMetrics()

        group_blocks = pol.initial_group_blocks if pol.use_block_groups else 1
        self.gpu_mgr = DynamicBlockGroupManager(
            config.num_gpu_blocks - 1,     # last block reserved as trash
            config.block_size, initial_group_blocks=group_blocks,
            seed=config.seed)
        self.reuse = KVCacheReuseManager(
            config.num_cpu_blocks, config.block_size,
            initial_group_blocks=group_blocks, enabled=pol.use_reuse,
            prealloc_blocks=pol.prealloc_blocks if pol.use_reuse else 0)

        self.model_bundle = model_bundle
        self.pools: Optional[PagedPools] = None
        # real-mode serving mesh (DESIGN.md §9): None at (1, 1) — the
        # single-device engine; otherwise the decode/prefill steps and
        # the staged swap plane run tensor-parallel over ``model``
        self.mesh = None
        if config.mode == "real":
            assert model_bundle is not None, "real mode needs a model bundle"
            from repro.launch.mesh import make_serving_mesh
            self.mesh = make_serving_mesh(config.mesh_shape)
            cfg = model_bundle["cfg"]
            spec = PoolSpec.from_config(cfg, config.num_gpu_blocks,
                                        config.num_cpu_blocks,
                                        config.block_size)
            self.pools = PagedPools(spec, with_data=True, mesh=self.mesh)
            self.block_bytes = spec.block_bytes()
            from repro.models.params import count_params_analytic
            model_params = count_params_analytic(cfg)
            kv_tok = spec.block_bytes() // spec.block_size
        else:
            # sim mode: modelled LLaMA-8B-like footprint
            self.block_bytes = config.kv_bytes_per_token * config.block_size
            model_params = config.model_params
            kv_tok = config.kv_bytes_per_token
        # beyond-paper wire compression (int8 KV on the PCIe/DMA link)
        self.block_bytes = self.block_bytes * pol.swap_wire_bytes_per_elem // 2

        self.swap = MultithreadingSwapManager(
            config.hardware, self.pools,
            async_enabled=pol.use_async_swap,
            adaptive=pol.adaptive_async,
            r_info_window=config.r_info_window,
            max_copy_retries=config.swap_max_retries,
            retry_backoff_us=config.swap_retry_backoff_us)
        self.iter_cost = IterationCostModel(
            config.hardware, model_params=model_params,
            kv_bytes_per_token=kv_tok)

        self.trace = trace or PriorityTrace()
        self.sched = PriorityScheduler(self.trace, config.max_running)
        # live-priority fallback for contamination victims never seen by
        # update_priority (the trace lazily assigns, so this never raises)
        self.reuse.priority_fn = self.sched.priority
        # cross-request prefix cache (DESIGN.md §10): radix tree of shared
        # full-block prompt prefixes pinned on the GPU pool.  Real mode
        # only (sim prompts have no token ids to key on) and requires a
        # reuse-enabled policy: the disabled-reuse swap paths rewrite a
        # request's whole context in place, which would scribble over
        # pinned shared blocks.
        self.prefix: Optional[PrefixCache] = None
        if config.prefix_cache:
            if config.mode != "real":
                raise ValueError("prefix_cache needs mode='real' "
                                 "(sim prompts carry no token ids)")
            if not pol.use_reuse or pol.preemption_mode != "swap":
                raise ValueError("prefix_cache requires a reuse-enabled "
                                 "policy (preemption_mode='swap' with "
                                 "use_reuse)")
            self.prefix = PrefixCache(self.gpu_mgr, config.block_size)
        # retained (FINISHED) sessions awaiting continue_session/release
        self.parked: Dict[int, Request] = {}
        self._next_handle = 0
        self._token_hist_by_conv: Dict[int, List[int]] = {}
        # per-request CPU block-id mirror for the data plane
        self._trash_block = config.num_gpu_blocks - 1
        # batch-bucket-aware admission: iterations the engine has held a
        # boundary against under-pressure growth (bounded, see
        # _admission_target)
        self._bucket_hold = 0
        self._bucket_hold_iter = -1
        # device-resident decode hot path (real mode): persistent block
        # tables, bucketed shapes, donated pool — see DESIGN.md §3
        self.runner: Optional[DecodeRunner] = None
        if self.pools is not None:
            self.runner = DecodeRunner(
                model_bundle, block_size=config.block_size,
                trash_block=self._trash_block,
                temperature=config.temperature, top_k=config.top_k,
                top_p=config.top_p, seed=config.seed, mesh=self.mesh)
        # serving-API surface: step outputs, event log, streaming
        self._outs: Dict[int, RequestOutput] = {}
        self.events: Optional[List[RequestEvent]] = [] if keep_events else None
        self._event_sink = event_sink
        self.stream_tokens = stream_tokens
        # robustness layer (DESIGN.md §7): deterministic fault injection,
        # drain mode, per-(rid, direction) swap dispatch counters (the
        # injector's stable site keys) and the allocation-pressure
        # phantom's current holding
        self.faults = FaultInjector(config.fault_plan)
        self._draining = False
        self._swap_seq: Dict[Tuple[int, str], int] = {}
        self._pressure_blocks = 0

    # ------------------------------------------------------------------
    # public API: request lifecycle
    # ------------------------------------------------------------------

    def add_request(self, prompt: Union[int, Sequence[int]],
                    sampling: Optional[SamplingParams] = None, *,
                    slo: Optional[SLOSpec] = None,
                    handle: Optional[int] = None,
                    retain_kv: bool = False,
                    priority: Optional[float] = None) -> int:
        """Submit one request.  ``prompt`` is the token-id list (real
        mode) or a token COUNT (sim mode — there are no ids to give).
        Returns the request handle, valid for ``step`` outputs,
        ``abort`` and ``continue_session``.

        ``retain_kv``: keep the finished turn's KV as a CPU reuse copy
        so a follow-up ``continue_session`` pays only the prefix swap-in
        instead of a full re-prefill; the caller owns the copy's
        lifetime (``release_session``/``abort`` frees it).

        ``priority``: admission-layer scheduler priority override (the
        front-end maps SLO tightness here, DESIGN.md §11); ``None``
        keeps the engine's priority trace in charge."""
        if self._draining:
            self.metrics.rejected += 1
            raise EngineDrainingError(
                "engine is draining: running requests finish, no new "
                "work is admitted")
        self._check_overload(slo)
        sampling = sampling or SamplingParams()
        self._check_sampling(sampling)
        if handle is None:
            while (self._next_handle in self.sched.requests
                   or self._next_handle in self.parked):
                self._next_handle += 1
            handle = self._next_handle
            self._next_handle += 1
        elif handle in self.sched.requests or handle in self.parked:
            raise ValueError(f"handle {handle} already in use")
        # a reused handle (aborted then re-added between steps) must not
        # inherit the old lifecycle's undelivered output delta
        self._outs.pop(handle, None)
        n_prompt, ids = self._parse_prompt(prompt)
        conv = Conversation(conv_id=handle,
                            arrival_s=self.clock.now_us / 1e6,
                            turns=[Turn(n_prompt, sampling.max_tokens,
                                        prompt_ids=ids)],
                            think_time_s=0.0)
        req = Request(conv=conv)
        req.sampling, req.slo, req.retain_kv = sampling, slo, retain_kv
        req.begin_turn(self.clock.now_us)
        self.sched.add_request(req)
        if priority is not None:
            # before the prefix probe: acquisition pins at this priority
            self.sched.set_priority(handle, priority)
        shared = 0
        if self.prefix is not None and ids is not None:
            # probe the prefix tree BEFORE prefill and pin the matched
            # blocks now — a hit found at arrival must not be evicted
            # while the request waits for admission
            shared = self.prefix.acquire(
                handle, ids, now_us=self.clock.now_us,
                priority=self.sched.priority(handle))
            if shared:
                self.metrics.prefix_hits += 1
                self.metrics.prefix_tokens_saved += shared
            else:
                self.metrics.prefix_misses += 1
        self._event(handle, "arrive", prompt_tokens=n_prompt,
                    max_tokens=sampling.max_tokens,
                    **({"shared_tokens": shared} if shared else {}))
        return handle

    def continue_session(self, handle: int,
                         prompt: Union[int, Sequence[int]],
                         sampling: Optional[SamplingParams] = None, *,
                         slo: Optional[SLOSpec] = None,
                         retain_kv: bool = False,
                         priority: Optional[float] = None) -> int:
        """Follow-up turn on a retained (FINISHED) session: the new
        prompt extends the conversation and admission reuses the CPU KV
        copy of the previous turns (prefix swap-in instead of full
        prefill — the paper's §3.3 mechanism, now exercised open-world)."""
        if self._draining:
            self.metrics.rejected += 1
            raise EngineDrainingError(
                "engine is draining: running requests finish, no new "
                "work is admitted")
        if handle in self.sched.requests:
            raise ValueError(f"handle {handle} still live; a follow-up "
                             "needs the previous turn finished")
        req = self.parked.pop(handle, None)
        if req is None:
            raise KeyError(f"no retained session for handle {handle}")
        sampling = sampling or SamplingParams()
        self._check_sampling(sampling)
        n_prompt, ids = self._parse_prompt(prompt)
        req.conv.turns.append(Turn(n_prompt, sampling.max_tokens,
                                   prompt_ids=ids))
        req.turn_idx += 1
        req.sampling, req.slo, req.retain_kv = sampling, slo, retain_kv
        req.begin_turn(self.clock.now_us)
        self.sched.add_request(req)
        if priority is not None:
            self.sched.set_priority(handle, priority)
        self._event(handle, "continue", turn=req.turn_idx,
                    prompt_tokens=n_prompt, prefix_tokens=req.prefix_tokens)
        return handle

    def release_session(self, handle: int) -> bool:
        """Drop a retained session's CPU KV copy (the caller will not
        follow up).  Live requests are released through ``abort``."""
        req = self.parked.pop(handle, None)
        if req is None:
            return False
        self.reuse.release(handle)
        if self.prefix is not None:
            self.prefix.release(handle)
        req.state = ReqState.DONE
        self.sched.clear_priority(handle)
        self._event(handle, "release")
        return True

    def abort(self, handle: int, reason: str = "abort") -> bool:
        """Cancel a request in ANY lifecycle state — WAITING, RUNNING,
        SWAPPED, SWAPPING_IN, mid-chunked-prefill or FINISHED/retained.
        Releases its GPU blocks and CPU reuse copy, retires its
        in-flight swap-in chunk tasks, drops any open chunked-prefill
        carry, and frees its decode-runner row (block table back to the
        trash sentinel).  In-flight swap-OUT d2h gathers are left on the
        ongoing list so later copies reusing their CPU blocks still
        order behind them (``data_deps``); they retire on completion.
        Returns False for an unknown handle."""
        req = self.sched.requests.get(handle)
        if req is None:
            if handle in self.parked:       # retained session: drop copy
                req = self.parked.pop(handle)
                self.reuse.release(handle)
                if self.prefix is not None:
                    self.prefix.release(handle)
                req.state = ReqState.DONE
                self.sched.clear_priority(handle)
                self.metrics.aborted += 1
                self._event(handle, "abort", state="finished")
                return True
            return False
        state = self._teardown_request(req, reason)
        if reason == "dropped":
            self.metrics.dropped += 1
            self._event(handle, "drop", state=state)
        elif reason == "shed":
            self.metrics.shed += 1
            self._event(handle, "shed", state=state)
        else:
            self.metrics.aborted += 1
            self._event(handle, "abort", state=state)
        return True

    def _teardown_request(self, req, reason: str,
                          error: Optional[str] = None) -> str:
        """The ONE full-resource teardown for a live request — shared by
        client ``abort``, budget drops, overload shedding and the
        request-fault path, so fault cleanup can never drift from abort
        cleanup (every leak class is released in one place): runner row
        + open prefill carry (trash-sentinel rebind), in-flight swap-in
        chunk tasks and queued copy failures, GPU blocks, the CPU reuse
        copy, queue membership, and the terminal output/SLO record.
        In-flight swap-OUT d2h gathers are left on the ongoing list so
        later copies reusing their CPU blocks still order behind them
        (``data_deps``); they retire on completion.  Returns the
        pre-teardown state name (for the caller's event)."""
        handle = req.rid
        state = req.state.value
        if self.runner is not None:
            self.runner.prefill_abort(handle)   # no-op if none open
            self.runner.release(handle)
        req.prefill_remaining = 0
        req.prefill_is_resume = False
        req.resume_tokens = 0
        self.swap.retire_request(handle)
        self.swap.take_failed_for(handle)   # drop stale copy failures
        self.gpu_mgr.release_request(handle)
        self.reuse.release(handle)
        if self.prefix is not None:
            self.prefix.release(handle)     # unpin the shared prefix
        for q in (self.sched.waiting, self.sched.running,
                  self.sched.swapped, self.sched.swapping_in):
            if handle in q:
                q.remove(handle)
        self._record_slo(req, reason)
        out = self._out(handle)
        out.finished, out.finish_reason = True, reason
        if error is not None:
            out.error = error
        out.generated, out.context_tokens = req.generated, req.context_tokens
        req.state = ReqState.DONE
        del self.sched.requests[handle]
        self.sched.clear_priority(handle)
        return state

    def _fault_request(self, rid: int, exc: BaseException) -> None:
        """Containment endpoint: an exception escaping a per-request
        operation faults THAT request — terminal ``finish_reason="error"``
        output, an ``error`` event, full resource teardown — instead of
        crashing ``step()`` and every other live request with it."""
        req = self.sched.requests.get(rid)
        if req is None:
            return
        msg = str(exc)
        if type(exc).__name__ not in msg:
            msg = f"{type(exc).__name__}: {msg}"
        state = self._teardown_request(req, "error", error=msg)
        self.metrics.faulted += 1
        self._event(rid, "error", state=state, error=msg)

    def _contained(self, rid: int, fn, *args, **kwargs):
        """Run one per-request operation with fault isolation: an
        escaping exception faults ``rid`` (terminal error output + full
        teardown) and returns None.  Applied at every step() site whose
        failure is attributable to a single request — batched decode
        stays engine-fatal (its failure has no single owner)."""
        try:
            return fn(*args, **kwargs)
        except Exception as e:
            self._fault_request(rid, e)
            return None

    def has_work(self) -> bool:
        """True while any request is live (retained sessions idle in
        ``parked`` don't count — they cost CPU blocks, not steps)."""
        return bool(self.sched.requests)

    # ------------------------------------------------------------------
    # overload protection / drain (DESIGN.md §7)
    # ------------------------------------------------------------------

    def drain(self) -> None:
        """Enter drain mode: running/queued requests finish normally but
        ``add_request``/``continue_session`` refuse new work — the
        front-end's graceful-shutdown primitive.  Irreversible for the
        engine's lifetime (restart to serve again)."""
        if not self._draining:
            self._draining = True
            self._event(-1, "drain", enabled=True)

    @property
    def draining(self) -> bool:
        return self._draining

    def predicted_ttft_us(self, queue_pos: int) -> float:
        """Coarse admission-queue model: a request entering at waiting
        position ``queue_pos`` waits roughly one mean turn duration per
        ``max_running`` requests ahead of it (turns drain in running-slot
        waves).  Mean turn duration comes from the recent SLO records;
        before any history a prefill-cost floor stands in.  Deliberately
        cheap and pessimism-biased — it gates shedding decisions, not
        billing."""
        stats = self.metrics.request_stats[-32:]
        durs = [s.ttft_us + s.generated * s.mean_tbt_us for s in stats
                if s.ttft_us is not None]
        mean_turn = (sum(durs) / len(durs)) if durs \
            else self.iter_cost.prefill_us(512)
        lanes = max(1, self.config.max_running)
        return (queue_pos // lanes + 1) * mean_turn

    def _doomed_waiting(self) -> set:
        """Waiting requests already predicted to miss their TTFT SLO
        (elapsed wait + predicted remaining queue delay > deadline):
        the shed policy's first victims — serving them spends GPU time
        on responses the client has already timed out on."""
        doomed = set()
        now = self.clock.now_us
        for pos, rid in enumerate(self.sched.waiting):
            req = self.sched.requests[rid]
            slo = req.slo
            if slo is None or slo.ttft_us is None:
                continue
            waited = now - req.turn_arrival_us
            if waited + self.predicted_ttft_us(pos) > slo.ttft_us:
                doomed.add(rid)
        return doomed

    def _check_overload(self, slo: Optional[SLOSpec]) -> None:
        """Bounded waiting queue (``EngineConfig.max_waiting``): policy
        "reject" raises a structured ``EngineOverloadError`` (front-ends
        map it to 429 + retry hint); policy "shed" aborts the least
        valuable waiting request — SLO-doomed first, then lowest
        priority, then newest — to make room for the arrival."""
        mw = self.config.max_waiting
        if mw <= 0 or len(self.sched.waiting) < mw:
            return
        depth = len(self.sched.waiting)
        if self.config.overload_policy == "shed":
            order = self.sched.shed_order(self._doomed_waiting())
            if order:
                self.abort(order[0], reason="shed")
                return
        self.metrics.rejected += 1
        raise EngineOverloadError(
            f"waiting queue full ({depth} >= max_waiting={mw})",
            queue_depth=depth, max_waiting=mw,
            predicted_ttft_us=self.predicted_ttft_us(depth))

    # ------------------------------------------------------------------
    # front-end introspection + session migration (DESIGN.md §11)
    # ------------------------------------------------------------------

    def load_snapshot(self) -> Dict[str, object]:
        """One coherent load sample for router dispatch decisions: queue
        depths, free pool space and the admission-queue TTFT prediction
        a request arriving NOW would face.  Cheap (no device sync) — the
        replica thread publishes one per step."""
        depth = len(self.sched.waiting)
        return {
            "now_us": self.clock.now_us,
            "waiting": depth,
            "running": len(self.sched.running),
            "swapped": len(self.sched.swapped),
            "swapping_in": len(self.sched.swapping_in),
            "parked": tuple(self.parked),
            "free_gpu_blocks": self.gpu_mgr.free_blocks(),
            "max_waiting": self.config.max_waiting,
            "draining": self._draining,
            "predicted_ttft_us": self.predicted_ttft_us(depth),
        }

    def export_session(self, handle: int) -> Dict[str, object]:
        """Package a PARKED session for migration to another replica:
        the conversation turns, token history and the CPU reuse copy's
        KV bytes, then release every local resource (``migrate_out``).
        Only parked sessions migrate — a live request's KV is on GPU and
        mid-flight; the router rebalances between turns.

        A session holding a pinned shared prefix exports with
        ``valid_tokens = 0``: its CPU blocks below the prefix-cache
        floor are phantoms (allocated, never written — see
        ``record_swap_out``), so the bytes aren't shippable and the
        target replica re-prefills the turn instead (its own prefix
        cache may well absorb the cost)."""
        req = self.parked.get(handle)
        if req is None:
            raise KeyError(f"no retained session for handle {handle} "
                           "(only parked sessions migrate)")
        meta = self.reuse.export_copy(handle)
        valid = min(meta["valid_tokens"], req.context_tokens) \
            if meta is not None else 0
        if self._shared_tokens(handle) > 0:
            valid = 0
        kv = None
        if valid > 0 and self.pools is not None:
            bs = self.config.block_size
            nblk = (valid + bs - 1) // bs
            ids = np.asarray(meta["block_ids"][:nblk])
            # the park-time d2h gather runs on a swap-manager worker
            # (async swap-out) — order this read behind any in-flight
            # write to the exported blocks, exactly like a local swap-in
            # does via data_deps, or the export ships unlanded bytes.
            # Waits happen OUTSIDE the pool lock: the dep's own copy
            # needs it.  A failed gather queues a copy failure for the
            # handle; those bytes never arrived, so export the session
            # without KV and let the target re-prefill.
            for f in self.swap.data_deps(list(ids)):
                try:
                    f.result()
                except BaseException:
                    pass
            if self.swap.has_failed(handle, "out"):
                self.swap.take_failed_for(handle)
                valid = 0
            else:
                kv = self.pools.cpu[:, :, ids].copy()
        payload = {
            "handle": handle,
            "turns": [(t.prompt_tokens, t.response_tokens,
                       list(t.prompt_ids) if t.prompt_ids is not None
                       else None) for t in req.conv.turns],
            "turn_idx": req.turn_idx,
            "think_time_s": req.conv.think_time_s,
            "context_tokens": req.context_tokens,
            "token_history": list(req.token_history),
            "valid_tokens": valid,
            "kv": kv,
            "priority": self.sched.extern.get(handle),
        }
        self.parked.pop(handle)
        self.reuse.release(handle)
        if self.prefix is not None:
            self.prefix.release(handle)
        self.sched.clear_priority(handle)
        req.state = ReqState.DONE
        self._event(handle, "migrate_out", valid_tokens=valid,
                    context_tokens=payload["context_tokens"])
        return payload

    def import_session(self, payload: Dict[str, object]) -> int:
        """Install a migrated session as a parked (FINISHED) request:
        rebuild the conversation, write the shipped KV bytes into a
        freshly allocated CPU reuse copy and park the handle
        (``migrate_in``) — the next ``continue_session`` admits through
        the ordinary prefix-swap-in path, bit-exact with a session that
        never moved.  The reuse pool may grant less space than shipped
        (contamination of lower-priority copies only goes so far): the
        advertised prefix is trimmed to what was actually installed, and
        a granted-but-unwritable copy is voided rather than advertised."""
        if self._draining:
            self.metrics.rejected += 1
            raise EngineDrainingError(
                "engine is draining: running requests finish, no new "
                "work is admitted")
        handle = int(payload["handle"])
        if handle in self.sched.requests or handle in self.parked:
            raise ValueError(f"handle {handle} already in use")
        turns = [Turn(pt, rt, prompt_ids=(list(ids) if ids is not None
                                          else None))
                 for pt, rt, ids in payload["turns"]]
        conv = Conversation(conv_id=handle,
                            arrival_s=self.clock.now_us / 1e6,
                            turns=turns,
                            think_time_s=payload["think_time_s"])
        req = Request(conv=conv, turn_idx=int(payload["turn_idx"]))
        req.context_tokens = int(payload["context_tokens"])
        req.token_history = list(payload["token_history"])
        req.hist_emitted = len(req.token_history)
        req.retain_kv = True
        req.state = ReqState.FINISHED
        prio = payload.get("priority")
        if prio is not None:
            self.sched.set_priority(handle, prio)
        valid = int(payload["valid_tokens"])
        cpu_ids = self.reuse.import_copy(
            handle, valid, priority=self.sched.priority(handle))
        got = self.reuse.valid_tokens(handle)
        kv = payload.get("kv")
        if got > 0:
            if kv is None:
                # bytes didn't ship (sim mode has none to ship; real
                # mode always pairs valid>0 with kv) — a real-mode copy
                # without its bytes must not be advertised
                if self.pools is not None:
                    self.reuse.invalidate(handle)
            elif self.pools is not None:
                bs = self.config.block_size
                nblk = (got + bs - 1) // bs
                self.pools.cpu[:, :, np.asarray(cpu_ids[:nblk])] = \
                    kv[:, :, :nblk]
        self.parked[handle] = req
        self._event(handle, "migrate_in",
                    valid_tokens=self.reuse.valid_tokens(handle),
                    context_tokens=req.context_tokens)
        return handle

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------

    def _parse_prompt(self, prompt: Union[int, Sequence[int]]
                      ) -> Tuple[int, Optional[List[int]]]:
        if isinstance(prompt, (int, np.integer)):
            if self.pools is not None:
                raise ValueError("real mode needs prompt token ids, "
                                 "not a token count")
            if prompt <= 0:
                raise ValueError(f"empty prompt ({prompt} tokens)")
            return int(prompt), None
        ids = [int(t) for t in prompt]
        if not ids:
            raise ValueError("empty prompt")
        return len(ids), ids

    def _check_sampling(self, sp: SamplingParams) -> None:
        if sp.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {sp.max_tokens}")
        # per-request overrides ride the runner's per-row (B, 3) sampling
        # array (DESIGN.md §3.6) — validate ranges only
        if sp.temperature is not None and sp.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{sp.temperature}")
        if sp.top_k is not None and sp.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {sp.top_k}")
        if sp.top_p is not None and not 0.0 < sp.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {sp.top_p}")

    def _hit_stop(self, req: Request) -> bool:
        """The turn's LAST generated token is one of the request's stop
        ids.  Real mode only (sim prompts/responses carry no token ids);
        ``generated`` guards the prompt's own last token from matching
        before anything was decoded.  Callers must have flushed the
        runner — deferred-sync decode ids land in ``token_history`` only
        on flush."""
        sp = req.sampling
        if (self.pools is None or sp is None or not sp.stop_token_ids
                or req.generated == 0 or not req.token_history):
            return False
        return req.token_history[-1] in sp.stop_token_ids

    def _apply_stop_tokens(self) -> None:
        """Finish running requests whose previous decode produced a stop
        token (``finish_reason="stop"``).  Runs at the top of the decode
        step, BEFORE this iteration's batch is assembled: the stop
        token's KV slot is the turn's last and stays unwritten — exactly
        the pending-token invariant ``_swap_out`` already handles — and
        the request must not decode past it.  The one runner flush is
        shared by every candidate (each flush is a host sync)."""
        cands = [r for r in self.sched.running
                 if (req := self._req(r)).prefill_remaining == 0
                 and req.generated > 0 and req.sampling is not None
                 and req.sampling.stop_token_ids]
        if not cands:
            return
        self.runner.flush()      # histories current before inspection
        for rid in cands:
            if self._hit_stop(self._req(rid)):
                self._contained(rid, self._finish_turn, rid, "stop")

    def _view_sampling(self, req: Request
                       ) -> Optional[Tuple[float, float, float]]:
        """The resolved (temperature, top_k, top_p) row for a request's
        DecodeRequestView: None when the request carries no overrides
        (the runner's engine-default row applies); otherwise each None
        field inherits the engine default."""
        sp = req.sampling
        if (sp is None or (sp.temperature is None and sp.top_k is None
                           and sp.top_p is None)):
            return None
        cfg = self.config
        return (cfg.temperature if sp.temperature is None else sp.temperature,
                cfg.top_k if sp.top_k is None else sp.top_k,
                cfg.top_p if sp.top_p is None else sp.top_p)

    def _budget_tokens(self) -> int:
        return self.gpu_mgr.num_blocks * self.config.block_size

    def _req(self, rid: int) -> Request:
        return self.sched.requests[rid]

    def _out(self, rid: int) -> RequestOutput:
        out = self._outs.get(rid)
        if out is None:
            req = self.sched.requests.get(rid)
            out = RequestOutput(handle=rid,
                                turn=req.turn_idx if req is not None else 0)
            self._outs[rid] = out
        # t_us = the LAST transition's clock instant, stamped as it
        # happens: a later request's synchronous swap stall in the same
        # iteration must not bleed into this one's timestamp (clients
        # schedule think-time wake-ups off the finish instant — replay
        # parity depends on it)
        out.t_us = self.clock.now_us
        return out

    def _credit(self, rid: int, first: bool = False) -> None:
        """Fold one emitted token into this step's output delta."""
        req = self._req(rid)
        out = self._out(rid)
        out.new_tokens += 1
        out.generated = req.generated
        out.context_tokens = req.context_tokens
        if first:
            out.first_token = True
            out.ttft_us = req.ttfts_us[-1]

    def _event(self, rid: int, kind: str, **data) -> None:
        ev = RequestEvent(t_us=self.clock.now_us, handle=rid, kind=kind,
                          data=data)
        if self._event_sink is not None:
            self._event_sink(ev)
        if self.events is not None:
            self.events.append(ev)

    def _record_slo(self, req: Request, reason: str) -> None:
        """Fold the turn's latency record into the per-request SLO
        attainment stats (on finish, abort or drop)."""
        turn = req.current_turn()
        ttft = (req.first_token_us - req.turn_arrival_us) \
            if req.first_token_us is not None else None
        tbts = req.tbts_us[req.tbt_mark:]
        slo = req.slo
        ttft_ok = tbt_frac = None
        if slo is not None:
            if slo.ttft_us is not None and ttft is not None:
                ttft_ok = ttft <= slo.ttft_us
            if slo.tbt_us is not None and tbts:
                tbt_frac = sum(t <= slo.tbt_us for t in tbts) / len(tbts)
        self.metrics.request_stats.append(RequestSLOStats(
            handle=req.rid, turn=req.turn_idx,
            prompt_tokens=turn.prompt_tokens, generated=req.generated,
            ttft_us=ttft,
            mean_tbt_us=(sum(tbts) / len(tbts)) if tbts else 0.0,
            max_tbt_us=max(tbts) if tbts else 0.0,
            ttft_ok=ttft_ok, tbt_ok_frac=tbt_frac, finish_reason=reason))

    def _transfer_runs(self, runs: List[Tuple[int, int]]
                       ) -> List[Tuple[int, int]]:
        """The vLLM baseline issues ONE memcpy per block regardless of
        physical adjacency (Fig. 3a); block-group policies transfer whole
        contiguous runs (Fig. 3b); the Llumnix baseline merges per-block
        copies through a small staging buffer (bounded granularity, one
        transfer per buffer-full — paper §2.2)."""
        pol = self.config.policy
        if pol.use_block_groups:
            return runs
        blocks = runs_to_indices(runs)
        mb = max(1, pol.merge_buffer_blocks)
        if mb == 1:
            return [(b, 1) for b in blocks]
        # staging-buffer merge: one op per <=mb blocks (the buffer copy
        # itself runs at HBM speed — negligible next to the PCIe leg)
        return [(blocks[i], min(mb, len(blocks) - i))
                for i in range(0, len(blocks), mb)]

    def _shared_tokens(self, rid: int) -> int:
        """Block-aligned prefix-cache prefix pinned on GPU for ``rid``."""
        return self.prefix.shared_tokens(rid) if self.prefix is not None \
            else 0

    def _block_table(self, rid: int) -> List[int]:
        """Composed logical->physical block table: the mapped shared
        prefix (prefix-cache nodes) followed by the request's private
        blocks.  Without a mapping this is exactly the manager's table."""
        ids = self.gpu_mgr.request_block_ids(rid)
        if self.prefix is not None:
            shared = self.prefix.blocks_for(rid)
            if shared:
                return shared + ids
        return ids

    def _gpu_alloc_tokens(self, rid: int, n_tokens: int) -> None:
        """allocate+note with prefix-cache eviction fallback: when the
        pool is exhausted, reclaim unreferenced cached leaves (worst
        fairness score first) before the caller falls back to preempting
        a live victim.  Raises OutOfBlocksError when neither helps."""
        if n_tokens <= 0:
            return
        try:
            self.gpu_mgr.allocate_tokens(rid, n_tokens)
        except OutOfBlocksError:
            if self.prefix is None:
                raise
            bs = self.config.block_size
            freed = self.prefix.evict((n_tokens + bs - 1) // bs + 1,
                                      now_us=self.clock.now_us)
            self.metrics.prefix_evictions += freed
            if not freed:
                raise
            self.gpu_mgr.allocate_tokens(rid, n_tokens)
        self.gpu_mgr.note_tokens(rid, n_tokens)

    def _runs_for_tokens(self, rid: int, t0: int, t1: int
                         ) -> List[Tuple[int, int]]:
        """Contiguous GPU block runs covering tokens [t0, t1) of the
        COMPOSED table (shared prefix + private suffix) — swap callers
        only ever pass ranges at or beyond the shared prefix, so the
        resulting runs never contain a pinned shared block."""
        if t1 <= t0:
            return []
        bs = self.config.block_size
        ids = self._block_table(rid)
        b0, b1 = t0 // bs, (t1 + bs - 1) // bs
        blocks = ids[b0:b1]
        runs: List[Tuple[int, int]] = []
        for b in blocks:
            if runs and runs[-1][0] + runs[-1][1] == b:
                runs[-1] = (runs[-1][0], runs[-1][1] + 1)
            else:
                runs.append((b, 1))
        return runs

    # ------------------------------------------------------------------
    # swap operations
    # ------------------------------------------------------------------

    def _swap_out(self, rid: int, keep_copy: bool,
                  last_slot_written: bool = False) -> None:
        """Preempt: move KV to CPU.  With reuse, only the increment beyond
        the valid CPU copy is transferred.  In recompute mode the KV is
        simply dropped (resumption re-prefills the whole context)."""
        req = self._req(rid)
        if self.config.policy.preemption_mode == "recompute":
            self.gpu_mgr.release_request(rid)
            req.resume_tokens = req.context_tokens
            req.prefill_remaining = 0
            req.prefill_is_resume = False
            self.metrics.preemptions += 1
            return
        # Only context_tokens - 1 positions hold written KV: the last
        # slot's K/V is produced by the NEXT decode step (which consumes
        # the pending token as input).  Claiming it would freeze garbage
        # into the CPU copy — once the reuse increment pointer moves past
        # that slot it is never re-copied, and a later swap-in would
        # restore the garbage into attended positions (token corruption
        # whenever a preemption lands on a block-aligned context).  The
        # now-valid slot is picked up by the NEXT increment instead.
        # ``last_slot_written``: a mid-prefill abort has NO pending decode
        # token — every context_tokens position holds chunk-inserted KV,
        # so the whole processed prefix is claimable.
        total = req.context_tokens if last_slot_written \
            else max(req.context_tokens - 1, 0)
        self.reuse.update_priority(rid, self.sched.priority(rid))
        # shared prefix-cache blocks are PINNED on GPU: they are never
        # transferred (floor_tokens excludes [0, shared) from the
        # increment) and never released below — only the private suffix
        # swaps, so preempting one sharer can't tear another's prefix
        shared = self._shared_tokens(rid)
        inc, _cpu_runs = self.reuse.record_swap_out(
            rid, total, requesting_priority=self.sched.priority(rid),
            floor_tokens=shared)
        valid_before = total - inc
        gpu_runs = self._runs_for_tokens(rid, valid_before, total)
        gpu_blocks = runs_to_indices(gpu_runs)
        if gpu_runs:
            # conflicts: blocks we're about to read may be swap-in targets
            self.swap.resolve_conflicts(self.clock, gpu_blocks)
            bs = self.config.block_size
            cpu_ids = self.reuse.mgr.request_block_ids(rid)[
                valid_before // bs:(total + bs - 1) // bs] \
                if self.pools is not None else []
            asynchronous = self.swap.decide_async(
                len(self.sched.running), sum(n for _, n in gpu_runs),
                runs=self._transfer_runs(gpu_runs),
                block_bytes=self.block_bytes, h2d=False,
                now_us=self.clock.now_us)
            self._dispatch_swap(rid, "out", gpu_runs, cpu_ids, asynchronous)
            self.metrics.swap_out_count += 1
        self.gpu_mgr.release_request(rid)
        self.metrics.preemptions += 1

    def _swap_in(self, rid: int) -> bool:
        """Bring a swapped request's KV back to GPU.  Returns True if the
        request is immediately RUNNING (sync), False if in flight."""
        req = self._req(rid)
        tokens = req.context_tokens
        # the shared prefix never left the GPU (pinned) — only the
        # private suffix beyond it is allocated and restored
        shared = self._shared_tokens(rid)
        try:
            self._gpu_alloc_tokens(rid, tokens - shared)
        except OutOfBlocksError:
            # roll back the PARTIAL allocation (allocate_tokens acquires
            # groups incrementally) or the blocks leak into a deadlock
            self.gpu_mgr.release_request(rid)
            return False                     # stays swapped; retry later
        # TOKEN-ordered runs (not request_runs, which sorts by physical
        # start): the data plane pairs these positionally with the
        # token-ordered CPU block list, and a fragmented allocation can
        # hand out groups with descending starts — sorted runs would
        # restore every block into the wrong slot of the block table
        gpu_runs = self._runs_for_tokens(rid, shared, tokens)
        gpu_blocks = runs_to_indices(gpu_runs)
        # the newly allocated target blocks may still be the SOURCE of an
        # in-flight swap-out — synchronize before overwriting them
        self.swap.resolve_conflicts(self.clock, gpu_blocks)
        self.reuse.record_swap_in(rid)
        bs = self.config.block_size
        nblk = (tokens + bs - 1) // bs
        cpu_ids = self.reuse.mgr.request_block_ids(rid)[shared // bs:nblk] \
            if self.pools is not None else []
        asynchronous = self.swap.decide_async(
            len(self.sched.running), sum(n for _, n in gpu_runs),
            runs=self._transfer_runs(gpu_runs),
            block_bytes=self.block_bytes, h2d=True, now_us=self.clock.now_us)
        self._dispatch_swap(rid, "in", gpu_runs, cpu_ids, asynchronous)
        self.metrics.swap_in_count += 1
        self._event(rid, "swap_in", asynchronous=asynchronous,
                    tokens=tokens)
        # Swap-in copies always run INLINE on the dispatching thread
        # (pool-mutating h2d never goes to workers — DESIGN.md §4.3), so
        # a terminal copy failure is known right here, before the request
        # is promoted onto KV that never arrived.
        if self.swap.has_failed(rid, "in"):
            self._recover_swap_in_failure(rid)
            return False
        if asynchronous:
            self.sched.move(rid, ReqState.SWAPPING_IN)
            return False
        self.sched.move(rid, ReqState.RUNNING)
        return True

    def _recover_swap_in_failure(self, rid: int) -> None:
        """Escalation ladder for a swap-in whose copy retries are spent
        (DESIGN.md §7): the GPU-side KV is incomplete and must not be
        decoded against.  Recoverable failures degrade to a
        RECOMPUTE-mode resume — GPU blocks dropped, the request re-enters
        WAITING with ``resume_tokens`` covering its full context, and
        ``_admit_resume`` regenerates the KV from the token history (the
        CPU copy stays valid; nothing corrupted it).  A fatal failure
        ends in a request fault."""
        tasks = self.swap.take_failed_for(rid)
        self.swap.retire_request(rid)    # drop remaining in-flight chunks
        req = self.sched.requests.get(rid)
        if req is None:
            return
        fatal = any(t.failed is not None and "Fatal" in t.failed
                    for t in tasks)
        if fatal:
            err = next(t.failed for t in tasks if t.failed is not None)
            self._fault_request(rid, FatalSwapFault(err))
            return
        self.gpu_mgr.release_request(rid)
        req.resume_tokens = req.context_tokens
        req.prefill_remaining = 0
        req.prefill_is_resume = False
        self.metrics.swap_failure_resumes += 1
        self.sched.move(rid, ReqState.WAITING)
        self._event(rid, "preempt", to="waiting", swap_failure="in")

    def _dispatch_swap(self, rid: int, direction: str,
                       gpu_runs: List[Tuple[int, int]], cpu_ids: List[int],
                       asynchronous: bool) -> None:
        """Dispatch one logical swap as ``swap_chunk_blocks``-sized chunk
        tasks (DESIGN.md §4.3).  Each chunk is its own task on the
        simulated stream with its own GPU-block conflict set and its own
        data-plane future, so (a) the pool lock is released between chunk
        copies — decode steps interleave with a long transfer — and (b) a
        fine-grained conflict sync waits only for the chunk whose blocks
        actually overlap, not the whole swap.  The data plane runs the
        staged run-coalesced path (``PagedPools.copy_*_staged``); a chunk
        whose CPU backing is shorter than its GPU runs (contamination
        capped the reuse copy) trims the copy to the backed prefix, and
        the sim cost still accounts the full dispatched runs.

        Data ordering: a copy touching CPU blocks that a still-queued
        swap-out writes (its own request's increment, or a contamination
        reallocation of a victim's blocks) must wait for that write;
        worker execution is not FIFO, so each chunk carries the
        overlapping out-futures as explicit dependencies (awaited before
        the pool lock — see ``MultithreadingSwapManager.data_deps``)."""
        pools = self.pools
        pos = 0
        for runs_c in split_runs(gpu_runs, self.config.swap_chunk_blocks):
            cnt = sum(n for _, n in runs_c)
            copy_fn = None
            cpu_c: List[int] = []
            deps: List = []
            if pools is not None:
                cpu_c = cpu_ids[pos:pos + cnt]
                if cpu_c:
                    deps = self.swap.data_deps(cpu_c)
                    data_runs = trim_runs(runs_c, len(cpu_c))
                    if direction == "out":
                        copy_fn = (lambda r=data_runs, c=cpu_c:
                                   pools.copy_out_staged(r, c))
                    else:
                        copy_fn = (lambda r=data_runs, c=cpu_c:
                                   pools.copy_in_staged(c, r))
            pos += cnt
            # deterministic fault injection (DESIGN.md §7): one draw per
            # chunk task, keyed by the per-(rid, direction) dispatch
            # sequence number — stable across runs and thread timing.
            # Wrapping also when copy_fn is None gives sim mode the same
            # failure surface as the real data plane.
            stall_us = 0.0
            if self.faults.enabled:
                seq = self._swap_seq.get((rid, direction), 0)
                self._swap_seq[(rid, direction)] = seq + 1
                spec = self.faults.swap_fault(rid, direction, seq)
                if spec is not None:
                    if spec.kind is not None:
                        copy_fn = FaultInjector.wrap_copy(spec, copy_fn)
                    stall_us = spec.stall_us
            self.swap.dispatch(self.clock, rid, direction,
                               self._transfer_runs(runs_c), self.block_bytes,
                               runs_to_indices(runs_c),
                               asynchronous=asynchronous, copy_fn=copy_fn,
                               copy_deps=deps, cpu_blocks=cpu_c,
                               extra_latency_us=stall_us)

    # ------------------------------------------------------------------
    # admission / prefill
    # ------------------------------------------------------------------

    def _preempt(self, rid: int) -> None:
        """Swap mode: KV to CPU, request -> SWAPPED.  Recompute mode: KV
        dropped, request -> WAITING for re-prefill.  A real-mode request
        caught MID chunked prefill has no pending decode token to resume
        from — it aborts to WAITING instead (the processed prefix is kept
        as a CPU reuse copy; re-admission opens a fresh prefill)."""
        req = self._req(rid)
        if self.pools is not None and req.prefill_remaining > 0:
            self._abort_chunked_prefill(rid)
            return
        self._swap_out(rid, keep_copy=True)
        if self.config.policy.preemption_mode == "recompute":
            self.sched.move(rid, ReqState.WAITING)
            self._event(rid, "preempt", to="waiting")
        else:
            self.sched.move(rid, ReqState.SWAPPED)
            self._event(rid, "preempt", to="swapped")

    def _abort_chunked_prefill(self, rid: int) -> None:
        """Mid-prefill preemption (real mode, DESIGN.md §5): drop the
        runner's carry buffers, keep the processed prefix as a CPU reuse
        copy (``context_tokens`` counts exactly the chunk-inserted
        tokens), roll back the turn's prompt extension and return the
        request to WAITING — the next ``_admit`` re-extends the turn's
        stored prompt and opens a fresh chunked prefill, reusing the
        saved prefix up to ``prefix_tokens``.

        A chunked recompute-mode RESUME (``prefill_is_resume``) has no
        prompt extension to roll back and no prefix worth keeping — the
        partial recompute is dropped whole and ``resume_tokens`` snaps
        back to the full context (a resume restarts from scratch)."""
        req = self._req(rid)
        self.runner.prefill_abort(rid)
        if req.prefill_is_resume:
            # recompute-mode branch of _swap_out: release + resume_tokens
            self._swap_out(rid, keep_copy=True)
            self.sched.move(rid, ReqState.WAITING)
            self._event(rid, "preempt", to="waiting", mid_prefill=True)
            return
        self._swap_out(rid, keep_copy=True, last_slot_written=True)
        req.prefill_remaining = 0
        req.resume_tokens = 0          # recompute mode: fresh _admit, not
        #                                a resume (no first token emitted)
        n_prompt = req.current_turn().prompt_tokens
        del req.token_history[len(req.token_history) - n_prompt:]
        self.sched.move(rid, ReqState.WAITING)
        self._event(rid, "preempt", to="waiting", mid_prefill=True)

    def _admit(self, rid: int) -> bool:
        """WAITING -> RUNNING via prefill (+prefix swap-in if CPU copy).
        Recompute-preempted requests re-prefill their whole context."""
        req = self._req(rid)
        if req.resume_tokens:
            return self._admit_resume(rid)
        turn = req.current_turn()
        # two sources of already-present KV: the GPU-pinned shared prefix
        # [0, shared) — no transfer at all — and the CPU reuse copy,
        # restored for [shared, reused).  ``reused`` >= ``shared`` by the
        # floor invariant (record_swap_out keeps valid_tokens at or above
        # the pinned prefix), so the two ranges tile.
        shared = self._shared_tokens(rid)
        reused = min(self.reuse.valid_tokens(rid), req.prefix_tokens)
        reused = max(reused, shared)
        new_ctx = req.prefix_tokens + turn.prompt_tokens
        try:
            self._gpu_alloc_tokens(rid, new_ctx - shared)
        except OutOfBlocksError:
            self.gpu_mgr.release_request(rid)   # roll back partial alloc
            return False
        gpu_runs = self.gpu_mgr.request_runs(rid)
        gpu_blocks = runs_to_indices(gpu_runs)
        self.swap.resolve_conflicts(self.clock, gpu_blocks)
        # prefix-with-prefill: reused tokens are swapped in, the rest computed
        if reused > shared:
            bs = self.config.block_size
            n_reused_blocks = (reused + bs - 1) // bs
            runs_in = self._runs_for_tokens(rid, shared, reused)
            cpu_ids = self.reuse.mgr.request_block_ids(rid)[
                shared // bs:n_reused_blocks] \
                if self.pools is not None else []
            self._dispatch_swap(rid, "in", runs_in, cpu_ids,
                                asynchronous=False)  # prefill needs it NOW
            if self.swap.has_failed(rid, "in"):
                # prefix restore failed terminally: degrade to a
                # reused=0 full prefill (DESIGN.md §7).  Void the copy —
                # this admission must not advertise a prefix it could
                # not restore — roll back the allocation and stay
                # WAITING; the next admission recomputes everything.
                # A FATAL failure propagates to the containment wrapper
                # and faults the request.
                tasks = self.swap.take_failed_for(rid)
                self.gpu_mgr.release_request(rid)
                self.reuse.invalidate(rid)
                fatal = [t.failed for t in tasks
                         if t.failed is not None and "Fatal" in t.failed]
                if fatal:
                    raise FatalSwapFault(fatal[0])
                return False
        # prefill compute for the non-reused tokens
        new_tokens = new_ctx - reused
        chunk = self.config.policy.chunked_prefill_tokens
        if chunk and self.pools is None and new_tokens > chunk:
            # BEYOND-PAPER (Sarathi-style): spread the prefill over
            # iterations so long prompts stop stalling the decode batch
            req.prefill_remaining = new_tokens
            req.context_tokens = new_ctx
            self.metrics.prefills += 1
            self.sched.move(rid, ReqState.RUNNING)
            self._event(rid, "admit", reused=reused, chunked=True)
            return True
        if chunk and self.pools is not None \
                and new_ctx - (reused - reused % self.config.block_size) \
                > chunk:
            # REAL-mode chunked prefill (DESIGN.md §5): the runner opens a
            # chunked-prefill state machine; step 5 advances it one
            # bucketed chunk per iteration between decode steps, so the
            # long prompt never freezes the decode batch.  The carry is
            # seeded from the restored ``reused`` prefix (bit-identical
            # to recomputing it), so the gate — like the compute and the
            # billing — covers only the tail beyond the block-aligned
            # reused prefix.
            self._begin_real_chunked_prefill(req, reused)
            self.metrics.prefills += 1
            self.sched.move(rid, ReqState.RUNNING)
            self._event(rid, "admit", reused=reused, chunked=True)
            return True
        t_prefill = self.iter_cost.prefill_us(max(new_tokens, 1))
        self.clock.advance(t_prefill)
        req.context_tokens = new_ctx
        self.metrics.prefills += 1
        if self.pools is not None:
            self._real_prefill(req, reused)
        self.sched.move(rid, ReqState.RUNNING)
        self._event(rid, "admit", reused=reused, chunked=False)
        self._emit_first_token(rid)
        return True

    def _allocate_token_slot(self, rid: int, skipped: Optional[set] = None
                             ) -> bool:
        """Allocate the one-token block slot the next decode will write
        KV into: on OutOfBlocksError preempt a victim (recorded in
        ``skipped`` so the caller drops it from this iteration's decode
        set) and retry; synchronize swap conflicts on any block the
        allocation acquired — it may be a just-freed block an async d2h
        copy is still reading (torn victim KV otherwise).  Returns False
        when the pool stays full."""
        before = set(self.gpu_mgr.request_block_ids(rid))
        try:
            self._gpu_alloc_tokens(rid, 1)    # evicts cached leaves first
        except OutOfBlocksError:
            victim = self._find_victim(exclude={rid})
            if victim is None:
                return False
            self._preempt(victim)
            if skipped is not None:
                skipped.add(victim)
            try:
                self._gpu_alloc_tokens(rid, 1)
            except OutOfBlocksError:
                return False
        grown = [b for b in self.gpu_mgr.request_block_ids(rid)
                 if b not in before]
        if grown:
            self.swap.resolve_conflicts(self.clock, grown)
        return True

    def _emit_first_token(self, rid: int) -> None:
        """The prompt's last position produced the response's first token."""
        if self.faults.enabled and self.faults.poisoned(rid):
            # poison hook: this request's compute path blows up (stands
            # in for a NaN logit / tokenizer crash); the containment
            # wrapper faults exactly this request
            self.faults.note_poison_fired()
            raise PoisonError(f"injected poison request (handle {rid})")
        req = self._req(rid)
        req.context_tokens += 1
        # stop check inline (not _hit_stop: ``generated`` is incremented
        # by finish_token below) — history IS current here: real-mode
        # prefill emits the first token synchronously
        sp = req.sampling
        first_stop = bool(self.pools is not None and sp is not None
                          and sp.stop_token_ids and req.token_history
                          and req.token_history[-1] in sp.stop_token_ids)
        if req.turn_done() or first_stop:
            # max_tokens == 1 (or the prompt's last position produced a
            # stop id straight away): the whole response is this one
            # token — no next-token slot, no decode step (without this
            # the decode loop over-generated by one token)
            reason = "length" if req.turn_done() else "stop"
            req.finish_token(self.clock.now_us)
            self.metrics.ttfts_us.append(req.ttfts_us[-1])
            self.metrics.total_tokens += 1
            self._credit(rid, first=True)
            self._event(rid, "first_token", ttft_us=req.ttfts_us[-1])
            self._finish_turn(rid, reason)
            return
        if not self._allocate_token_slot(rid):
            # a rebalance-time admission landed on a pool that stays full
            # even after the victim fallback: bounce THIS request; the
            # emitted token stays in its history and the resumption path
            # (swap-in / re-prefill) allocates its next-token slot
            req.finish_token(self.clock.now_us)
            self.metrics.ttfts_us.append(req.ttfts_us[-1])
            self.metrics.total_tokens += 1
            self._credit(rid, first=True)
            self._event(rid, "first_token", ttft_us=req.ttfts_us[-1])
            self._preempt(rid)
            return
        req.finish_token(self.clock.now_us)
        self.metrics.ttfts_us.append(req.ttfts_us[-1])
        self.metrics.total_tokens += 1
        self._credit(rid, first=True)
        self._event(rid, "first_token", ttft_us=req.ttfts_us[-1])

    def _admit_resume(self, rid: int) -> bool:
        """Re-admit a recompute-preempted request: re-prefill the full
        context (the recomputation cost the paper's swap mode avoids).
        With chunked prefill enabled the recomputation runs through the
        SAME chunked state machine as a fresh admission — one chunk per
        engine iteration interleaved with decode steps — instead of one
        monolithic re-prefill iteration; the completion emits NO first
        token (``prefill_is_resume``): the request already holds its
        pending token and resumes decoding."""
        req = self._req(rid)
        ctx = req.resume_tokens
        shared = self._shared_tokens(rid)    # pinned prefix: still resident
        try:
            self._gpu_alloc_tokens(rid, ctx - shared)
        except OutOfBlocksError:
            self.gpu_mgr.release_request(rid)   # roll back partial alloc
            return False
        # conflict sync covers only the newly allocated PRIVATE blocks
        # (pinned shared blocks are never swap sources or targets)
        self.swap.resolve_conflicts(
            self.clock, self.gpu_mgr.request_block_ids(rid))
        gpu_blocks = self._block_table(rid)
        # A sim-mode recompute preemption can land MID chunked prefill —
        # before the turn's first token existed (real mode can't reach
        # here: _abort_chunked_prefill reroutes those to a fresh admit).
        # Such a resume must still EMIT the first token on completion;
        # a resume of a decoding request (first_token_us set) must not.
        emitted = req.first_token_us is not None
        chunk = self.config.policy.chunked_prefill_tokens
        if chunk and ctx > chunk:
            if self.pools is not None:
                # the runner recomputes KV for all but the pending last
                # token, chunk by chunk; ``context_tokens`` stays at the
                # full context throughout (the blocks are allocated and
                # the token positions fixed — only the KV is re-filling)
                view = DecodeRequestView(rid, gpu_blocks, req.token_history,
                                         sampling=self._view_sampling(req))
                if shared:
                    # seed the carry from the pinned prefix: recomputing
                    # it would scatter into shared blocks
                    with self.swap._pool_lock:
                        req.prefill_remaining = self.runner.prefill_begin(
                            view, emit_first=False, reused_tokens=shared,
                            pool=self.pools.gpu)
                else:
                    req.prefill_remaining = self.runner.prefill_begin(
                        view, emit_first=False)
            else:
                req.prefill_remaining = ctx
            req.prefill_is_resume = emitted
            req.resume_tokens = 0
            self.metrics.prefills += 1
            self.sched.move(rid, ReqState.RUNNING)
            self._event(rid, "resume", tokens=ctx, chunked=True)
            return True
        self.clock.advance(self.iter_cost.prefill_us(max(ctx, 1)))
        self.metrics.prefills += 1
        if self.pools is not None:
            # recompute: regenerate KV for the already-known history
            self._real_reprefill(req)
        req.resume_tokens = 0
        self.sched.move(rid, ReqState.RUNNING)
        self._event(rid, "resume", tokens=ctx, chunked=False)
        if not emitted:
            self._emit_first_token(rid)
        return True

    def _real_reprefill(self, req: Request) -> None:
        """Recompute-preemption resume: the runner regenerates KV for the
        already-known history (all but the last token — its K/V is written
        by the next decode step, which consumes hist[-1] as input) and
        inserts it through its persistent block tables.  A pinned shared
        prefix seeds the carry instead of being recomputed — recomputing
        it would scatter into blocks other sharers are reading."""
        rid = req.rid
        view = DecodeRequestView(rid, self._block_table(rid),
                                 req.token_history,
                                 sampling=self._view_sampling(req))
        shared = self._shared_tokens(rid)
        if shared:
            with self.swap._pool_lock:   # the carry seed reads the pool
                total = self.runner.prefill_begin(
                    view, emit_first=False, reused_tokens=shared,
                    pool=self.pools.gpu)
        else:
            total = self.runner.prefill_begin(view, emit_first=False)
        # KV compute runs OUTSIDE the pool lock (it never touches the
        # pool); only the scatter + rebind serialize with swap copies
        staged = self.runner.prefill_chunk_compute(rid, total)
        with self.swap._pool_lock:
            self.pools.gpu = self.runner.prefill_insert(
                view, self.pools.gpu, staged)

    # ------------------------------------------------------------------
    # real-model data plane
    # ------------------------------------------------------------------

    def _extend_prompt(self, req: Request) -> DecodeRequestView:
        """Extend the token history with the current turn's prompt ids
        (supplied by the client at add_request/continue_session time)
        and build the runner view for its prefill."""
        rid = req.rid
        hist = req.token_history
        self.runner.flush()          # history must be current before extend
        turn = req.current_turn()
        assert turn.prompt_ids is not None, \
            "real mode needs prompt token ids (add_request got a count?)"
        hist.extend(turn.prompt_ids)
        req.hist_emitted = len(hist)     # stream deltas = response tokens
        return DecodeRequestView(rid, self._block_table(rid),
                                 hist, sampling=self._view_sampling(req))

    def _real_prefill(self, req: Request, reused: int = 0) -> None:
        """Runner-managed whole-prompt prefill: extend the turn's prompt,
        then the runner computes KV, inserts it through its persistent
        block tables (device-side scatter — no host KV round-trip) and
        emits the first response token (device-side sampling; greedy at
        temperature 0).  With ``reused`` > 0 the carry is seeded from the
        prefix the admission just restored into the pool
        (``ops.seed_prefill_carry`` — bit-identical to recomputing), so
        the monolithic path — like the chunked one — never recomputes a
        re-admitted prefix."""
        view = self._extend_prompt(req)
        rid = req.rid
        if reused > 0:
            with self.swap._pool_lock:   # the carry seed reads the pool
                total = self.runner.prefill_begin(
                    view, emit_first=True, reused_tokens=reused,
                    pool=self.pools.gpu)
        else:
            total = self.runner.prefill_begin(view, emit_first=True)
        # KV compute + first-token draw run OUTSIDE the pool lock; only
        # the scatter + rebind serialize with swap copies
        staged = self.runner.prefill_chunk_compute(rid, total)
        self.runner.prefill_emit_first(rid)
        with self.swap._pool_lock:
            self.pools.gpu = self.runner.prefill_insert(
                view, self.pools.gpu, staged)
        self._prefix_insert(req)

    def _prefix_insert(self, req: Request) -> None:
        """Donate a freshly prefilled FIRST-turn prompt's full blocks to
        the prefix tree (the block holding the last prompt token doubles
        as the first decode slot and stays private).  Only turn 0
        qualifies: later turns' prompts sit beyond decode tokens unique
        to this conversation, so no other request could ever match them.
        The donated blocks stay physically in place — the request keeps
        using them, now as mapped shared blocks."""
        if self.prefix is None or req.turn_idx != 0:
            return
        ids = req.current_turn().prompt_ids
        if not ids:
            return
        self.prefix.insert(req.rid, list(ids),
                           now_us=self.clock.now_us,
                           priority=self.sched.priority(req.rid))

    def _begin_real_chunked_prefill(self, req: Request,
                                    reused: int) -> None:
        """Open the runner's chunked-prefill state machine for a newly
        admitted request (DESIGN.md §5).  The carry is seeded from the
        ``reused`` prefix the admission just restored into the pool, so
        only the non-reused tail is computed AND billed — matching the
        sim-mode chunked accounting (the prefix's transfer cost was
        already charged by the synchronous swap-in).  ``context_tokens``
        tracks the tokens whose KV is resident and claimable (seeded
        prefix + chunk inserts), so a mid-prefill preemption swaps out
        exactly the processed prefix; ``prefill_remaining`` counts the
        tokens left to compute — step 5 advances one chunk per
        iteration."""
        view = self._extend_prompt(req)
        with self.swap._pool_lock:      # the carry seed reads the pool
            req.prefill_remaining = self.runner.prefill_begin(
                view, emit_first=True, reused_tokens=reused,
                pool=self.pools.gpu)
        req.context_tokens = len(req.token_history) - req.prefill_remaining

    def _real_prefill_chunk(self, rid: int) -> int:
        """Advance one request's in-flight chunked prefill by one chunk:
        compute OUTSIDE the pool lock (the forward touches no pool
        state), insert the chunk's KV under it, and on the final chunk
        emit the first token.  Non-final chunks are trimmed to block-size
        multiples so every insert stays block-aligned.  A chunked RESUME
        (recompute re-prefill) neither advances ``context_tokens`` (the
        full context was re-allocated up front) nor emits a first token.
        Returns the chunk token count (charged to the sim clock by the
        caller)."""
        if self.faults.enabled and self.faults.poisoned(rid):
            self.faults.note_poison_fired()
            raise PoisonError(f"injected poison request (handle {rid})")
        req = self._req(rid)
        bs = self.config.block_size
        n = min(self.config.policy.chunked_prefill_tokens,
                req.prefill_remaining)
        if n < req.prefill_remaining:
            n -= n % bs
            if n == 0:                 # chunk smaller than one block
                n = min(bs, req.prefill_remaining)
        staged = self.runner.prefill_chunk_compute(rid, n)
        with self.swap._pool_lock:
            self.pools.gpu = self.runner.prefill_chunk_insert(
                rid, self.pools.gpu, staged)
        req.prefill_remaining -= n
        if not req.prefill_is_resume:
            req.context_tokens += n
        if req.prefill_remaining == 0:
            self.runner.prefill_finish(rid)
            if req.prefill_is_resume:
                req.prefill_is_resume = False
            else:
                self._prefix_insert(req)
                self._emit_first_token(rid)
        return n

    def _real_decode(self, rids: List[int]) -> None:
        """Batched paged decode through the device-resident runner: only
        changed block-table rows are uploaded, the pool is donated, and
        the next-token host sync is deferred to the next iteration's
        decode (overlapping this step with the next control plane)."""
        views = [DecodeRequestView(r, self._block_table(r),
                                   self._req(r).token_history,
                                   sampling=self._view_sampling(self._req(r)))
                 for r in rids]
        with self.swap._pool_lock:
            self.pools.gpu = self.runner.decode(views, self.pools.gpu)

    # ------------------------------------------------------------------
    # the iteration
    # ------------------------------------------------------------------

    def step(self, until_us: Optional[float] = None) -> List[RequestOutput]:
        """Advance the engine one iteration and return this step's
        incremental per-request outputs (token deltas, first-token and
        finish markers — aborts issued since the previous step are
        folded in too).  ``until_us``: the caller's next known event
        (arrival, wake-up); an idle engine advances its clock no further
        than that, so open-world drivers control time without the engine
        polling."""
        t_wall0 = time.perf_counter()
        m = self.metrics
        bs = self.config.block_size
        prefills_before = m.prefills

        # Step 0 (robustness, DESIGN.md §7): watchdog-escalate stuck swap
        # tasks, surface copy retries as events, run the recovery ladder
        # over terminally failed copies, and apply this iteration's
        # injected allocation pressure.
        if self.config.swap_watchdog_us > 0:
            for t in self.swap.watchdog_check(self.clock,
                                              self.config.swap_watchdog_us):
                self._event(t.req_id, "retry", watchdog=True,
                            direction=t.direction)
        for rec in self.swap.drain_retries():
            self._event(rec["rid"], "retry", direction=rec["direction"],
                        attempt=rec["attempt"], error=rec["error"])
        self._process_failed_swaps()
        if self.faults.enabled:
            self._apply_alloc_pressure()

        # Step 1: completed async swap-ins -> running.  A swap-in may
        # consist of several chunk tasks, and a fine-grained conflict sync
        # (resolve_conflicts) can retire tasks between polls; a request is
        # resident — promote it — exactly when NO in-flight swap-in task
        # remains for it (it would otherwise be stranded in SWAPPING_IN).
        self.swap.poll_completed(self.clock)
        if self.sched.swapping_in:
            ongoing = {t.req_id for t in self.swap.ongoing_swap_in}
            for rid in list(self.sched.swapping_in):
                if rid not in ongoing:
                    self.sched.move(rid, ReqState.RUNNING)
                    self._event(rid, "promote")

        # Step 2: budget safeguard — a request whose working set exceeds
        # the whole GPU pool can never be served; fail it instead of
        # deadlocking the queue.
        budget = self._budget_tokens()
        for rid in list(self.sched.waiting):
            req = self._req(rid)
            need = max(req.target_tokens,
                       req.prefix_tokens + req.current_turn().prompt_tokens
                       + bs)
            if need > budget:
                import warnings
                warnings.warn(f"request {rid} needs {need} tokens "
                              f"> pool budget {budget}; dropping")
                self.abort(rid, reason="dropped")

        # Step 3: priority update -> rebalance
        updated = self.sched.step_trace()
        if updated:
            desired = self.sched.desired_running(
                self._budget_tokens(), bs,
                batch_bucket=(self.runner.batch_bucket
                              if self.runner is not None else 0))
            to_preempt, to_swap_in, to_admit = \
                self.sched.classify_rebalance(desired)
            for rid in to_preempt:
                self._contained(rid, self._preempt, rid)
            for rid in to_swap_in:
                self._contained(rid, self._swap_in, rid)
            for rid in to_admit:
                self._contained(rid, self._admit, rid)

        # Step 4: opportunistic admission (space permitting), capped at
        # the batch-bucket-aware target instead of max_running outright
        for rid in sorted(list(self.sched.waiting),
                          key=self.sched.priority, reverse=True):
            free_tok = self.gpu_mgr.free_blocks() * bs
            req = self._req(rid)
            # the pinned shared prefix is already resident: only the
            # private tail needs free space
            need = (req.prefix_tokens + req.current_turn().prompt_tokens
                    + bs - self._shared_tokens(rid))
            if need > free_tok \
                    or len(self.sched.running) + len(self.sched.swapping_in) \
                    >= self._admission_target():
                break
            self._contained(rid, self._admit, rid)
        for rid in list(self.sched.swapped):
            if len(self.sched.running) + len(self.sched.swapping_in) \
                    >= self._admission_target():
                break
            free_tok = self.gpu_mgr.free_blocks() * bs
            if (self._req(rid).context_tokens + bs
                    - self._shared_tokens(rid)) > free_tok:
                break
            self._contained(rid, self._swap_in, rid)

        # Step 5: decode one token for the running batch.  Requests with
        # an in-flight chunked prefill advance their prefill instead of
        # decoding (one chunk per iteration, piggybacked on the batch).
        # First retire stop-token hits from the PREVIOUS decode — their
        # last token ended the turn and must not enter this batch.
        if self.pools is not None:
            self._apply_stop_tokens()
        rids = [r for r in self.sched.running
                if self._req(r).prefill_remaining == 0]
        prefilling = [r for r in self.sched.running
                      if self._req(r).prefill_remaining > 0]
        chunk_tokens = 0
        if prefilling:
            # at most ONE prompt chunk per iteration (highest priority
            # first) interleaved with the decode batch — the Sarathi-style
            # fairness lever bounding tail TBT during admission bursts
            chunk = self.config.policy.chunked_prefill_tokens
            rid_p = max(prefilling, key=self.sched.priority)
            reqp = self._req(rid_p)
            if self.pools is not None:
                chunk_tokens = self._contained(
                    rid_p, self._real_prefill_chunk, rid_p) or 0
            else:
                chunk_tokens = min(chunk, reqp.prefill_remaining)
                reqp.prefill_remaining -= chunk_tokens
                if reqp.prefill_remaining == 0:
                    if reqp.prefill_is_resume:
                        reqp.prefill_is_resume = False
                    else:
                        self._contained(rid_p, self._emit_first_token,
                                        rid_p)
        if rids or prefilling:
            # block allocation for the new token (conflict-checked in
            # _allocate_token_slot).  Iterate over a SNAPSHOT and track a
            # ``skipped`` set: a victim preempted from inside the batch
            # must not shift the iteration (the old in-place
            # ``rids.remove`` silently skipped the next request's
            # allocation while still decoding and crediting it), and a
            # request whose allocation failed must sit this iteration out
            # entirely — decoding it anyway would advance
            # ``context_tokens`` past its block table (desync).
            skipped: set = set()
            for rid in list(rids):
                if rid in skipped or rid not in self.sched.running:
                    continue       # preempted as a victim earlier this loop
                if not self._contained(rid, self._allocate_token_slot,
                                       rid, skipped):
                    skipped.add(rid)           # retry next iteration
            decode_rids = [r for r in rids if r not in skipped
                           and r in self.sched.running]
            if decode_rids and self.pools is not None:
                self._real_decode(decode_rids)
            total_ctx = sum(self._req(r).context_tokens for r in decode_rids)
            t_iter = self.iter_cost.decode_iter_us(len(decode_rids),
                                                   total_ctx)
            if chunk_tokens:
                t_iter += self.iter_cost.prefill_us(chunk_tokens) \
                    - self.iter_cost.hw.iter_overhead_us
            if not decode_rids and not chunk_tokens:
                # everyone was skipped (pool exhausted, no victim): charge
                # the iteration overhead so the sim clock still advances
                t_iter = self.iter_cost.hw.iter_overhead_us
            if decode_rids:
                # feed the adaptive swap profiler the overlap window one
                # decode iteration offers (decide_async cost model)
                self.swap.note_decode_iter(t_iter)
            self.clock.advance(t_iter)
            for rid in decode_rids:
                req = self._req(rid)
                req.context_tokens += 1
                req.finish_token(self.clock.now_us)
                m.total_tokens += 1
                if req.tbts_us:
                    m.tbts_us.append(req.tbts_us[-1])
                self._credit(rid)
                if req.turn_done():
                    self._finish_turn(rid)
            m.iter_records.append((self.clock.now_us, len(decode_rids),
                                   t_iter, m.prefills - prefills_before,
                                   self.swap.total_stall_us))
        else:
            # idle: advance to the next event (the caller's next arrival
            # or wake-up, or an in-flight swap-in completing)
            self._advance_idle(until_us)

        m.iterations += 1
        m.total_time_us = self.clock.now_us
        m.ctx_switch_stall_us = self.swap.total_stall_us

        # run the recovery ladder again over failures surfaced DURING
        # this step (inline sim copies, fast workers): a terminally
        # failed copy in the engine's final step would otherwise sit in
        # the failed queue forever — the drain loop stops calling step()
        self._process_failed_swaps()

        # injected allocation pressure dies with the last live request —
        # an emptied engine must reclaim the phantom reserve THIS step
        # (the drain loop stops calling step() once has_work is False)
        if self._pressure_blocks and not self.sched.requests:
            self.gpu_mgr.release_request(self._PRESSURE_RID)
            self._pressure_blocks = 0

        # invariant sanitizer (DESIGN.md §7): cross-layer state check
        # every N steps; raises InvariantViolation with a state dump —
        # deliberately NOT contained (corrupt engine state has no single
        # owning request; continuing would serve garbage)
        n_inv = self.config.check_invariants_every
        if n_inv > 0 and m.iterations % n_inv == 0:
            check_engine_invariants(self)
            m.invariant_checks += 1

        m.callstack_wall_s += time.perf_counter() - t_wall0
        return self._collect_outputs()

    def _process_failed_swaps(self) -> None:
        """Recovery ladder over terminally failed copies surfaced since
        the last step (worker d2h gathers fail ASYNCHRONOUSLY — inline
        swap-in failures were already handled at their dispatch site;
        this drain is their backstop).  A failed swap-OUT means the CPU
        copy's increment never arrived: the copy is voided, and a
        SWAPPED request whose resumption depended on it converts to a
        recompute-mode resume (KV regenerated from token history).
        Fatal failures end in a request fault; failures of finished /
        aborted requests need nothing beyond the voided copy."""
        for t in self.swap.take_failed():
            rid = t.req_id
            req = self.sched.requests.get(rid)
            if t.direction == "in":
                if req is not None:
                    self._recover_swap_in_failure(rid)
                continue
            self.reuse.invalidate(rid)
            if req is None:
                continue        # finished/parked/aborted: copy voided
            if t.failed is not None and "Fatal" in t.failed:
                self._fault_request(rid, FatalSwapFault(t.failed))
                continue
            if req.state is ReqState.SWAPPED:
                # the CPU KV this request would swap back in is
                # incomplete: resume by recomputation instead
                req.resume_tokens = req.context_tokens
                req.prefill_remaining = 0
                req.prefill_is_resume = False
                self.metrics.swap_failure_resumes += 1
                self.sched.move(rid, ReqState.WAITING)
                self._event(rid, "preempt", to="waiting",
                            swap_failure="out")

    _PRESSURE_RID = -7777       # phantom owner of injected-reserve blocks

    def _apply_alloc_pressure(self) -> None:
        """Allocation-pressure injection: a phantom request holds the
        plan's reserved blocks for the spike window, so the shortage
        flows through every real path — admission gating, token-slot
        allocation, victim preemption — rather than a bolted-on check.
        Released as the window closes (and whenever the engine is empty,
        so drained runs can never leak phantom blocks)."""
        want = self.faults.reserved_blocks(self.metrics.iterations) \
            if self.sched.requests else 0
        if want == self._pressure_blocks:
            return
        self.gpu_mgr.release_request(self._PRESSURE_RID)
        self._pressure_blocks = 0
        if want > 0:
            try:
                bs = self.config.block_size
                self.gpu_mgr.allocate_tokens(self._PRESSURE_RID, want * bs)
                self.gpu_mgr.note_tokens(self._PRESSURE_RID, want * bs)
                self._pressure_blocks = want
            except OutOfBlocksError:
                # pool already under real pressure: the spike is moot
                self.gpu_mgr.release_request(self._PRESSURE_RID)

    def _collect_outputs(self) -> List[RequestOutput]:
        outs = list(self._outs.values())
        self._outs = {}
        if self.stream_tokens and self.runner is not None:
            # materialize this step's token ids for streaming clients —
            # the one deliberate host sync of the online path (the
            # deferred-sync overlap is the price of live token deltas)
            self.runner.flush()
        for out in outs:
            req = self.sched.requests.get(out.handle) \
                or self.parked.get(out.handle)
            if (self.stream_tokens and req is not None
                    and self.pools is not None and out.token_ids is None):
                hist = req.token_history
                out.token_ids = hist[req.hist_emitted:]
                req.hist_emitted = len(hist)
        return outs

    def _finish_turn(self, rid: int, reason: str = "length") -> None:
        req = self._req(rid)
        if self.runner is not None:
            self.runner.flush()      # materialize the turn's last tokens
            # free the decode row eagerly (same as abort): the lazy
            # `_update_rows` release only runs at the NEXT decode batch,
            # and a finished request must not hold a row (or trip the
            # sanitizer's D2 check) waiting for a decode that may never
            # come
            self.runner.release(rid)
        if reason == "length" and self._hit_stop(req):
            # the turn's LAST token (max_tokens boundary) was a stop id:
            # the response ended by matching, not by running out — the
            # stop reason wins (clients branch on it for follow-ups)
            reason = "stop"
        if req.token_history:
            self._token_hist_by_conv[rid] = list(req.token_history)
        # retain the KV copy for the next turn (reuse mechanism); baseline
        # swaps the whole context out; recompute mode just frees
        self._swap_out(rid, keep_copy=True)
        req.resume_tokens = 0       # a follow-up turn is a fresh prefill
        for q in (self.sched.waiting, self.sched.running,
                  self.sched.swapped, self.sched.swapping_in):
            if rid in q:
                q.remove(rid)
        self._record_slo(req, reason)
        out = self._out(rid)
        out.finished, out.finish_reason = True, reason
        out.generated, out.context_tokens = req.generated, req.context_tokens
        if self.stream_tokens and self.pools is not None:
            # fill the final delta HERE (history is flushed above): a
            # non-retained request is gone before _collect_outputs runs
            out.token_ids = req.token_history[req.hist_emitted:]
            req.hist_emitted = len(req.token_history)
        if req.retain_kv:
            req.state = ReqState.FINISHED
            self.parked[rid] = req
            del self.sched.requests[rid]
            self._event(rid, "finish", retained=True, tokens=req.generated,
                        reason=reason)
        else:
            req.state = ReqState.DONE
            self.reuse.release(rid)
            if self.prefix is not None:
                self.prefix.release(rid)    # unpin the shared prefix
            del self.sched.requests[rid]
            self.sched.clear_priority(rid)
            self._event(rid, "finish", retained=False, tokens=req.generated,
                        reason=reason)

    def _advance_idle(self, until_us: Optional[float] = None) -> None:
        events = [t.done_at for t in self.swap.ongoing_swap_in]
        if until_us is not None:
            events.append(until_us)
        if events:
            self.clock.advance_to(max(min(events), self.clock.now_us + 100.0))
        else:
            self.clock.advance(1000.0)

    def _admission_target(self) -> int:
        """Batch-bucket-aware admission cap (real mode).  The decode step
        executes the next pow2 batch regardless of occupancy, so filling
        the compiled bucket is FREE (padded rows already run) while
        spilling a boundary doubles the padded batch and compiles a new
        variant.  Admission therefore targets the current bucket and only
        crosses a boundary when the candidates would fill at least half
        of the next bucket's new rows — with a bounded hold (16
        iterations) so a lone straggler is never starved; the priority
        rebalance path is never gated.  Sim mode — and a cold runner with
        no compiled variant to protect yet — keeps the plain
        ``max_running`` cap."""
        cap = self.config.max_running
        if self.runner is None or self.runner.batch_bucket == 0:
            return cap
        cur = len(self.sched.running) + len(self.sched.swapping_in)
        bucket = self.runner.batch_bucket
        while bucket < cur:
            bucket *= 2
        if cur < min(bucket, cap):
            self._bucket_hold = 0       # not at a boundary: no hold episode
            return min(bucket, cap)
        waiting = len(self.sched.waiting) + len(self.sched.swapped)
        if waiting == 0:
            self._bucket_hold = 0       # episode ended without crossing
            return min(bucket, cap)
        if waiting >= max(1, bucket // 2) or self._bucket_hold >= 16:
            self._bucket_hold = 0
            return min(bucket * 2, cap)
        if self.metrics.iterations != self._bucket_hold_iter:
            # count the hold once per engine iteration, not per call
            self._bucket_hold += 1
            self._bucket_hold_iter = self.metrics.iterations
        return min(bucket, cap)

    def _find_victim(self, exclude) -> Optional[int]:
        victims = self.sched.victims_for_space(exclude)
        return victims[0] if victims else None

    # ------------------------------------------------------------------

    def shutdown(self) -> None:
        if self.runner is not None:
            self.runner.flush()
        self.swap.shutdown()
