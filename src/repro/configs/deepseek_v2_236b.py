"""DeepSeek-V2-236B [moe] — 60L d_model=5120 128H d_ff=1536 vocab=102400;
MLA kv_lora=512; MoE 2 shared + 160 routed top-6.  [arXiv:2405.04434]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    source="arXiv:2405.04434",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: latent cache shared across heads
    d_ff=1536,               # routed expert FFN width (dense first layer 12288)
    vocab_size=102400,
    rope_theta=10_000.0,
    max_seq_len=131_072,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert_ff=1536,
                  n_shared_experts=2, layer_pattern="skip_first"),
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(
        name="deepseek-v2-236b-smoke",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, max_seq_len=1024,
        mla=MLAConfig(kv_lora_rank=64, q_lora_rank=96,
                      rope_head_dim=16, nope_head_dim=32, v_head_dim=32),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert_ff=128,
                      n_shared_experts=1, layer_pattern="skip_first",
                      capacity_factor=4.0),   # dropless at smoke scale
    )
