"""RWKV6-1.6B "Finch" [ssm] — 24L d_model=2048 attention-free, d_ff=7168
vocab=65536; data-dependent decay.  [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    source="arXiv:2404.05892",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # 2048 / head_dim 64
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    head_dim=64,
    layer_pattern="rwkv",
    rwkv=RWKVConfig(head_dim=64, decay_lora_rank=64, gate_lora_rank=64,
                    chunk_size=64),
    max_seq_len=1_048_576,            # recurrent: unbounded in principle
    supports_long_context_decode=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(
        name="rwkv6-1.6b-smoke",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=512, max_seq_len=1024,
        rwkv=RWKVConfig(head_dim=64, decay_lora_rank=16, gate_lora_rank=16,
                        chunk_size=16),
    )
