"""Model/architecture configuration dataclasses.

Every assigned architecture gets one module in ``repro.configs`` exporting
``CONFIG`` (the full published config, used only via the dry-run) and
``smoke_config()`` (a reduced same-family variant for CPU smoke tests:
<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    n_shared_experts: int = 0
    # Layers that use MoE FFN.  "all" or "interleave:k" (every k-th layer).
    layer_pattern: str = "all"
    # Router capacity factor for the dense (einsum-dispatch) implementation.
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style SSD block config (used by zamba2 hybrid)."""
    state_dim: int = 64
    head_dim: int = 64
    n_heads: int = 0           # 0 -> derived as d_inner // head_dim
    expand: int = 2
    conv_kernel: int = 4
    chunk_size: int = 64


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 (Finch) time-mix config."""
    head_dim: int = 64
    decay_lora_rank: int = 64
    gate_lora_rank: int = 64
    chunk_size: int = 64


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: input_specs() yields precomputed embeddings."""
    kind: str = "vision"       # "vision" | "audio"
    n_tokens: int = 2880       # patch/frame embedding count
    d_embed: int = 4096


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str             # dense | moe | ssm | hybrid | vlm | audio
    source: str                # provenance citation
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    max_seq_len: int = 131_072
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- layer pattern ------------------------------------------------
    # "uniform"                : all layers identical full attention
    # "local_global:R"         : R local (sliding window) : 1 global (gemma3)
    # "zamba2"                 : mamba2 backbone + shared attention block
    #                            inserted every `hybrid_attn_every` layers
    # "rwkv"                   : all layers RWKV6 time-mix + channel-mix
    layer_pattern: str = "uniform"
    sliding_window: Optional[int] = None
    hybrid_attn_every: int = 6       # zamba2: shared attn block frequency
    # --- sub-configs ----------------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    frontend: Optional[FrontendConfig] = None
    encoder_decoder: bool = False    # whisper
    n_encoder_layers: int = 0
    n_encoder_tokens: int = 1500     # whisper: 30 s of audio frames
    # Sub-quadratic decode support (gates the long_500k shape).
    supports_long_context_decode: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (approximate, embeddings included)."""
        from repro.models.params import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params_analytic
        return count_params_analytic(self, active_only=True)

    def reduced(self, **overrides) -> "ModelConfig":
        """Generic reduction helper used by smoke_config() implementations."""
        return dataclasses.replace(self, **overrides)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}
