"""Zamba2-7B [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64; Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    source="arXiv:2411.15242",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,          # 3584 / 32
    rope_theta=10_000.0,
    max_seq_len=4096,
    layer_pattern="zamba2",
    hybrid_attn_every=6,   # shared attention block every 6 mamba2 layers
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_kernel=4,
                  chunk_size=64),
    supports_long_context_decode=True,   # SSM state is O(1); attn KV windowless
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(
        name="zamba2-7b-smoke",
        n_layers=3, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=512, max_seq_len=1024, hybrid_attn_every=2,
        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, conv_kernel=4,
                      chunk_size=16),
    )
