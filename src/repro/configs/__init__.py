"""Architecture config registry.

``get_config(arch_id)`` returns the full published config (dry-run only);
``get_smoke_config(arch_id)`` returns the reduced same-family variant used
by CPU smoke tests and the serving-engine examples.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exports)
    INPUT_SHAPES,
    SHAPES_BY_NAME,
    FrontendConfig,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
)

# arch id -> module name
_REGISTRY: Dict[str, str] = {
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1p6b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "qwen2-1.5b": "repro.configs.qwen2_1p5b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "llama3.2-3b": "repro.configs.llama3p2_3b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
}


def list_archs() -> List[str]:
    return list(_REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(_REGISTRY[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(_REGISTRY[arch_id]).smoke_config()
