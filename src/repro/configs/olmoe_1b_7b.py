"""OLMoE-1B-7B [moe] — 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8.  [arXiv:2409.02060]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    source="arXiv:2409.02060",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,             # per-expert FFN width
    vocab_size=50304,
    rope_theta=10_000.0,
    max_seq_len=4096,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert_ff=1024,
                  n_shared_experts=0, layer_pattern="all"),
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(
        name="olmoe-1b-7b-smoke",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=128, vocab_size=512, max_seq_len=1024,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert_ff=128,
                      n_shared_experts=0, layer_pattern="all",
                      capacity_factor=4.0),   # dropless at smoke scale
    )
