"""Mistral-Nemo-12B [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k context.  [hf:mistralai/Mistral-Nemo-Base-2407]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    arch_type="dense",
    source="hf:mistralai/Mistral-Nemo-Base-2407",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,          # nemo uses head_dim=128 (not d_model/n_heads=160)
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(
        name="mistral-nemo-12b-smoke",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, max_seq_len=1024,
    )
