"""Whisper-large-v3 [audio] — 32L d_model=1280 20H d_ff=5120 vocab=51866;
encoder-decoder; mel+conv frontend is a STUB (input_specs() provides
precomputed frame embeddings (B, 1500, 1280)).  [arXiv:2212.04356]"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    source="arXiv:2212.04356",
    n_layers=32,               # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    rope_theta=10_000.0,       # unused: whisper uses learned abs pos; we keep
    max_seq_len=448,           # decoder max target positions
    encoder_decoder=True,
    n_encoder_layers=32,
    n_encoder_tokens=1500,
    frontend=FrontendConfig(kind="audio", n_tokens=1500, d_embed=1280),
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(
        name="whisper-large-v3-smoke",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=512, max_seq_len=128,
        n_encoder_layers=2, n_encoder_tokens=32,
        frontend=FrontendConfig(kind="audio", n_tokens=32, d_embed=256),
    )
