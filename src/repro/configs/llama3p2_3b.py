"""Llama-3.2-3B [dense] — 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256.  [hf:meta-llama/Llama-3.2-1B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    arch_type="dense",
    source="hf:meta-llama/Llama-3.2-1B",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    max_seq_len=131_072,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(
        name="llama3.2-3b-smoke",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, max_seq_len=1024,
    )
