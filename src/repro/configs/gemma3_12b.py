"""Gemma3-12B [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144; 5:1 local:global attention, 128k ctx.  [hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
    layer_pattern="local_global:5",
    sliding_window=1024,
    # Local layers keep a bounded window; global layers at decode are linear
    # per token -> long_500k decode runs (see DESIGN.md long_500k rules).
    supports_long_context_decode=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(
        name="gemma3-12b-smoke",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, max_seq_len=1024,
        sliding_window=64, layer_pattern="local_global:1",
    )
