"""LLaVA-NeXT (Mistral-7B backbone) [vlm] — 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000; anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf]

The ViT/SigLIP vision tower + projector is a STUB: input_specs() provides
precomputed patch embeddings (B, n_patches, d_model).  anyres at 672x672
with 4 tiles + base image = 5 * 576 = 2880 patch tokens.
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
    frontend=FrontendConfig(kind="vision", n_tokens=2880, d_embed=4096),
)


def smoke_config() -> ModelConfig:
    return CONFIG.reduced(
        name="llava-next-mistral-7b-smoke",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, max_seq_len=1024,
        frontend=FrontendConfig(kind="vision", n_tokens=16, d_embed=256),
    )
