"""Core data model for fslint: findings, configuration, suppressions.

A ``Finding`` is one rule violation at one source location.  Its
``fingerprint`` deliberately excludes the line number so a committed
baseline survives unrelated edits above the finding (see
``baseline.py``).

Suppressions are per-site trailing comments of the form
``fslint: disable=FS001(caller rebinds via return)`` (preceded by a
hash sign; spelled out here so this docstring does not register one).
A suppression applies to findings on its own line and on the line
directly below it (so it can sit on its own line above a long
statement).  The reason is mandatory — a bare ``disable=FS001`` is
itself reported as FS000 so undocumented waivers cannot accumulate.
"""
from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# FS000 is reserved for malformed suppression comments and cannot be
# disabled itself.
BAD_SUPPRESSION = "FS000"

_SUPPRESS_RE = re.compile(r"#\s*fslint:\s*disable=(.*)$")
_CLAUSE_RE = re.compile(r"\s*(FS\d{3})\s*\(([^()]*)\)\s*")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str        # repo-relative, forward slashes
    line: int        # 1-based
    col: int         # 0-based
    qualname: str    # enclosing function (module-qualified) or "<module>"
    message: str

    def fingerprint(self) -> str:
        """Stable, line-independent identity used by the baseline."""
        key = "|".join((self.rule, self.path, self.qualname, self.message))
        return hashlib.blake2b(key.encode("utf-8"), digest_size=10).hexdigest()

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.qualname}] {self.message}")

    def to_json(self) -> dict:
        return {
            "rule": self.rule, "path": self.path, "line": self.line,
            "col": self.col, "qualname": self.qualname,
            "message": self.message, "fingerprint": self.fingerprint(),
        }


@dataclass
class Config:
    """Repo-tuned knobs; rules read conventions from here, never from
    hard-coded strings, so tests can retarget them at fixture trees."""

    # -- FS002: approved pow2 bucketing helpers (call by any alias path
    # whose final component matches).  Functions whose *return value*
    # contains a call to one of these become derived bucketing sources
    # (e.g. kernels/ops.py::_pad_runs).
    bucketing_helpers: Tuple[str, ...] = (
        "next_pow2", "_next_pow2", "slab_bucket_blocks", "page_tile",
        "_grow_to", "pow2_bucket",
    )

    # -- FS003: modules whose calls produce device values, and the
    # documented staged-copy sync points that are allowed to block.
    device_modules: Tuple[str, ...] = (
        "jax", "jnp", "jax.numpy", "jax.random", "jax.lax", "jax.nn",
    )
    device_functions: Tuple[str, ...] = ("sample_tokens",)
    sync_allowlist: Tuple[str, ...] = (
        "PagedPools.copy_out_staged", "PagedPools.copy_in_staged",
    )

    # -- hot-path roots: a function is "hot" when its bare name matches
    # one of these (or starts with a listed prefix) or it is reachable
    # from a hot function through the project call graph.
    hot_root_names: Tuple[str, ...] = ("step", "decode")
    hot_root_prefixes: Tuple[str, ...] = ("prefill",)

    # -- FS004: attribute paths whose final component names a device
    # pool; assignments to these (or ``X = X.at[..].set(..)`` updates of
    # them) outside donated jit bodies count as pool mutation.
    pool_attr_names: Tuple[str, ...] = ("gpu", "pool")
    # Wrappers that return their callable argument (possibly decorated):
    # closure direction labels flow through them unchanged.
    passthrough_wrappers: Tuple[str, ...] = ("wrap_copy",)
    # Attribute/keyword names under which data-plane closures are
    # registered for (possibly threaded) execution.
    copy_fn_names: Tuple[str, ...] = ("copy_fn",)
    # Name of the direction variable tested to segregate d2h from h2d.
    direction_var: str = "direction"
    out_label: str = "out"

    # -- FS005: lock attributes are recognised by suffix match on the
    # final component.
    lock_suffix: str = "lock"

    # -- FS007: calls that block the event loop inside ``async def``
    # bodies (the front-end's streaming server shares one loop across
    # every connection — one blocking call stalls them all).  Dotted
    # entries match the call path exactly or by suffix ("time.sleep"
    # also catches an aliased import); attr entries match any
    # ``obj.<attr>()`` call.  A call that is DIRECTLY awaited is exempt
    # (``await ws.recv()`` yields to the loop).
    async_blocking_calls: Tuple[str, ...] = (
        "time.sleep", "jax.block_until_ready", "jax.device_get",
    )
    async_blocking_attrs: Tuple[str, ...] = (
        "result", "recv", "recv_into", "recvfrom", "sendall", "accept",
    )

    # Rules to run (None = all registered).
    rules: Optional[Tuple[str, ...]] = None


@dataclass
class Suppressions:
    """Parsed per-site disable comments for one file."""

    # line -> {rule -> reason}
    by_line: Dict[int, Dict[str, str]] = field(default_factory=dict)
    malformed: List[Tuple[int, str]] = field(default_factory=list)

    def covers(self, line: int, rule: str) -> bool:
        for ln in (line, line - 1):
            clauses = self.by_line.get(ln)
            if clauses and rule in clauses:
                return True
        return False


def parse_suppressions(source: str) -> Suppressions:
    sup = Suppressions()
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m is None:
            continue
        body = m.group(1).strip()
        clauses: Dict[str, str] = {}
        pos, ok = 0, True
        while pos < len(body):
            cm = _CLAUSE_RE.match(body, pos)
            if cm is None:
                ok = False
                break
            rule, reason = cm.group(1), cm.group(2).strip()
            if not reason or rule == BAD_SUPPRESSION:
                ok = False
                break
            clauses[rule] = reason
            pos = cm.end()
            if pos < len(body):
                if body[pos] != ",":
                    ok = False
                    break
                pos += 1
        if ok and clauses:
            sup.by_line[lineno] = clauses
        else:
            sup.malformed.append((lineno, body))
    return sup
