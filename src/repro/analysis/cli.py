"""fslint command line.

Exit codes: 0 clean (baseline-known and stale entries allowed),
1 new findings, 2 usage error.

    python -m repro.analysis src/repro
    python -m repro.analysis src/repro --rule FS003 --format json
    python -m repro.analysis src/repro --update-baseline
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis.baseline import Baseline
from repro.analysis.core import Config
from repro.analysis.driver import AnalysisResult, run_analysis
from repro.analysis.rules import ALL_RULES

DEFAULT_BASELINE = "fslint-baseline.json"


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="fslint: FastSwitch JAX hot-path static analyzer")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to scan "
                         "(default: src/repro)")
    ap.add_argument("--rule", action="append", dest="rules",
                    metavar="FSxxx",
                    help="run only this rule (repeatable)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help=f"baseline file (default: {DEFAULT_BASELINE} "
                         f"next to the scanned tree when present)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write current findings to the baseline file "
                         "and exit 0")
    ap.add_argument("--list-rules", action="store_true")
    return ap


def _default_baseline(paths: List[str]) -> Optional[Path]:
    """Find a committed baseline next to the scanned tree: walk up
    from the first path looking for fslint-baseline.json."""
    cur = Path(paths[0]).resolve()
    if cur.is_file():
        cur = cur.parent
    for candidate in (cur, *cur.parents):
        p = candidate / DEFAULT_BASELINE
        if p.exists():
            return p
    return None


def main(argv: Optional[List[str]] = None) -> int:
    ap = _build_parser()
    args = ap.parse_args(argv)

    if args.list_rules:
        for cls in ALL_RULES:
            print(f"{cls.id}  {cls.title}")
        return 0

    known_ids = {cls.id for cls in ALL_RULES} | {"FS000"}
    rules = tuple(args.rules) if args.rules else None
    if rules:
        bad = [r for r in rules if r not in known_ids]
        if bad:
            print(f"unknown rule(s): {', '.join(bad)}", file=sys.stderr)
            return 2

    missing = [p for p in args.paths if not Path(p).exists()]
    if missing:
        print(f"no such path: {', '.join(missing)}", file=sys.stderr)
        return 2

    cfg = Config(rules=rules)
    result = run_analysis(args.paths, cfg)

    if args.baseline is not None:
        bl_path: Optional[Path] = Path(args.baseline)
    else:
        bl_path = _default_baseline(args.paths)
    baseline = Baseline.load(bl_path) if bl_path else Baseline(
        Path(DEFAULT_BASELINE))

    if args.update_baseline:
        baseline.save(result.findings)
        print(f"baseline written: {baseline.path} "
              f"({len(result.findings)} findings)")
        return 0

    new, known, stale = baseline.split(result.findings)

    if args.format == "json":
        print(json.dumps({
            "paths": args.paths,
            "rules": sorted(rules) if rules else "all",
            "new": [f.to_json() for f in new],
            "baselined": [f.to_json() for f in known],
            "stale_baseline": stale,
            "suppressed": [f.to_json() for f in result.suppressed],
            "jit_degrees": result.jit_degrees,
            "exit": 1 if new else 0,
        }, indent=2, sort_keys=True))
    else:
        for f in new:
            print(f.render())
        for f in known:
            print(f"{f.render()}  [baselined]")
        for e in stale:
            print(f"stale baseline entry: {e.get('rule')} "
                  f"{e.get('path')} [{e.get('qualname')}] — prune it")
        n_sup = len(result.suppressed)
        print(f"fslint: {len(new)} new, {len(known)} baselined, "
              f"{len(stale)} stale, {n_sup} suppressed")
    return 1 if new else 0


# convenience for tests
def variant_bound(degrees: int, max_tokens: int) -> int:
    return AnalysisResult.variant_bound(degrees, max_tokens)
