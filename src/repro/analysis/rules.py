"""The fslint rule set.

Each rule encodes one discipline the FastSwitch hot path depends on,
grounded in a real bug from this repo's history (DESIGN.md §8 has the
full catalog):

* FS001 use-after-donate — PR 3's cross-thread donation KV tear.
* FS002 jit-variant budget — PR 1/PR 4's O(log) jit-cache bounds.
* FS003 host-sync in hot path — PR 2's torn async d2h reads and the
  deferred-sync token pipeline.
* FS004 swap-plane thread discipline — the PR 3 residency contract
  (worker threads run read-only d2h gathers only).
* FS005 lock-order / await-outside-lock — swap_manager's "await copy
  deps *before* taking the pool lock" contract.
* FS006 un-donated pool write — the legacy whole-pool ``.at[].set``
  copy-in path this PR retires.
* FS007 blocking call in async def — the front-end's single event loop
  (DESIGN.md §11) must never run thread sleeps, synchronous future
  waits, raw socket I/O or host-syncing jax calls.

Rules report syntactic facts with dataflow just deep enough to avoid
noise; they are deliberately intra-module (plus a project call graph)
and never import jax.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import (
    FunctionInfo,
    assign_target_paths,
    call_name,
    dotted_path,
    enclosing_loop,
    enclosing_statement,
    last_component,
)
from repro.analysis.callgraph import Project
from repro.analysis.core import Finding
from repro.analysis.dataflow import (
    BucketEnv,
    DeviceWalk,
    class_device_attrs,
    collect_direction_facts,
    device_returning_functions,
)


class Rule:
    id: str = "FS000"
    title: str = ""

    def run(self, project: Project) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError


def _finding(rule: str, fi: FunctionInfo, node: ast.AST,
             message: str) -> Finding:
    return Finding(
        rule=rule, path=fi.module.rel_path,
        line=getattr(node, "lineno", fi.node.lineno),
        col=getattr(node, "col_offset", 0),
        qualname=fi.qualname, message=message)


def _owned_calls(fi: FunctionInfo) -> List[ast.Call]:
    """Call nodes belonging to ``fi`` itself (lambdas included, nested
    named defs excluded — they are analysed separately)."""
    out = []
    for node in ast.walk(fi.node):
        if isinstance(node, ast.Call):
            owner = fi.module.function_for(node)
            if owner is None or owner.node is fi.node:
                out.append(node)
    return out


# ---------------------------------------------------------------------------
# FS001 — use-after-donate
# ---------------------------------------------------------------------------

class UseAfterDonate(Rule):
    id = "FS001"
    title = "use-after-donate"

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for fi in project.functions.values():
            findings.extend(self._check_function(project, fi))
        return findings

    def _check_function(self, project: Project,
                        fi: FunctionInfo) -> List[Finding]:
        out: List[Finding] = []
        parents = fi.module.parents
        for call in _owned_calls(fi):
            for callee in project.resolve_call(call, fi.module, fi):
                donated = project.donated_params.get(callee.qualname)
                if not donated:
                    continue
                for pname, arg in project.map_call_args(call, callee):
                    if pname not in donated:
                        continue
                    path = dotted_path(arg)
                    if path is None:
                        continue  # rvalue expression: nothing survives
                    stmt = enclosing_statement(call, parents)
                    if stmt is None or isinstance(stmt, ast.Return):
                        continue
                    rebound_here = path in assign_target_paths(stmt)
                    bare = last_component(callee.qualname)
                    loop = enclosing_loop(call, fi.node, parents)
                    if loop is not None and not rebound_here:
                        if not self._rebinds_in(loop, path):
                            out.append(_finding(
                                self.id, fi, call,
                                f"'{path}' is donated to {bare} inside a "
                                f"loop without being rebound; the next "
                                f"iteration reads a freed buffer"))
                            continue
                    if rebound_here:
                        continue
                    use = self._first_use_after(fi, stmt, path)
                    if use is not None:
                        out.append(_finding(
                            self.id, fi, use,
                            f"'{path}' was donated to {bare} and is read "
                            f"again afterwards; rebind it from the call's "
                            f"return value (owner-of-record protocol)"))
        return out

    @staticmethod
    def _rebinds_in(scope: ast.AST, path: str) -> bool:
        for node in ast.walk(scope):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                if path in assign_target_paths(node):
                    return True
        return False

    @staticmethod
    def _first_use_after(fi: FunctionInfo, stmt: ast.stmt,
                         path: str) -> Optional[ast.AST]:
        origin = (stmt.end_lineno or stmt.lineno,
                  stmt.end_col_offset or 0)
        # first revival: end of the first later statement that rebinds
        revive: Optional[Tuple[int, int]] = None
        for node in ast.walk(fi.node):
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                pos = (node.end_lineno or node.lineno,
                       node.end_col_offset or 0)
                if pos > origin and path in assign_target_paths(node):
                    if revive is None or pos < revive:
                        revive = pos
        best: Optional[ast.AST] = None
        best_pos: Optional[Tuple[int, int]] = None
        for node in ast.walk(fi.node):
            if not isinstance(node, (ast.Name, ast.Attribute)):
                continue
            if not isinstance(getattr(node, "ctx", None), ast.Load):
                continue
            p = dotted_path(node)
            if p is None or (p != path and not p.startswith(path + ".")):
                continue
            pos = (node.lineno, node.col_offset)
            if pos <= origin:
                continue
            if revive is not None and pos > revive:
                continue
            if best_pos is None or pos < best_pos:
                best, best_pos = node, pos
        return best


# ---------------------------------------------------------------------------
# FS002 — jit-variant budget
# ---------------------------------------------------------------------------

class JitVariantBudget(Rule):
    id = "FS002"
    title = "jit-variant-budget"

    def __init__(self) -> None:
        # qualname of jit def -> max bucketed degrees observed at any
        # hot call site; consumed by `launch/dryrun.py --audit-jit`.
        self.degrees: Dict[str, int] = {}

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        self.degrees = {}
        for qual, fi in project.functions.items():
            if qual not in project.hot:
                continue
            benv = BucketEnv(fi, project)
            for call in _owned_calls(fi):
                for callee in project.resolve_call(call, fi.module, fi):
                    spec = project.jit_specs.get(callee.qualname)
                    if spec is None:
                        continue
                    bucketed = 0
                    for pname, arg in project.map_call_args(call, callee):
                        flags = benv.flags(arg)
                        # one degree of freedom per bucketed *static*
                        # arg; traced-shape buckets are correlated with
                        # these, so the audit bound stays tight
                        if flags.bucketed and pname in spec.static_argnames:
                            bucketed += 1
                        if not flags.suspect:
                            continue
                        kind = ("static arg"
                                if pname in spec.static_argnames
                                else "traced array arg")
                        findings.append(_finding(
                            self.id, fi, arg,
                            f"{kind} '{pname}' of jitted "
                            f"{last_component(callee.qualname)} derives "
                            f"from a per-call size; route it through a "
                            f"pow2 bucketing helper or the jit cache "
                            f"grows per distinct value"))
                    cur = self.degrees.get(callee.qualname, 0)
                    self.degrees[callee.qualname] = max(cur, bucketed)
        return findings


# ---------------------------------------------------------------------------
# FS003 — host sync in hot path
# ---------------------------------------------------------------------------

class HostSyncInHotPath(Rule):
    id = "FS003"
    title = "host-sync-in-hot-path"

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        device_returning = device_returning_functions(project)
        attr_cache: Dict[Tuple[str, str], Dict[str, str]] = {}
        allow = project.config.sync_allowlist
        for qual, fi in project.functions.items():
            if qual not in project.hot:
                continue
            if any(qual.endswith(suffix) for suffix in allow):
                continue
            attrs: Dict[str, str] = {}
            if fi.class_name is not None:
                key = (fi.module.modname, fi.class_name)
                if key not in attr_cache:
                    attr_cache[key] = class_device_attrs(
                        project, fi.module, fi.class_name, device_returning)
                attrs = attr_cache[key]
            walk = DeviceWalk(fi, project, attrs, device_returning)
            for site in walk.syncs:
                findings.append(_finding(
                    self.id, fi, site.node,
                    f"{site.detail} inside the serving hot path; defer it "
                    f"or move it to a documented staged sync point"))
        return findings


# ---------------------------------------------------------------------------
# FS004 — swap-plane thread discipline
# ---------------------------------------------------------------------------

class SwapThreadDiscipline(Rule):
    id = "FS004"
    title = "swap-thread-discipline"

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        cfg = project.config
        facts = collect_direction_facts(project)
        mutators = self._pool_mutators(project)
        for submit_fi, call, targets, guard in facts.submit_sites:
            reachable = project.reachable_from(targets)
            # expand through indirect `task.copy_fn()` dispatch: which
            # registered closures can a worker thread actually run?
            for _ in range(4):  # closures may chain; small fixpoint
                extra: List[str] = []
                for qual in list(reachable):
                    if qual not in facts.indirect_callers:
                        continue
                    for rec in facts.registered:
                        if guard == cfg.out_label and \
                                rec.label == "in":
                            continue  # provably h2d-only: not submitted
                        extra.extend(rec.callees)
                new = project.reachable_from(extra) - reachable
                if not new:
                    break
                reachable |= new
            hit = sorted(reachable & mutators)
            if hit:
                findings.append(_finding(
                    self.id, submit_fi, call,
                    f"pool-mutating op(s) {', '.join(hit[:3])} reachable "
                    f"from a swap worker thread; workers may only run "
                    f"read-only d2h gathers (residency contract)"))
        return findings

    @staticmethod
    def _pool_mutators(project: Project) -> Set[str]:
        mutators = {qual for qual, donated
                    in project.donated_params.items() if donated}
        for qual, fi in project.functions.items():
            if qual in project.jit_specs:
                continue
            for node in ast.walk(fi.node):
                if isinstance(node, ast.Assign):
                    for t in node.targets:
                        if isinstance(t, ast.Attribute) and \
                                t.attr in project.config.pool_attr_names:
                            mutators.add(qual)
        return mutators


# ---------------------------------------------------------------------------
# FS005 — lock order / await under pool lock
# ---------------------------------------------------------------------------

class LockDiscipline(Rule):
    id = "FS005"
    title = "lock-discipline"

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        suffix = project.config.lock_suffix
        awaiting = self._direct_awaiters(project)
        acquires = self._direct_acquires(project, suffix)
        reach_cache: Dict[str, Set[str]] = {}

        def reach(qual: str) -> Set[str]:
            if qual not in reach_cache:
                reach_cache[qual] = project.reachable_from([qual])
            return reach_cache[qual]

        edges: Dict[str, Set[str]] = {}
        edge_sites: Dict[Tuple[str, str], Tuple[FunctionInfo, ast.AST]] = {}

        for qual, fi in project.functions.items():
            self._scan(project, fi, fi.node.body, [], suffix, awaiting,
                       acquires, reach, findings, edges, edge_sites)

        # lock-order cycles across the whole project
        for a, b in self._cycle_edges(edges):
            fi, site = edge_sites[(a, b)]
            findings.append(_finding(
                self.id, fi, site,
                f"lock-order cycle: '{a}' is held while acquiring '{b}' "
                f"and elsewhere the reverse; pick one global order"))
        return findings

    # -- project scans ----------------------------------------------------

    @staticmethod
    def _direct_awaiters(project: Project) -> Set[str]:
        out: Set[str] = set()
        for qual, fi in project.functions.items():
            for node in ast.walk(fi.node):
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "result":
                    out.add(qual)
                    break
        return out

    @staticmethod
    def _lock_names(stmt: ast.stmt, suffix: str) -> List[str]:
        names = []
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                path = dotted_path(item.context_expr)
                if path is not None and \
                        last_component(path).endswith(suffix):
                    names.append(last_component(path))
        return names

    def _direct_acquires(self, project: Project,
                         suffix: str) -> Dict[str, Set[str]]:
        out: Dict[str, Set[str]] = {}
        for qual, fi in project.functions.items():
            got: Set[str] = set()
            for node in ast.walk(fi.node):
                if isinstance(node, (ast.With, ast.AsyncWith)):
                    got.update(self._lock_names(node, suffix))
            if got:
                out[qual] = got
        return out

    def _scan(self, project: Project, fi: FunctionInfo,
              body: List[ast.stmt], held: List[str], suffix: str,
              awaiting: Set[str], acquires: Dict[str, Set[str]],
              reach, findings: List[Finding],
              edges: Dict[str, Set[str]], edge_sites: Dict) -> None:
        for stmt in body:
            locks = self._lock_names(stmt, suffix)
            if locks:
                for new in locks:
                    for h in held:
                        if h == new:
                            findings.append(_finding(
                                self.id, fi, stmt,
                                f"re-acquisition of non-reentrant lock "
                                f"'{new}' while already held"))
                        else:
                            edges.setdefault(h, set()).add(new)
                            edge_sites.setdefault((h, new), (fi, stmt))
                self._scan(project, fi, stmt.body, held + locks, suffix,
                           awaiting, acquires, reach, findings, edges,
                           edge_sites)
                continue
            if held:
                for node in ast.walk(stmt):
                    if not isinstance(node, ast.Call):
                        continue
                    if isinstance(node.func, ast.Attribute) and \
                            node.func.attr == "result":
                        findings.append(_finding(
                            self.id, fi, node,
                            f"future awaited while holding "
                            f"'{held[-1]}'; await copy deps before "
                            f"taking the pool lock"))
                        continue
                    for callee in project.resolve_call(node, fi.module, fi):
                        r = reach(callee.qualname)
                        waits = r & awaiting
                        if waits:
                            findings.append(_finding(
                                self.id, fi, node,
                                f"call to {last_component(callee.qualname)} "
                                f"awaits a future "
                                f"({last_component(sorted(waits)[0])}) "
                                f"while '{held[-1]}' is held"))
                        for acq_qual in r:
                            for lock in acquires.get(acq_qual, ()):  #
                                if lock in held:
                                    findings.append(_finding(
                                        self.id, fi, node,
                                        f"call path into "
                                        f"{last_component(acq_qual)} "
                                        f"re-acquires '{lock}' already "
                                        f"held here"))
                                else:
                                    for h in held:
                                        edges.setdefault(h, set()).add(lock)
                                        edge_sites.setdefault(
                                            (h, lock), (fi, node))
            # recurse into nested blocks with the same held set
            for sub in self._sub_bodies(stmt):
                self._scan(project, fi, sub, held, suffix, awaiting,
                           acquires, reach, findings, edges, edge_sites)

    @staticmethod
    def _sub_bodies(stmt: ast.stmt) -> List[List[ast.stmt]]:
        out = []
        for attr in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, attr, None)
            if isinstance(sub, list) and sub and \
                    isinstance(sub[0], ast.stmt):
                out.append(sub)
        for h in getattr(stmt, "handlers", []) or []:
            out.append(h.body)
        return out

    @staticmethod
    def _cycle_edges(edges: Dict[str, Set[str]]) -> List[Tuple[str, str]]:
        out = []
        for a, succs in edges.items():
            for b in succs:
                if a in edges.get(b, set()):
                    out.append((a, b))
        return out


# ---------------------------------------------------------------------------
# FS006 — un-donated whole-pool write
# ---------------------------------------------------------------------------

class UndonatedPoolWrite(Rule):
    id = "FS006"
    title = "undonated-pool-write"

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        traced = project.reachable_from(project.jit_specs.keys())
        for qual, fi in project.functions.items():
            if qual in project.jit_specs or qual in traced:
                continue  # inside-trace updates are donated by the jit
            for node in ast.walk(fi.node):
                pool = self._pool_at_set(node, project)
                if pool is not None:
                    findings.append(_finding(
                        self.id, fi, node,
                        f"un-donated functional update of pool '{pool}' "
                        f"copies the entire pool; route through the "
                        f"staged/donating swap path"))
        return findings

    @staticmethod
    def _pool_at_set(node: ast.AST, project: Project) -> Optional[str]:
        # matches <pool>.at[...].set(...)/.add(...) etc. outside jit
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("set", "add", "mul", "max", "min")):
            return None
        sub = node.func.value
        if not isinstance(sub, ast.Subscript):
            return None
        at = sub.value
        if not (isinstance(at, ast.Attribute) and at.attr == "at"):
            return None
        base = dotted_path(at.value)
        if base is not None and \
                last_component(base) in project.config.pool_attr_names:
            return base
        return None


# ---------------------------------------------------------------------------
# FS007 — blocking call inside async def
# ---------------------------------------------------------------------------

class AsyncBlockingCall(Rule):
    """The front-end's asyncio server (DESIGN.md §11) multiplexes every
    connection over one event loop; a single blocking call — a thread
    sleep, a synchronous ``future.result()`` bridging the engine
    threads, a raw socket recv, a host-syncing jax call — stalls token
    streaming for ALL clients.  Engine access must marshal through
    ``asyncio.wrap_future`` / the reader-writer streams instead.

    A call that is directly awaited is exempt: ``await ws.recv()``
    yields to the loop.  Deep device-value host-sync detection stays
    FS003's job; this rule names the explicit blocking entry points
    (``Config.async_blocking_calls`` / ``async_blocking_attrs``)."""
    id = "FS007"
    title = "async-blocking-call"

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        cfg = project.config
        for fi in project.functions.values():
            if not isinstance(fi.node, ast.AsyncFunctionDef):
                continue
            parents = fi.module.parents
            for call in _owned_calls(fi):
                if isinstance(parents.get(call), ast.Await):
                    continue
                path = dotted_path(call.func)
                if path is not None and (
                        path in cfg.async_blocking_calls
                        or any(path.endswith("." + c)
                               for c in cfg.async_blocking_calls)):
                    findings.append(_finding(
                        self.id, fi, call,
                        f"blocking call '{path}' inside an async def "
                        f"stalls the event loop for every connection; "
                        f"use the asyncio equivalent or move it off-loop"))
                    continue
                if isinstance(call.func, ast.Attribute) and \
                        call.func.attr in cfg.async_blocking_attrs:
                    findings.append(_finding(
                        self.id, fi, call,
                        f"blocking '.{call.func.attr}()' inside an async "
                        f"def stalls the event loop; bridge threads with "
                        f"asyncio.wrap_future / run_in_executor and use "
                        f"stream reader/writer APIs for sockets"))
        return findings


ALL_RULES: Tuple[type, ...] = (
    UseAfterDonate, JitVariantBudget, HostSyncInHotPath,
    SwapThreadDiscipline, LockDiscipline, UndonatedPoolWrite,
    AsyncBlockingCall,
)


def make_rules(only: Optional[Tuple[str, ...]] = None) -> List[Rule]:
    rules = [cls() for cls in ALL_RULES]
    if only:
        rules = [r for r in rules if r.id in only]
    return rules
