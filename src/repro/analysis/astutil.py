"""AST plumbing shared by the fslint rules.

Everything here is syntactic: dotted-path flattening, parent links,
qualified names, and per-module import maps.  Semantic layers (call
graph, donation registry, taint) live in ``callgraph.py`` /
``dataflow.py``.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple, Union

from repro.analysis.core import Suppressions, parse_suppressions

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef]


def dotted_path(node: ast.AST) -> Optional[str]:
    """Flatten ``a.b.c`` (Name/Attribute chains) to ``"a.b.c"``.
    Returns None for anything else (subscripts, calls, literals)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def last_component(path: str) -> str:
    return path.rsplit(".", 1)[-1]


def call_name(call: ast.Call) -> Optional[str]:
    """Dotted path of a call's callee, or None (e.g. ``f()()``)."""
    return dotted_path(call.func)


def walk_with_parents(root: ast.AST) -> Dict[ast.AST, ast.AST]:
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(root):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def node_contains(outer: ast.AST, inner: ast.AST,
                  parents: Dict[ast.AST, ast.AST]) -> bool:
    cur: Optional[ast.AST] = inner
    while cur is not None:
        if cur is outer:
            return True
        cur = parents.get(cur)
    return False


def enclosing_statement(node: ast.AST,
                        parents: Dict[ast.AST, ast.AST]) -> Optional[ast.stmt]:
    cur: Optional[ast.AST] = node
    while cur is not None and not isinstance(cur, ast.stmt):
        cur = parents.get(cur)
    return cur if isinstance(cur, ast.stmt) else None


def enclosing_loop(node: ast.AST, stop: ast.AST,
                   parents: Dict[ast.AST, ast.AST]) -> Optional[ast.stmt]:
    """Innermost For/While between ``node`` and ``stop`` (exclusive)."""
    cur = parents.get(node)
    while cur is not None and cur is not stop:
        if isinstance(cur, (ast.For, ast.While)):
            return cur
        cur = parents.get(cur)
    return None


def assign_target_paths(stmt: ast.stmt) -> List[str]:
    """Dotted paths bound by an assignment statement (tuple targets
    flattened; subscript/starred targets are skipped)."""
    targets: List[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.For):
        targets = [stmt.target]
    paths: List[str] = []
    queue = list(targets)
    while queue:
        t = queue.pop()
        if isinstance(t, (ast.Tuple, ast.List)):
            queue.extend(t.elts)
        else:
            p = dotted_path(t)
            if p is not None:
                paths.append(p)
    return paths


@dataclass
class FunctionInfo:
    qualname: str            # module.Class.func or module.func
    node: FuncNode
    module: "ModuleInfo"
    class_name: Optional[str]

    @property
    def name(self) -> str:
        return self.node.name

    @property
    def is_method(self) -> bool:
        return self.class_name is not None

    @property
    def params(self) -> List[str]:
        a = self.node.args
        return ([p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
                + ([a.vararg.arg] if a.vararg else [])
                + [p.arg for p in a.kwonlyargs]
                + ([a.kwarg.arg] if a.kwarg else []))

    @property
    def positional_params(self) -> List[str]:
        a = self.node.args
        return [p.arg for p in a.posonlyargs] + [p.arg for p in a.args]


@dataclass
class ModuleInfo:
    modname: str             # dotted, e.g. "repro.kernels.ops"
    path: Path               # absolute
    rel_path: str            # repo-relative, forward slashes
    tree: ast.Module
    source: str
    suppressions: Suppressions
    parents: Dict[ast.AST, ast.AST] = field(default_factory=dict)
    imports: Dict[str, str] = field(default_factory=dict)  # alias -> full
    functions: Dict[str, FunctionInfo] = field(default_factory=dict)

    def function_for(self, node: ast.AST) -> Optional[FunctionInfo]:
        """Innermost enclosing def of a node (lambdas belong to their
        enclosing def)."""
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for fi in self.functions.values():
                    if fi.node is cur:
                        return fi
            cur = self.parents.get(cur)
        return None


def _collect_imports(tree: ast.Module) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out[alias.asname or alias.name.split(".")[0]] = (
                    alias.name if alias.asname else alias.name.split(".")[0])
                if alias.asname:
                    out[alias.asname] = alias.name
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for alias in node.names:
                out[alias.asname or alias.name] = f"{node.module}.{alias.name}"
    return out


def _collect_functions(mod: ModuleInfo) -> None:
    def visit(body: List[ast.stmt], prefix: str, cls: Optional[str]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}.{stmt.name}"
                mod.functions[qual] = FunctionInfo(qual, stmt, mod, cls)
                # nested defs: qualify but keep the nearest class tag
                visit(stmt.body, qual, cls)
            elif isinstance(stmt, ast.ClassDef):
                visit(stmt.body, f"{prefix}.{stmt.name}", stmt.name)
    visit(mod.tree.body, mod.modname, None)


def modname_for(path: Path, roots: List[Path]) -> str:
    """Dotted module name for a source file.

    A ``src`` directory anywhere on the path is treated as the import
    root (``src/repro/kernels/ops.py`` -> ``repro.kernels.ops``).
    Otherwise the file is named relative to the shallowest scanned
    root that contains it (fixture trees: ``tmp/mod.py`` -> ``mod``).
    """
    p = path.resolve()
    parts = p.with_suffix("").parts
    if "src" in parts:
        idx = len(parts) - 1 - parts[::-1].index("src")
        tail = list(parts[idx + 1:])
    else:
        tail = None
        for root in sorted(roots, key=lambda r: len(str(r))):
            try:
                rel = p.relative_to(root.resolve())
            except ValueError:
                continue
            tail = list(rel.with_suffix("").parts)
            break
        if tail is None:
            tail = [p.stem]
    if tail and tail[-1] == "__init__":
        tail = tail[:-1]
    return ".".join(tail) if tail else p.stem


def load_module(path: Path, roots: List[Path],
                repo_root: Path) -> Optional[ModuleInfo]:
    try:
        source = path.read_text(encoding="utf-8")
        tree = ast.parse(source, filename=str(path))
    except (OSError, SyntaxError):
        return None
    try:
        rel = str(path.resolve().relative_to(repo_root.resolve()))
    except ValueError:
        rel = str(path)
    mod = ModuleInfo(
        modname=modname_for(path, roots), path=path.resolve(),
        rel_path=rel.replace("\\", "/"), tree=tree, source=source,
        suppressions=parse_suppressions(source),
    )
    mod.parents = walk_with_parents(tree)
    mod.imports = _collect_imports(tree)
    _collect_functions(mod)
    return mod


def iter_source_files(paths: List[Path]) -> Iterator[Path]:
    seen = set()
    for p in paths:
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            r = f.resolve()
            if r not in seen and r.suffix == ".py":
                seen.add(r)
                yield r


def source_roots(paths: List[Path]) -> List[Path]:
    """Scanned base directories, used by ``modname_for`` for trees
    without a ``src`` layout (fixture directories in tests)."""
    return [(p if p.is_dir() else p.parent).resolve() for p in paths]
