"""fslint — static analysis for the FastSwitch JAX hot path.

The engine's performance contract lives in a handful of disciplines
that are invisible to generic linters: donated pool buffers must be
rebound by their caller (PR 3's cross-thread KV tear), every jit
variant reachable from the serving hot path must bucket its
shape-determining arguments to pow2 (PR 4's O(log) cache bounds),
host synchronisation is only allowed at the documented staged-copy
points (PR 2's torn async d2h reads), swap worker threads must never
touch pool-mutating donated ops (the swap-plane residency contract),
and copy futures must never be awaited while holding the pool lock
(swap_manager's deadlock contract).

``python -m repro.analysis [paths]`` runs the rule set over a source
tree; see DESIGN.md §8 for the rule catalog and policy.

The package is stdlib-only on purpose: it never imports jax or the
repro runtime, so the CI gate costs milliseconds and runs anywhere.
"""
from repro.analysis.core import Config, Finding  # noqa: F401
from repro.analysis.driver import jit_budget, run_analysis  # noqa: F401
