"""Baseline file handling: grandfathered findings.

The baseline is a committed JSON file of finding fingerprints.  A
finding whose fingerprint appears in the baseline is reported as
``known`` and does not fail the gate; anything else is ``new`` and
does.  Baseline entries that no longer match any finding are ``stale``
— the gate still passes, but they are printed so the file can be
pruned and the count only ever goes down.

Fingerprints exclude line numbers (see ``Finding.fingerprint``), so
edits elsewhere in a file do not churn the baseline.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Tuple

from repro.analysis.core import Finding

BASELINE_VERSION = 1


@dataclass
class Baseline:
    path: Path
    entries: Dict[str, dict] = field(default_factory=dict)  # fp -> entry

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        bl = cls(path=path)
        if not path.exists():
            return bl
        data = json.loads(path.read_text(encoding="utf-8"))
        for entry in data.get("findings", []):
            fp = entry.get("fingerprint")
            if fp:
                bl.entries[fp] = entry
        return bl

    def save(self, findings: List[Finding]) -> None:
        payload = {
            "version": BASELINE_VERSION,
            "findings": sorted(
                (f.to_json() for f in findings),
                key=lambda e: (e["rule"], e["path"], e["qualname"])),
        }
        self.path.write_text(
            json.dumps(payload, indent=2, sort_keys=True) + "\n",
            encoding="utf-8")

    def split(self, findings: List[Finding]
              ) -> Tuple[List[Finding], List[Finding], List[dict]]:
        """(new, known, stale_entries)."""
        new: List[Finding] = []
        known: List[Finding] = []
        seen = set()
        for f in findings:
            fp = f.fingerprint()
            if fp in self.entries:
                known.append(f)
                seen.add(fp)
            else:
                new.append(f)
        stale = [e for fp, e in sorted(self.entries.items())
                 if fp not in seen]
        return new, known, stale
