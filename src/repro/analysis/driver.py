"""Analysis driver: index the tree, run the rules, apply suppressions.

``run_analysis`` is the programmatic entry point used by the CLI,
``tests/test_fslint.py``, and ``launch/dryrun.py --audit-jit`` (which
consumes the FS002 degrees-of-freedom table to bound the runtime jit
cache).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.analysis.callgraph import Project
from repro.analysis.core import BAD_SUPPRESSION, Config, Finding
from repro.analysis.rules import JitVariantBudget, make_rules


@dataclass
class AnalysisResult:
    findings: List[Finding] = field(default_factory=list)
    suppressed: List[Finding] = field(default_factory=list)
    # FS002's static degrees-of-freedom per jitted function: the
    # runtime variant count of each must stay within
    # (log2(max_tokens) + 2) ** max(degrees, 2).
    jit_degrees: Dict[str, int] = field(default_factory=dict)
    project: Optional[Project] = None

    @staticmethod
    def variant_bound(degrees: int, max_tokens: int) -> int:
        base = max(1, max_tokens).bit_length() + 2
        return base ** max(degrees, 2)


def run_analysis(paths: List[str], config: Optional[Config] = None,
                 repo_root: Optional[str] = None) -> AnalysisResult:
    cfg = config or Config()
    root = Path(repo_root) if repo_root else Path.cwd()
    project = Project([Path(p) for p in paths], root, cfg)
    result = AnalysisResult(project=project)

    raw: List[Finding] = []
    for rule in make_rules(cfg.rules):
        raw.extend(rule.run(project))
        if isinstance(rule, JitVariantBudget):
            result.jit_degrees = dict(rule.degrees)

    # malformed suppressions are findings themselves (not disableable)
    if cfg.rules is None or BAD_SUPPRESSION in cfg.rules:
        for mod in project.modules.values():
            for line, body in mod.suppressions.malformed:
                raw.append(Finding(
                    rule=BAD_SUPPRESSION, path=mod.rel_path, line=line,
                    col=0, qualname="<module>",
                    message=f"malformed fslint suppression "
                            f"'disable={body}'; the form is "
                            f"disable=FSxxx(reason), reason required"))

    mods_by_rel = {m.rel_path: m for m in project.modules.values()}
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.col, f.rule)):
        mod = mods_by_rel.get(f.path)
        if f.rule != BAD_SUPPRESSION and mod is not None and \
                mod.suppressions.covers(f.line, f.rule):
            result.suppressed.append(f)
        else:
            result.findings.append(f)
    return result


def jit_budget(paths: List[str], config: Optional[Config] = None,
               repo_root: Optional[str] = None) -> Dict[str, int]:
    """Static degrees-of-freedom per hot jitted function (FS002)."""
    cfg = config or Config()
    cfg = Config(**{**cfg.__dict__, "rules": ("FS002",)})
    return run_analysis(paths, cfg, repo_root).jit_degrees
