"""Project index: call graph, jit registry, donation propagation.

Resolution is deliberately conservative for a linter:

* ``Name`` callees resolve through module-level defs and import maps.
* ``self.m(...)`` resolves to the enclosing class's method.
* ``obj.m(...)`` falls back to *every* project method named ``m`` —
  an over-approximation that keeps reachability sound (FS003/FS004
  would rather scan one extra function than miss the hot path behind
  ``runner.decode`` / ``pools.copy_in_staged``).

Donation facts start at ``jax.jit(..., donate_argnums=...)`` defs and
propagate to wrappers: a function that forwards its own parameter into
a donated position donates that parameter too, so FS001 holds callers
of ``DecodeRunner.decode`` to the same rebind contract as callers of
the raw jitted step.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.astutil import (
    FunctionInfo,
    ModuleInfo,
    call_name,
    iter_source_files,
    last_component,
    load_module,
    source_roots,
)
from repro.analysis.core import Config


@dataclass
class JitSpec:
    qualname: str
    static_argnames: Tuple[str, ...] = ()
    donate_argnums: Tuple[int, ...] = ()


def _const_strs(node: ast.expr) -> Tuple[str, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
        return tuple(out)
    return ()


def _const_ints(node: ast.expr) -> Tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.append(e.value)
        return tuple(out)
    return ()


def _is_jax_jit(node: ast.expr) -> bool:
    name = None
    if isinstance(node, (ast.Name, ast.Attribute)):
        from repro.analysis.astutil import dotted_path
        name = dotted_path(node)
    return name in ("jax.jit", "jit")


# SPMD wrappers that preserve the wrapped function's signature: a
# ``jax.jit(shard_map(f, ...), donate_argnums=...)`` site donates f's
# params exactly like ``jax.jit(f, ...)`` would, so the indexer must
# see through them or every mesh-sharded step shows up as an undonated
# unbucketed hot-path jit (false FS001/FS002/FS006).
_SPMD_WRAPPERS = ("shard_map", "jax.experimental.shard_map.shard_map",
                  "shmap", "pjit", "jax.experimental.pjit.pjit")


def _unwrap_jit_target(node: ast.expr) -> Optional[str]:
    """Dotted name of the function a jit call-form ultimately wraps.

    Sees through signature-preserving SPMD wrappers (``shard_map``,
    ``pjit``) and ``functools.partial`` so assignment-style specs like
    ``g = jax.jit(shard_map(f, mesh=..., out_specs=...), ...)`` map the
    alias ``g`` back onto ``f``'s def (param names then resolve for
    donation/bucketing facts).  None when the target is dynamic."""
    from repro.analysis.astutil import dotted_path
    if isinstance(node, (ast.Name, ast.Attribute)):
        return dotted_path(node)
    if isinstance(node, ast.Call) and node.args:
        callee = call_name(node)
        if callee in _SPMD_WRAPPERS + ("functools.partial", "partial"):
            return _unwrap_jit_target(node.args[0])
    return None


def parse_jit_decorator(dec: ast.expr) -> Optional[Tuple[Tuple[str, ...],
                                                         Tuple[int, ...]]]:
    """(static_argnames, donate_argnums) if ``dec`` is a jax.jit
    decoration, else None."""
    if _is_jax_jit(dec):
        return (), ()
    if isinstance(dec, ast.Call):
        callee = call_name(dec)
        if callee in ("functools.partial", "partial") and dec.args \
                and _is_jax_jit(dec.args[0]):
            static: Tuple[str, ...] = ()
            donate: Tuple[int, ...] = ()
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    static = _const_strs(kw.value)
                elif kw.arg == "donate_argnums":
                    donate = _const_ints(kw.value)
            return static, donate
        if _is_jax_jit(dec.func):
            static = ()
            donate = ()
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    static = _const_strs(kw.value)
                elif kw.arg == "donate_argnums":
                    donate = _const_ints(kw.value)
            return static, donate
    return None


class Project:
    """Cross-module index over a set of scanned source files."""

    def __init__(self, paths: List[Path], repo_root: Path, config: Config):
        self.config = config
        self.repo_root = repo_root
        self.modules: Dict[str, ModuleInfo] = {}
        roots = source_roots(paths)
        for f in iter_source_files(paths):
            mod = load_module(f, roots, repo_root)
            if mod is not None:
                self.modules[mod.modname] = mod

        # qualname -> FunctionInfo, plus a bare-name index for the
        # conservative attribute-call fallback.
        self.functions: Dict[str, FunctionInfo] = {}
        self.by_bare_name: Dict[str, List[FunctionInfo]] = {}
        for mod in self.modules.values():
            for qual, fi in mod.functions.items():
                self.functions[qual] = fi
                self.by_bare_name.setdefault(fi.name, []).append(fi)

        self.jit_specs: Dict[str, JitSpec] = {}
        # assignment-style alias qual -> the def it wraps (possibly
        # through shard_map/pjit/partial); _build_edges links the two so
        # reachable_from(jit_specs) covers the wrapped body ("inside the
        # trace" facts like FS006's donation exemption hold for it).
        self._jit_alias_of: Dict[str, str] = {}
        self._index_jit_defs()

        # qualname -> donated param names (seeded from jit specs,
        # closed under wrapper propagation).
        self.donated_params: Dict[str, Set[str]] = {}
        self._propagate_donation()

        self._edges: Dict[str, Set[str]] = {}
        self._build_edges()

        self.bucketing_sources: Set[str] = set(config.bucketing_helpers)
        self._derive_bucketing_sources()

        self.hot: Set[str] = set()
        self._compute_hot_set()

    # -- jit registry ---------------------------------------------------

    def _index_jit_defs(self) -> None:
        for qual, fi in self.functions.items():
            for dec in fi.node.decorator_list:
                parsed = parse_jit_decorator(dec)
                if parsed is not None:
                    static, donate = parsed
                    self.jit_specs[qual] = JitSpec(qual, static, donate)
                    break
        # assignment-style: g = jax.jit(f, donate_argnums=..., ...)
        for mod in self.modules.values():
            for node in ast.walk(mod.tree):
                if not (isinstance(node, ast.Assign)
                        and isinstance(node.value, ast.Call)
                        and _is_jax_jit(node.value.func)):
                    continue
                parsed = parse_jit_decorator(node.value)
                # re-parse as a call form: jax.jit(f, kw=...)
                static: Tuple[str, ...] = ()
                donate: Tuple[int, ...] = ()
                for kw in node.value.keywords:
                    if kw.arg == "static_argnames":
                        static = _const_strs(kw.value)
                    elif kw.arg == "donate_argnums":
                        donate = _const_ints(kw.value)
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        qual = f"{mod.modname}.{tgt.id}"
                        self.jit_specs[qual] = JitSpec(qual, static, donate)
                        # map the alias onto the wrapped def so param
                        # names resolve — including through shard_map/
                        # pjit/partial wrappers (signature-preserving)
                        if node.value.args:
                            wrapped = _unwrap_jit_target(node.value.args[0])
                            if wrapped:
                                src = mod.functions.get(
                                    f"{mod.modname}.{wrapped}")
                                if src is not None:
                                    self.functions.setdefault(qual, src)
                                    self._jit_alias_of[qual] = src.qualname
                                    # rules look up facts by the qualname
                                    # a call RESOLVES to — the wrapped
                                    # def — so mirror the spec there
                                    self.jit_specs.setdefault(
                                        src.qualname,
                                        JitSpec(src.qualname, static,
                                                donate))

    def jit_spec_for(self, fi: FunctionInfo) -> Optional[JitSpec]:
        return self.jit_specs.get(fi.qualname)

    # -- call resolution ------------------------------------------------

    def resolve_call(self, call: ast.Call, mod: ModuleInfo,
                     caller: Optional[FunctionInfo]) -> List[FunctionInfo]:
        name = call_name(call)
        if name is None:
            return []
        return self.resolve_name(name, mod, caller)

    def resolve_name(self, name: str, mod: ModuleInfo,
                     caller: Optional[FunctionInfo]) -> List[FunctionInfo]:
        parts = name.split(".")
        # plain name: module-level def, then imported symbol
        if len(parts) == 1:
            fi = self.functions.get(f"{mod.modname}.{name}")
            if fi is not None:
                return [fi]
            full = mod.imports.get(name)
            if full is not None and full in self.functions:
                return [self.functions[full]]
            return []
        # self.m / cls.m -> method on the enclosing class
        if parts[0] in ("self", "cls") and caller is not None \
                and caller.class_name is not None and len(parts) == 2:
            fi = self.functions.get(
                f"{mod.modname}.{caller.class_name}.{parts[1]}")
            return [fi] if fi is not None else []
        # import-alias rooted: ops.insert_prefill, repro.kernels.ops.f
        root = mod.imports.get(parts[0])
        if root is not None:
            full = ".".join([root] + parts[1:])
            if full in self.functions:
                return [self.functions[full]]
        if name in self.functions:
            return [self.functions[name]]
        # Class.method in same module (e.g. FaultInjector.wrap_copy)
        if len(parts) == 2:
            fi = self.functions.get(f"{mod.modname}.{parts[0]}.{parts[1]}")
            if fi is not None:
                return [fi]
        # conservative fallback: any method with the same bare name
        bare = parts[-1]
        return [fi for fi in self.by_bare_name.get(bare, ())
                if fi.is_method]

    # -- call edges / reachability ---------------------------------------

    def _build_edges(self) -> None:
        for qual, fi in self.functions.items():
            callees: Set[str] = set()
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                # calls inside a nested named def belong to that def's
                # edge set (it is indexed separately); lambdas fold
                # into the enclosing def.
                owner = fi.module.function_for(node)
                if owner is not None and owner.node is not fi.node:
                    continue
                for target in self.resolve_call(node, fi.module, fi):
                    callees.add(target.qualname)
                # a nested def called locally also contributes an edge
                # to itself implicitly via resolve_call's qual lookup —
                # additionally link container -> nested def so
                # reachability descends into closures that are only
                # *referenced* (registered as callbacks), not called.
            for sub_qual, sub_fi in fi.module.functions.items():
                if sub_fi.node is not fi.node and \
                        sub_qual.startswith(qual + "."):
                    callees.add(sub_qual)
            wrapped = self._jit_alias_of.get(qual)
            if wrapped is not None:
                callees.add(wrapped)
            self._edges[qual] = callees

    def callees(self, qual: str) -> Set[str]:
        return self._edges.get(qual, set())

    def reachable_from(self, roots: Iterable[str]) -> Set[str]:
        seen: Set[str] = set()
        stack = [r for r in roots if r in self.functions]
        while stack:
            cur = stack.pop()
            if cur in seen:
                continue
            seen.add(cur)
            stack.extend(self._edges.get(cur, ()))
        return seen

    # -- hot set ---------------------------------------------------------

    def _compute_hot_set(self) -> None:
        cfg = self.config
        roots = []
        for qual, fi in self.functions.items():
            if fi.name in cfg.hot_root_names or \
                    any(fi.name.startswith(p) for p in cfg.hot_root_prefixes):
                roots.append(qual)
        self.hot = self.reachable_from(roots)

    # -- donation ---------------------------------------------------------

    def _propagate_donation(self) -> None:
        for qual, spec in self.jit_specs.items():
            fi = self.functions.get(qual)
            if fi is None or not spec.donate_argnums:
                continue
            pos = fi.positional_params
            names = {pos[i] for i in spec.donate_argnums if i < len(pos)}
            if names:
                self.donated_params[qual] = names

        changed = True
        while changed:
            changed = False
            for qual, fi in self.functions.items():
                my_params = set(fi.params)
                for node in ast.walk(fi.node):
                    if not isinstance(node, ast.Call):
                        continue
                    for callee in self.resolve_call(node, fi.module, fi):
                        donated = self.donated_params.get(callee.qualname)
                        if not donated:
                            continue
                        for pname, arg in self.map_call_args(node, callee):
                            if pname in donated and isinstance(arg, ast.Name) \
                                    and arg.id in my_params:
                                cur = self.donated_params.setdefault(
                                    qual, set())
                                if arg.id not in cur:
                                    cur.add(arg.id)
                                    changed = True

    def map_call_args(self, call: ast.Call,
                      callee: FunctionInfo) -> List[Tuple[str, ast.expr]]:
        """(param_name, arg_expr) pairs for a call site.  For methods
        called attribute-style the implicit self consumes the first
        positional parameter."""
        params = callee.positional_params
        offset = 0
        if callee.is_method and isinstance(call.func, ast.Attribute):
            from repro.analysis.astutil import dotted_path
            root = dotted_path(call.func)
            # ClassName.method(obj, ...) passes self explicitly
            if not (root and root.split(".")[0] == callee.class_name):
                offset = 1
        pairs: List[Tuple[str, ast.expr]] = []
        for i, arg in enumerate(call.args):
            if isinstance(arg, ast.Starred):
                break
            idx = i + offset
            if idx < len(params):
                pairs.append((params[idx], arg))
        for kw in call.keywords:
            if kw.arg is not None:
                pairs.append((kw.arg, kw.value))
        return pairs

    # -- bucketing sources (FS002) ----------------------------------------

    def _derive_bucketing_sources(self) -> None:
        """A function whose return expression calls an approved
        bucketing helper is itself a bucketing source (``_pad_runs``
        returns pow2-padded run tables)."""
        changed = True
        while changed:
            changed = False
            for qual, fi in self.functions.items():
                if last_component(qual) in self.bucketing_sources:
                    continue
                for node in ast.walk(fi.node):
                    if not isinstance(node, ast.Return) or node.value is None:
                        continue
                    for sub in ast.walk(node.value):
                        if isinstance(sub, ast.Call):
                            cn = call_name(sub)
                            if cn and last_component(cn) in \
                                    self.bucketing_sources:
                                self.bucketing_sources.add(
                                    last_component(qual))
                                changed = True
                                break

    def is_bucketing_call(self, call: ast.Call) -> bool:
        cn = call_name(call)
        return cn is not None and last_component(cn) in self.bucketing_sources
