"""Intra-function dataflow for the fslint rules.

Three small abstract interpreters over a linear (source-order) walk of
a function body.  Branches are walked in order and joined by union —
sound enough for a linter, and exactly the precision the repo's hot
path needs:

* **Bucket flags** (FS002): is an expression derived from a pow2
  bucketing helper (``bucketed``) or from a per-call varying size like
  ``len(...)`` without bucketing (``suspect``)?
* **Device taint** (FS003): does an expression hold a live jax device
  value (so ``np.asarray`` / ``int()`` / ``.item()`` on it forces a
  host sync)?  Class attributes assigned device values in any method
  are device-tainted in every method (the deferred-sync token ring
  buffer pattern in ``decode_runner.py``).
* **Direction labels** (FS004): which data-plane closures were created
  under a ``direction == "out"`` guard, so the swap-worker
  reachability check knows which closures a thread can actually run.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import (
    FunctionInfo,
    call_name,
    dotted_path,
    last_component,
)

# ---------------------------------------------------------------------------
# FS002: bucket flags
# ---------------------------------------------------------------------------

_ARRAY_CONSTRUCTORS = ("asarray", "array", "zeros", "ones", "full", "empty",
                       "arange")
_SIZE_CALLS = ("len", "sum")
_HOST_ARRAY_ROOTS = ("np", "numpy")

# metadata attributes of a device array that live on the host
HOST_META_ATTRS = ("shape", "dtype", "ndim", "size", "at")


@dataclass
class BucketFlags:
    bucketed: bool = False
    suspect: bool = False

    @staticmethod
    def join(flags: List["BucketFlags"]) -> "BucketFlags":
        bucketed = any(f.bucketed for f in flags)
        suspect = any(f.suspect for f in flags) and not bucketed
        return BucketFlags(bucketed, suspect)


class BucketEnv:
    """Source-order walk of one function computing bucket flags for
    every local name."""

    def __init__(self, fi: FunctionInfo, project) -> None:
        self.fi = fi
        self.project = project
        self.env: Dict[str, BucketFlags] = {}
        self._walk(fi.node.body)

    # -- expression evaluation --------------------------------------------

    def flags(self, expr: ast.expr) -> BucketFlags:
        if isinstance(expr, ast.Name):
            return self.env.get(expr.id, BucketFlags())
        if isinstance(expr, ast.Call):
            cn = call_name(expr)
            if cn is not None:
                bare = last_component(cn)
                if bare in self.project.bucketing_sources:
                    return BucketFlags(bucketed=True)
                if bare in _SIZE_CALLS and "." not in cn:
                    return BucketFlags(suspect=True)
                if bare in ("max", "min") and "." not in cn:
                    return BucketFlags.join([self.flags(a)
                                             for a in expr.args]) \
                        if expr.args else BucketFlags()
                if bare in _ARRAY_CONSTRUCTORS:
                    # flags of an array value follow its data/shape arg
                    if expr.args:
                        return self.flags(expr.args[0])
            return BucketFlags()
        if isinstance(expr, ast.BinOp):
            return BucketFlags.join([self.flags(expr.left),
                                     self.flags(expr.right)])
        if isinstance(expr, ast.UnaryOp):
            return self.flags(expr.operand)
        if isinstance(expr, (ast.Tuple, ast.List)):
            if not expr.elts:
                return BucketFlags()
            return BucketFlags.join([self.flags(e) for e in expr.elts])
        if isinstance(expr, ast.IfExp):
            return BucketFlags.join([self.flags(expr.body),
                                     self.flags(expr.orelse)])
        if isinstance(expr, ast.Subscript):
            return self.flags(expr.value)
        if isinstance(expr, ast.Starred):
            return self.flags(expr.value)
        return BucketFlags()

    # -- statement walk ----------------------------------------------------

    def _bind(self, target: ast.expr, flags: BucketFlags) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = flags
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, flags)

    def _walk(self, body: List[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, ast.Assign):
                value = stmt.value
                for target in stmt.targets:
                    if isinstance(target, (ast.Tuple, ast.List)) and \
                            isinstance(value, (ast.Tuple, ast.List)) and \
                            len(target.elts) == len(value.elts):
                        for t, v in zip(target.elts, value.elts):
                            self._bind(t, self.flags(v))
                    else:
                        self._bind(target, self.flags(value))
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                self._bind(stmt.target, self.flags(stmt.value))
            elif isinstance(stmt, ast.AugAssign):
                if isinstance(stmt.target, ast.Name):
                    cur = self.env.get(stmt.target.id, BucketFlags())
                    self.env[stmt.target.id] = BucketFlags.join(
                        [cur, self.flags(stmt.value)])
            elif isinstance(stmt, (ast.If,)):
                self._walk(stmt.body)
                self._walk(stmt.orelse)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                self._bind(stmt.target, self.flags(stmt.iter))
                self._walk(stmt.body)
                self._walk(stmt.orelse)
            elif isinstance(stmt, ast.While):
                self._walk(stmt.body)
                self._walk(stmt.orelse)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                self._walk(stmt.body)
            elif isinstance(stmt, ast.Try):
                self._walk(stmt.body)
                for h in stmt.handlers:
                    self._walk(h.body)
                self._walk(stmt.orelse)
                self._walk(stmt.finalbody)


# ---------------------------------------------------------------------------
# FS003: device taint
# ---------------------------------------------------------------------------

@dataclass
class SyncSite:
    node: ast.AST
    kind: str       # "np.asarray", "int()", ".item()", "block_until_ready",
                    # "device_get", "implicit-bool"
    detail: str


class DeviceWalk:
    """Device-taint walk of one function.

    ``class_device_attrs`` maps a ``self``-relative attribute path
    (``"pools.gpu"``, ``"_pending"``) to its kind — ``"value"`` (the
    attribute IS a device array) or ``"container"`` (a host container
    holding device elements, like the deferred-token ring buffer;
    truthiness/len on it stay host, indexing/iteration yield device
    values).  ``device_returning`` is the set of project function
    qualnames whose return value is device-tainted.
    """

    def __init__(self, fi: FunctionInfo, project,
                 class_device_attrs: Dict[str, str],
                 device_returning: Set[str]) -> None:
        self.fi = fi
        self.project = project
        self.mod = fi.module
        self.class_attrs = class_device_attrs
        self.device_returning = device_returning
        self.env: Dict[str, bool] = {}
        self.syncs: List[SyncSite] = []
        self.attr_writes: Dict[str, str] = {}  # rel path -> kind
        self.returns_device = False
        self._walk(fi.node.body)

    @staticmethod
    def _self_rel(path: Optional[str]) -> Optional[str]:
        if path is not None and path.startswith("self."):
            return path[len("self."):]
        return None

    def _attr_kind(self, expr: ast.expr) -> Optional[str]:
        rel = self._self_rel(dotted_path(expr))
        if rel is None:
            return None
        return self.class_attrs.get(rel)

    # -- helpers -----------------------------------------------------------

    def _resolved_module_root(self, name: str) -> Optional[str]:
        root = name.split(".")[0]
        return self.mod.imports.get(root, root)

    def _is_device_module_call(self, cn: str) -> bool:
        full = self._resolved_module_root(cn)
        if full is None:
            return False
        cfg = self.project.config
        return full in cfg.device_modules or full.startswith("jax.") \
            or full == "jax"

    def _is_numpy_call(self, cn: str) -> bool:
        full = self._resolved_module_root(cn)
        return full in ("numpy",) or cn.split(".")[0] in _HOST_ARRAY_ROOTS

    def device(self, expr: ast.expr) -> bool:
        if isinstance(expr, ast.Name):
            return self.env.get(expr.id, False)
        if isinstance(expr, ast.Attribute):
            if expr.attr in HOST_META_ATTRS:
                return False
            path = dotted_path(expr)
            if path is not None:
                if path in self.env:
                    return self.env[path]
                if self._attr_kind(expr) == "value":
                    return True
                if self._attr_kind(expr) is not None:
                    return False  # container itself is host
            return self.device(expr.value)
        if isinstance(expr, ast.Subscript):
            # indexing a device-element container yields a device value
            if self._attr_kind(expr.value) == "container":
                return True
            return self.device(expr.value)
        if isinstance(expr, ast.Call):
            return self._call_device(expr)
        if isinstance(expr, (ast.BinOp,)):
            return self.device(expr.left) or self.device(expr.right)
        if isinstance(expr, ast.UnaryOp):
            return self.device(expr.operand)
        if isinstance(expr, ast.Compare):
            ops_sync = [o for o in expr.ops
                        if not isinstance(o, (ast.Is, ast.IsNot,
                                              ast.In, ast.NotIn))]
            if not ops_sync:
                return False
            return self.device(expr.left) or \
                any(self.device(c) for c in expr.comparators)
        if isinstance(expr, ast.BoolOp):
            return any(self.device(v) for v in expr.values)
        if isinstance(expr, (ast.Tuple, ast.List)):
            return any(self.device(e) for e in expr.elts)
        if isinstance(expr, ast.IfExp):
            return self.device(expr.body) or self.device(expr.orelse)
        return False

    def _call_device(self, call: ast.Call) -> bool:
        cn = call_name(call)
        if cn is None:
            return False
        bare = last_component(cn)
        # numpy conversions produce host values
        if self._is_numpy_call(cn) and "." in cn:
            return False
        if bare == "item":
            return False
        if self._is_device_module_call(cn) and "." in cn:
            return True
        if bare in self.project.config.device_functions:
            return True
        for target in self.project.resolve_call(call, self.mod, self.fi):
            if target.qualname in self.project.jit_specs:
                return True
            if target.qualname in self.device_returning:
                return True
        return False

    # -- sync detection ----------------------------------------------------

    def _check_call(self, call: ast.Call) -> None:
        cn = call_name(call)
        if cn is None:
            return
        bare = last_component(cn)
        full_root = self._resolved_module_root(cn)
        if bare in ("block_until_ready", "device_get") and \
                (full_root == "jax" or (full_root or "").startswith("jax")):
            self.syncs.append(SyncSite(call, f"jax.{bare}",
                                       f"jax.{bare} forces a host sync"))
            return
        if bare in ("asarray", "array") and self._is_numpy_call(cn) \
                and "." in cn and call.args and self.device(call.args[0]):
            self.syncs.append(SyncSite(
                call, "np.asarray",
                f"{cn}(...) on a device value blocks on the transfer"))
            return
        if bare in ("int", "float", "bool") and "." not in cn and \
                call.args and self.device(call.args[0]):
            self.syncs.append(SyncSite(
                call, f"{bare}()",
                f"{bare}() on a device value forces a host sync"))
            return
        if bare == "item" and isinstance(call.func, ast.Attribute) and \
                self.device(call.func.value):
            self.syncs.append(SyncSite(
                call, ".item()",
                ".item() on a device value forces a host sync"))

    def _scan_expr(self, expr: Optional[ast.expr]) -> None:
        if expr is None:
            return
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                self._check_call(node)

    # -- statement walk ----------------------------------------------------

    def _bind(self, target: ast.expr, dev: bool) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = dev
        elif isinstance(target, ast.Attribute):
            path = dotted_path(target)
            if path is not None:
                self.env[path] = dev
                rel = self._self_rel(path)
                if dev and rel is not None:
                    self.attr_writes[rel] = "value"
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, dev)

    def _walk(self, body: List[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, ast.Assign):
                self._scan_expr(stmt.value)
                dev = self.device(stmt.value)
                for target in stmt.targets:
                    self._bind(target, dev)
            elif isinstance(stmt, ast.AnnAssign):
                self._scan_expr(stmt.value)
                if stmt.value is not None:
                    self._bind(stmt.target, self.device(stmt.value))
            elif isinstance(stmt, ast.AugAssign):
                self._scan_expr(stmt.value)
                if isinstance(stmt.target, ast.Name):
                    self.env[stmt.target.id] = (
                        self.env.get(stmt.target.id, False)
                        or self.device(stmt.value))
            elif isinstance(stmt, ast.Expr):
                self._scan_expr(stmt.value)
                # device values flowing into container attributes taint
                # the attribute for the whole class (ring buffers)
                if isinstance(stmt.value, ast.Call) and \
                        isinstance(stmt.value.func, ast.Attribute) and \
                        stmt.value.func.attr in ("append", "add", "extend"):
                    rel = self._self_rel(
                        dotted_path(stmt.value.func.value))
                    if rel is not None and \
                            any(self.device(a) for a in stmt.value.args):
                        self.attr_writes.setdefault(rel, "container")
            elif isinstance(stmt, ast.Return):
                self._scan_expr(stmt.value)
                if stmt.value is not None and self.device(stmt.value):
                    self.returns_device = True
            elif isinstance(stmt, ast.If):
                self._scan_expr(stmt.test)
                if self.device(stmt.test):
                    self.syncs.append(SyncSite(
                        stmt.test, "implicit-bool",
                        "branching on a device value forces a host sync"))
                self._walk(stmt.body)
                self._walk(stmt.orelse)
            elif isinstance(stmt, ast.While):
                self._scan_expr(stmt.test)
                if self.device(stmt.test):
                    self.syncs.append(SyncSite(
                        stmt.test, "implicit-bool",
                        "looping on a device value forces a host sync"))
                self._walk(stmt.body)
                self._walk(stmt.orelse)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                self._scan_expr(stmt.iter)
                elem_dev = (self.device(stmt.iter)
                            or self._attr_kind(stmt.iter) == "container")
                self._bind(stmt.target, elem_dev)
                self._walk(stmt.body)
                self._walk(stmt.orelse)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self._scan_expr(item.context_expr)
                self._walk(stmt.body)
            elif isinstance(stmt, ast.Try):
                self._walk(stmt.body)
                for h in stmt.handlers:
                    self._walk(h.body)
                self._walk(stmt.orelse)
                self._walk(stmt.finalbody)
            elif isinstance(stmt, (ast.Assert, ast.Raise, ast.Delete)):
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Call):
                        self._check_call(node)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                pass   # nested defs are analysed as their own functions


def class_device_attrs(project, cls_module, class_name: str,
                       device_returning: Set[str]) -> Dict[str, str]:
    """Fixpoint of device-tainted attribute paths for one class
    (``"value"`` wins over ``"container"`` when both are observed)."""
    attrs: Dict[str, str] = {}
    methods = [fi for fi in cls_module.functions.values()
               if fi.class_name == class_name]
    changed = True
    while changed:
        changed = False
        for fi in methods:
            walk = DeviceWalk(fi, project, attrs, device_returning)
            for rel, kind in walk.attr_writes.items():
                if attrs.get(rel) not in ("value", kind):
                    attrs[rel] = ("value" if "value" in
                                  (attrs.get(rel), kind) else kind)
                    changed = True
    return attrs


def device_returning_functions(project) -> Set[str]:
    """Qualnames of project functions whose return value is
    device-tainted (fixpoint across modules)."""
    out: Set[str] = set()
    changed = True
    while changed:
        changed = False
        for fi in project.functions.values():
            if fi.qualname in out:
                continue
            walk = DeviceWalk(fi, project, {}, out)
            if walk.returns_device:
                out.add(fi.qualname)
                changed = True
    return out


# ---------------------------------------------------------------------------
# FS004: direction-labelled closures
# ---------------------------------------------------------------------------

@dataclass
class ClosureRecord:
    label: Optional[str]            # "out", "in", or None (unknown)
    callees: Tuple[str, ...]        # resolved qualnames called by the body
    node: ast.AST                   # the lambda / def / name reference
    registered_at: Optional[ast.AST] = None


@dataclass
class DirectionFacts:
    """Per-project registry of data-plane closures and submit sites."""
    registered: List[ClosureRecord] = field(default_factory=list)
    # (module, call node, submit target quals, guard label)
    submit_sites: List[Tuple[object, ast.Call, Tuple[str, ...],
                             Optional[str]]] = field(default_factory=list)
    # functions that invoke a registered closure indirectly
    # (qual -> guard label at the `.copy_fn()` call, or None)
    indirect_callers: Dict[str, Optional[str]] = field(default_factory=dict)


def _direction_test_label(test: ast.expr, cfg) -> Optional[Tuple[str, bool]]:
    """If ``test`` (possibly inside an ``and``) compares the direction
    variable against a constant, return (label, exact) where ``exact``
    is True for a bare comparison (so the else-branch gets the
    complementary label) and False when the comparison is one conjunct
    of an ``and`` (else-branch label unknown)."""
    def match(cmp: ast.expr) -> Optional[str]:
        if not isinstance(cmp, ast.Compare) or len(cmp.ops) != 1:
            return None
        if not isinstance(cmp.ops[0], ast.Eq):
            return None
        left, right = cmp.left, cmp.comparators[0]
        for a, b in ((left, right), (right, left)):
            pa = dotted_path(a)
            if pa is not None and last_component(pa) == cfg.direction_var \
                    and isinstance(b, ast.Constant) \
                    and isinstance(b.value, str):
                return b.value
        return None

    direct = match(test)
    if direct is not None:
        return direct, True
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
        for v in test.values:
            m = match(v)
            if m is not None:
                return m, False
    return None


class DirectionWalk:
    """Collect closure records and submit sites for one function."""

    def __init__(self, fi: FunctionInfo, project,
                 facts: DirectionFacts) -> None:
        self.fi = fi
        self.project = project
        self.cfg = project.config
        self.facts = facts
        self.env: Dict[str, List[ClosureRecord]] = {}
        self._walk(fi.node.body, label=None)

    def _lambda_record(self, node: ast.expr,
                       label: Optional[str]) -> Optional[ClosureRecord]:
        if isinstance(node, ast.Lambda):
            callees: List[str] = []
            for sub in ast.walk(node.body):
                if isinstance(sub, ast.Call):
                    for t in self.project.resolve_call(
                            sub, self.fi.module, self.fi):
                        callees.append(t.qualname)
            return ClosureRecord(label, tuple(callees), node)
        path = dotted_path(node)
        if path is not None:
            # a reference to a named function
            targets = self.project.resolve_name(path, self.fi.module, self.fi)
            if targets:
                return ClosureRecord(
                    label, tuple(t.qualname for t in targets), node)
        return None

    def _closures_of(self, expr: ast.expr,
                     label: Optional[str]) -> List[ClosureRecord]:
        if isinstance(expr, ast.Name) and expr.id in self.env:
            return list(self.env[expr.id])
        if isinstance(expr, ast.IfExp):
            return (self._closures_of(expr.body, label)
                    + self._closures_of(expr.orelse, label))
        if isinstance(expr, ast.Call):
            cn = call_name(expr)
            if cn is not None and last_component(cn) in \
                    self.cfg.passthrough_wrappers:
                out: List[ClosureRecord] = []
                for a in list(expr.args) + [k.value for k in expr.keywords]:
                    out.extend(self._closures_of(a, label))
                return out
            return []
        rec = self._lambda_record(expr, label)
        return [rec] if rec is not None else []

    def _register(self, expr: ast.expr, label: Optional[str],
                  site: ast.AST) -> None:
        for rec in self._closures_of(expr, label):
            rec.registered_at = site
            self.facts.registered.append(rec)

    def _scan_calls(self, expr: Optional[ast.expr],
                    label: Optional[str]) -> None:
        if expr is None:
            return
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            # executor.submit(f, ...) —— a thread dispatch site
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "submit" and node.args:
                targets: List[str] = []
                for t in self._closures_of(node.args[0], label):
                    targets.extend(t.callees)
                fpath = dotted_path(node.args[0])
                if fpath is not None:
                    for t in self.project.resolve_name(
                            fpath, self.fi.module, self.fi):
                        targets.append(t.qualname)
                self.facts.submit_sites.append(
                    (self.fi, node, tuple(targets), label))
            # keyword registration: f(..., copy_fn=<closure>)
            for kw in node.keywords:
                if kw.arg in self.cfg.copy_fn_names:
                    self._register(kw.value, label, node)
            # indirect invocation: task.copy_fn()
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr in self.cfg.copy_fn_names:
                cur = self.facts.indirect_callers.get(self.fi.qualname)
                # keep the least restrictive guard seen (None < label)
                if self.fi.qualname not in self.facts.indirect_callers or \
                        cur is not None and label is None:
                    self.facts.indirect_callers[self.fi.qualname] = label

    def _walk(self, body: List[ast.stmt], label: Optional[str]) -> None:
        for stmt in body:
            if isinstance(stmt, ast.Assign):
                self._scan_calls(stmt.value, label)
                closures = self._closures_of(stmt.value, label)
                for target in stmt.targets:
                    if isinstance(target, ast.Name):
                        self.env[target.id] = closures
                    elif isinstance(target, ast.Attribute) and \
                            target.attr in self.cfg.copy_fn_names:
                        self._register(stmt.value, label, stmt)
            elif isinstance(stmt, ast.If):
                self._scan_calls(stmt.test, label)
                guard = _direction_test_label(stmt.test, self.cfg)
                if guard is not None:
                    body_label, exact = guard
                    other = None
                    if exact:
                        other = (self.cfg.out_label
                                 if body_label != self.cfg.out_label
                                 else "in")
                    self._walk(stmt.body, body_label)
                    self._walk(stmt.orelse, other if exact else label)
                else:
                    self._walk(stmt.body, label)
                    self._walk(stmt.orelse, label)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                self._scan_calls(stmt.iter, label)
                self._walk(stmt.body, label)
                self._walk(stmt.orelse, label)
            elif isinstance(stmt, ast.While):
                self._scan_calls(stmt.test, label)
                self._walk(stmt.body, label)
                self._walk(stmt.orelse, label)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self._scan_calls(item.context_expr, label)
                self._walk(stmt.body, label)
            elif isinstance(stmt, ast.Try):
                self._walk(stmt.body, label)
                for h in stmt.handlers:
                    self._walk(h.body, label)
                self._walk(stmt.orelse, label)
                self._walk(stmt.finalbody, label)
            elif isinstance(stmt, (ast.Expr, ast.Return, ast.AugAssign,
                                   ast.AnnAssign, ast.Assert, ast.Raise)):
                for node in ast.iter_child_nodes(stmt):
                    if isinstance(node, ast.expr):
                        self._scan_calls(node, label)


def collect_direction_facts(project) -> DirectionFacts:
    facts = DirectionFacts()
    for fi in project.functions.values():
        DirectionWalk(fi, project, facts)
    return facts
