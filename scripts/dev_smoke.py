"""Dev-loop smoke: forward + train + prefill + decode for each smoke arch."""
import sys
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, list_archs
from repro.models import steps, transformer as T

ARCHS = sys.argv[1:] or list_archs()

for arch in ARCHS:
    cfg = get_smoke_config(arch)
    try:
        key = jax.random.PRNGKey(0)
        params = T.init_params(cfg, key)
        n = sum(x.size for x in jax.tree.leaves(params))
        B, S = 2, 32
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}
        if cfg.frontend is not None and cfg.frontend.kind == "vision":
            batch["extra_embeds"] = jnp.ones((B, cfg.frontend.n_tokens,
                                              cfg.frontend.d_embed), jnp.float32)
        if cfg.encoder_decoder:
            batch["encoder_frames"] = jnp.ones((B, cfg.n_encoder_tokens,
                                                cfg.d_model), jnp.float32)
        # train
        from repro.train.optimizer import adamw_init
        opt = adamw_init(params)
        p2, o2, loss = steps.train_step(params, opt, batch, cfg=cfg)
        assert jnp.isfinite(loss), f"loss not finite: {loss}"
        # prefill
        logits, raw = steps.prefill(params, cfg, tokens,
                                    extra_embeds=batch.get("extra_embeds"),
                                    encoder_frames=batch.get("encoder_frames"))
        assert logits.shape == (B, cfg.vocab_size), logits.shape
        assert bool(jnp.all(jnp.isfinite(logits)))
        caches = steps.caches_from_prefill(cfg, raw, B, 64)
        # decode 3 steps
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = S + (cfg.frontend.n_tokens if (cfg.frontend and cfg.frontend.kind == "vision") else 0)
        for i in range(3):
            tok, lg, caches = steps.serve_step(params, caches, tok, pos + i, cfg=cfg)
            assert bool(jnp.all(jnp.isfinite(lg))), f"decode {i} NaN"
        print(f"OK   {arch:26s} params={n:,} loss={float(loss):.3f}")
    except Exception as e:
        print(f"FAIL {arch}: {type(e).__name__}: {e}")
        traceback.print_exc()
