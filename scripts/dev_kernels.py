import jax, jax.numpy as jnp, numpy as np
from repro.kernels.ref import paged_attention_ref, block_copy_ref
from repro.kernels.paged_attention import paged_attention
from repro.kernels.block_copy import block_copy, block_copy_grouped

key = jax.random.PRNGKey(0)
B, Hq, Hkv, D, bs, nb, npages = 3, 8, 2, 64, 16, 32, 4
ks = jax.random.split(key, 5)
q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
kp = jax.random.normal(ks[1], (nb, bs, Hkv, D), jnp.float32)
vp = jax.random.normal(ks[2], (nb, bs, Hkv, D), jnp.float32)
bt = jax.random.permutation(ks[3], nb)[:B * npages].reshape(B, npages).astype(jnp.int32)
ctx = jnp.array([5, 33, 64], jnp.int32)
ref = paged_attention_ref(q, jnp.stack([kp, vp]), bt, ctx, 0.125)
out = paged_attention(q, kp, vp, bt, ctx, 0.125)
print("paged_attention maxerr", float(jnp.max(jnp.abs(ref - out))))

# block copy
E = 128
src = jax.random.normal(ks[4], (16, E), jnp.float32)
dst = jnp.zeros((12, E), jnp.float32)
si = jnp.array([3, 7, 1], jnp.int32)
di = jnp.array([0, 5, 11], jnp.int32)
ref2 = block_copy_ref(src, dst, si, di)
try:
    out2 = block_copy(src, dst, si, di)
    print("block_copy maxerr", float(jnp.max(jnp.abs(ref2 - out2))))
except Exception as e:
    print("block_copy FAIL:", type(e).__name__, e)

# grouped
ss = jnp.array([0, 8], jnp.int32)
ds = jnp.array([2, 6], jnp.int32)
ls = jnp.array([2, 4], jnp.int32)
ref3 = dst
for s, d, l in [(0, 2, 2), (8, 6, 4)]:
    ref3 = ref3.at[d:d + l].set(src[s:s + l])
try:
    out3 = block_copy_grouped(src, dst, ss, ds, ls, run_blocks=4)
    print("block_copy_grouped maxerr", float(jnp.max(jnp.abs(ref3 - out3))))
except Exception as e:
    print("block_copy_grouped FAIL:", type(e).__name__, e)
