#!/usr/bin/env sh
# Tier-1 verify in one command (ISSUE 2 tooling satellite):
#   scripts/tier1.sh                # full test suite + hot-path smoke benches
#   scripts/tier1.sh -k engine      # extra args forwarded to pytest
#   scripts/tier1.sh -m "not slow"  # deselect the heaviest parity replays
#                                   # (what the push/PR CI job runs; the
#                                   # scheduled job runs the full suite)
set -e
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# fslint gate (DESIGN.md §8): hot-path static analysis — donation
# safety, jit-variant budget, host-sync hygiene, swap-plane thread
# discipline.  Stdlib-only (no jax import), runs in milliseconds; the
# json report is uploaded by CI.  Any non-baselined finding fails the
# build.
python -m repro.analysis src/repro --format json \
    > /tmp/fslint.json || { cat /tmp/fslint.json; exit 1; }
# generic lint (unused imports / undefined names; [tool.ruff] in
# pyproject.toml) — runs wherever ruff is on PATH, skipped elsewhere
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks
fi
python -m pytest -x -q "$@"
# hot-path smoke benches emit BENCH_*.json artifacts (uploaded by CI so
# perf rows can be diffed across commits)
python benchmarks/decode_hotpath.py --smoke \
    --json-out /tmp/BENCH_decode_hotpath.json
python benchmarks/swap_path.py --smoke \
    --json-out /tmp/BENCH_swap_path.json
# mesh-sharded rows (ISSUE 8, multi-device CPU): each bench re-invokes
# with a forced 4-device host (--mesh sets XLA_FLAGS itself, pre-import)
# and MERGES its @1x1/@1x4 rows into the same artifact — the @1x1 row is
# the in-process no-regression reference for the sharded row, and the
# 4-way engine bit-parity tests run under pytest (tests/test_mesh_*)
python benchmarks/decode_hotpath.py --smoke --mesh 1x4 \
    --json-out /tmp/BENCH_decode_hotpath.json
python benchmarks/swap_path.py --smoke --mesh 1x4 \
    --json-out /tmp/BENCH_swap_path.json
# online serving-API smoke (ISSUE 5): open-world add_request/step replay
# with cancellations, sim + real, asserting the JSONL event log is
# well-formed and the SLO attainment records populate
python -m repro.launch.serve --online --smoke \
    --events /tmp/fastswitch_online_sim.jsonl
python -m repro.launch.serve --online --smoke --real \
    --events /tmp/fastswitch_online_real.jsonl
# chaos smoke (DESIGN.md §7): seeded fault schedule under the invariant
# sanitizer on EVERY step — faults must fire, step() must never crash,
# and the event log (error/shed/retry kinds included) stays well-formed
python -m repro.launch.serve --online --smoke --chaos \
    --events /tmp/fastswitch_online_chaos.jsonl
# prefix-cache smoke (DESIGN.md §10): real-mode shared-system-prompt
# replay with the refcount sanitizer (C1/C2) after EVERY step — the
# radix tree must produce actual cross-request hits
python -m repro.launch.serve --online --smoke --prefix-cache \
    --events /tmp/fastswitch_online_prefix.jsonl
# front-end smoke (DESIGN.md §11): loopback JSON-lines server over TWO
# sim replicas — concurrent socket clients (streaming, one follow-up
# through the affinity pin, one mid-decode abort), clean drain, then
# each replica's event log is validated AND the cross-replica affinity
# audit must report zero violations
python -m repro.frontend.loadgen --smoke \
    --events-prefix /tmp/fastswitch_online_frontend \
    --json-out /tmp/BENCH_frontend.json
