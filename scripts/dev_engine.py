"""Dev-loop: run the engine in sim mode for vllm vs fastswitch."""
import sys

from repro.core import EngineConfig, FastSwitchEngine
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import sample_conversations, trace_stats

n = int(sys.argv[1]) if len(sys.argv) > 1 else 100
convs = sample_conversations(n, seed=1)
print("trace:", trace_stats(convs))

for policy in ("vllm", "+dbg", "+dbg+reuse", "fastswitch"):
    cfg = EngineConfig(mode="sim", num_gpu_blocks=2048,
                       num_cpu_blocks=8192).with_policy(policy)
    trace = PriorityTrace(pattern="markov", update_freq=0.04, seed=7)
    eng = FastSwitchEngine(cfg, [c for c in convs], trace=trace)
    m = eng.run(max_iterations=200_000)
    s = m.summary()
    sw = eng.swap.stats()
    print(f"{policy:12s} p99ttft={s['p99_ttft_ms']:9.1f}ms "
          f"p999tbt={s['p999_tbt_ms']:8.1f}ms thr={s['throughput_tok_s']:7.1f} "
          f"tok={s['total_tokens']} iters={s['iterations']} "
          f"preempt={s['preemptions']} ops={sw['total_ops']} "
          f"blocks={sw['total_blocks']} stall={sw['total_stall_us']/1e6:.2f}s "
          f"gran={sw['total_blocks']/max(sw['total_ops'],1):.1f}")
