"""Fig. 9 — call-stack (control-plane) overhead vs priority-update
frequency.  Ours is a REAL measurement: wall time of the Python control
plane per iteration relative to modelled end-to-end time (paper: <1%)."""
from benchmarks.common import csv_line, run_policy


def main(emit=print, freqs=(0.01, 0.02, 0.04)):
    rows = {}
    for freq in freqs:
        eng = run_policy("llama8b-a10", "fastswitch", update_freq=freq)
        m = eng.metrics
        wall_us = m.callstack_wall_s * 1e6
        sim_us = m.total_time_us
        share = wall_us / max(sim_us, 1e-9)
        sync_us = eng.swap.callstack_overhead_us
        rows[freq] = (wall_us, share, sync_us)
        emit(csv_line(f"fig9_freq{freq}_callstack",
                      wall_us / max(m.iterations, 1),
                      f"share_of_e2e={share:.4f}"))
        emit(csv_line(f"fig9_freq{freq}_syncpoints",
                      sync_us / max(m.iterations, 1),
                      f"sync_us_total={sync_us:.0f}"))
    return rows


if __name__ == "__main__":
    main()
