"""Fig. 2 — in most iterations only a small fraction of requests wait on
KV-cache transfers (motivates async swapping of the affected few)."""
import numpy as np

from benchmarks.common import csv_line
from repro.core import EngineConfig, FastSwitchEngine
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import sample_conversations


def main(emit=print):
    convs = sample_conversations(120, rate_req_s=2.0, seed=7)
    cfg = EngineConfig(mode="sim", num_gpu_blocks=512, num_cpu_blocks=4096,
                       max_running=16).with_policy("fastswitch")
    eng = FastSwitchEngine(cfg, convs,
                           trace=PriorityTrace("markov", 0.02, seed=7))
    fractions = []
    while not eng.done() and eng.metrics.iterations < 200_000:
        eng.step()
        active = (len(eng.sched.running) + len(eng.sched.swapping_in))
        if active:
            fractions.append(len(eng.sched.swapping_in) / active)
    eng.swap.shutdown()
    f = np.asarray(fractions)
    emit(csv_line("fig2_mean_waiting_fraction", float(f.mean()) * 1e6,
                  f"mean={f.mean():.3f}"))
    emit(csv_line("fig2_p99_waiting_fraction",
                  float(np.percentile(f, 99)) * 1e6,
                  f"p99={np.percentile(f, 99):.3f}"))
    emit(csv_line("fig2_iters_with_no_waiting",
                  float((f == 0).mean()) * 1e6,
                  f"share={float((f == 0).mean()):.3f}"))
    return f


if __name__ == "__main__":
    main()
