"""Swap data-plane microbenchmark (ISSUE 3 acceptance): per-block host
copies vs the run-coalesced staged path.

One "swap" moves the same set of KV blocks (N blocks in R contiguous
runs) through three data planes:
  * ``per_block``  — one blocking d2h gather / un-donated h2d ``.at[].set``
                     PER BLOCK (the vLLM-style dispatch-bound baseline;
                     the copy-in also pays a full-pool copy per block)
  * ``host_vec``   — the pre-refactor engine path: one vectorized host
                     gather + ONE un-donated full-pool ``.at[].set`` per
                     swap (kept here as a local legacy implementation:
                     ``PagedPools.copy_in`` itself is now stage-routed,
                     fslint FS006)
  * ``staged``     — the engine's path (``copy_out_staged/copy_in_staged``):
                     grouped Pallas gather into a contiguous device slab,
                     one slab transfer, donated scatter (DESIGN.md §4)

CSV: name,us_per_swap,derived (ops = host-visible transfer/kernel
dispatches per swap; bytes per swap; jit variants compiled).
``--smoke`` shrinks the run for the tier-1 verify wrapper.

NOTE: this container runs the Pallas kernels in interpret mode (CPU), so
the staged numbers are conservative — the interpreter materializes a
buffer update per grid step, a cost that grows with pool size and does
not exist on real TPUs where each run is one streaming DMA chain.
"""
import argparse
import os
import sys
import time


def _force_mesh_devices() -> None:
    """``--mesh DxM`` needs D*M host devices, and XLA only honours
    ``xla_force_host_platform_device_count`` BEFORE the first jax
    import — so pre-scan argv here, above the jax import."""
    for i, a in enumerate(sys.argv):
        if a == "--mesh" or a.startswith("--mesh="):
            v = a.split("=", 1)[1] if "=" in a else sys.argv[i + 1]
            d, _, m = v.lower().partition("x")
            n = int(d) * int(m)
            flags = os.environ.get("XLA_FLAGS", "")
            if n > 1 and "host_platform_device_count" not in flags:
                os.environ["XLA_FLAGS"] = (
                    f"{flags} --xla_force_host_platform_device_count={n}"
                ).strip()


_force_mesh_devices()

import jax
import jax.numpy as jnp
import numpy as np

try:                                    # package run (benchmarks/run.py)
    from benchmarks.common import emit, write_bench_json
except ImportError:                     # direct run (tier1.sh)
    from common import emit, write_bench_json

from repro.cache.paged import PagedPools, PoolSpec
from repro.kernels import ops
from repro.kernels.block_copy import runs_to_indices


def _mk_pools(num_blocks, n_kv_heads=2, mesh=None):
    spec = PoolSpec(n_layers=2, n_kv_heads=n_kv_heads, head_dim=16,
                    block_size=16, num_gpu_blocks=num_blocks,
                    num_cpu_blocks=num_blocks)
    pools = PagedPools(spec, mesh=mesh)
    key = jax.random.PRNGKey(0)
    data = jax.random.normal(key, pools.gpu.shape).astype(jnp.bfloat16)
    pools.gpu = jax.device_put(data, pools.gpu.sharding)
    return pools, spec


def _legacy_copy_in(pools, cpu_blocks, gpu_blocks):
    """The retired un-donated h2d path (whole-pool functional update),
    preserved verbatim so the baseline legs keep measuring it after
    ``PagedPools.copy_in`` was stage-routed."""
    data = jnp.asarray(pools.cpu_bf16()[:, :, np.asarray(cpu_blocks)])
    pools.gpu = pools.gpu.at[:, :, np.asarray(gpu_blocks)].set(data)


def swap_per_block(pools, blocks, cpu_ids):
    """One d2h per block out; one un-donated ``.at[].set`` per block in."""
    for g, c in zip(blocks, cpu_ids):
        pools.copy_out([g], [c])
    for g, c in zip(blocks, cpu_ids):
        _legacy_copy_in(pools, [c], [g])
    pools.gpu.block_until_ready()


def swap_host_vec(pools, blocks, cpu_ids):
    pools.copy_out(blocks, cpu_ids)
    _legacy_copy_in(pools, cpu_ids, blocks)
    pools.gpu.block_until_ready()


def swap_staged(pools, runs, cpu_ids):
    pools.copy_out_staged(runs, cpu_ids)
    pools.copy_in_staged(cpu_ids, runs)
    pools.gpu.block_until_ready()


def _time(fn, iters):
    fn()                                    # warm the jit caches
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def run_mesh_rows(args, mesh_shape) -> None:
    """ISSUE 8 rows: the staged swap leg per mesh shape — per-shard slabs
    keep it ONE run-coalesced gather/scatter + one host transfer per
    chunk PER SHARD (each 1/n_shards the bytes).  Both shapes run in
    THIS process (same forced-device env) so the @1x1 row is the
    apples-to-apples no-regression reference for the sharded row."""
    d, m = mesh_shape
    n_runs, run_len = (2, 4) if args.smoke else (4, 16)
    iters = 2 if args.smoke else 3
    num_blocks = 64 if args.smoke else 512
    for shape in ((1, 1), (d, m)):
        mesh = None if shape == (1, 1) else jax.make_mesh(
            shape, ("data", "model"))
        # n_kv_heads divisible by the model axis (4-way needs 4 heads)
        pools, spec = _mk_pools(num_blocks, n_kv_heads=max(4, shape[1]),
                                mesh=mesh)
        runs = [(i * run_len * 2, run_len) for i in range(n_runs)]
        blocks = runs_to_indices(runs)
        cpu_ids = list(range(len(blocks)))
        snap = np.asarray(pools.gpu)
        t = _time(lambda: swap_staged(pools, runs, cpu_ids), iters)
        np.testing.assert_array_equal(np.asarray(pools.gpu), snap)
        chunks = pools.staged_out_calls
        emit(f"swap_staged@{shape[0]}x{shape[1]}", t * 1e6,
             f"blocks={len(blocks)};shards={pools.n_shards}"
             f";d2h_per_chunk={pools.d2h_transfers // chunks}"
             f";h2d_per_chunk={pools.h2d_transfers // chunks}"
             f";bytes={2 * len(blocks) * spec.block_bytes()}")
        assert pools.d2h_transfers == pools.n_shards * chunks
        assert pools.h2d_transfers == pools.n_shards * pools.staged_in_calls


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced run for the tier-1 verify wrapper")
    ap.add_argument("--json-out", default=None,
                    help="also write the rows as a JSON artifact "
                         "(BENCH_swap_path.json in CI)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="emit ONLY the mesh-sharded staged rows for this "
                         "(data, model) shape (plus the in-process 1x1 "
                         "reference); forces D*M host devices itself")
    args, _ = ap.parse_known_args()
    if args.mesh:
        d, _, m = args.mesh.lower().partition("x")
        run_mesh_rows(args, (int(d), int(m)))
        if args.json_out:
            write_bench_json(args.json_out, "swap_path", args.smoke)
        return
    n_runs, run_len = (2, 4) if args.smoke else (4, 16)
    iters = 2 if args.smoke else 3
    # pool much larger than the swapped set, as in serving: the baselines'
    # full-pool ``.at[].set`` copies pay for every resident block
    num_blocks = 64 if args.smoke else 512

    pools, spec = _mk_pools(num_blocks=num_blocks)
    # a request's blocks: n_runs contiguous runs with gaps between them
    runs = [(i * run_len * 2, run_len) for i in range(n_runs)]
    blocks = runs_to_indices(runs)
    cpu_ids = list(range(len(blocks)))
    n_blocks = len(blocks)
    swap_bytes = 2 * n_blocks * spec.block_bytes()      # out + in

    snap = np.asarray(pools.gpu)
    t_pb = _time(lambda: swap_per_block(pools, blocks, cpu_ids), iters)
    t_hv = _time(lambda: swap_host_vec(pools, blocks, cpu_ids), iters)
    t_st = _time(lambda: swap_staged(pools, runs, cpu_ids), iters)
    np.testing.assert_array_equal(np.asarray(pools.gpu), snap)  # integrity

    # host-visible dispatches per swap (out + in):
    ops_pb = 2 * n_blocks              # one transfer per block per leg
    ops_hv = 2 * 2                     # gather+store / upload+set
    ops_st = 2 * 2                     # kernel+slab transfer per leg
    compiles = ops.swap_gather_cache_size() + ops.swap_scatter_cache_size()

    assert ops_pb >= 2 * ops_st, "staged path must halve copy ops"
    emit("swap_per_block", t_pb * 1e6,
         f"ops={ops_pb};blocks={n_blocks};bytes={swap_bytes}")
    emit("swap_host_vec", t_hv * 1e6, f"ops={ops_hv};blocks={n_blocks}")
    emit("swap_staged", t_st * 1e6,
         f"ops={ops_st};runs={n_runs};blocks={n_blocks}"
         f";jit_variants={compiles};speedup_vs_per_block={t_pb / t_st:.2f}x")

    # double-buffered copy-in (ISSUE 10 satellite): the same swap-in as
    # ONE monolithic slab vs split into bounded sub-slabs — JAX's async
    # dispatch overlaps stage k+1's host gather/upload with stage k's
    # donated scatter, and the bounded slab caps staging memory at
    # stage_blocks instead of the whole swap
    def copy_in_once(stage_blocks):
        pools.copy_in_staged(cpu_ids, runs, stage_blocks=stage_blocks)
        pools.gpu.block_until_ready()

    t_mono = _time(lambda: copy_in_once(0), iters)
    t_dbuf = _time(lambda: copy_in_once(run_len), iters)
    np.testing.assert_array_equal(np.asarray(pools.gpu), snap)  # integrity
    assert pools.h2d_transfers == pools.n_shards * pools.staged_in_calls
    emit("swap_in_mono_slab", t_mono * 1e6,
         f"stage_blocks=0;stages=1;blocks={n_blocks}")
    emit("swap_in_dbuf", t_dbuf * 1e6,
         f"stage_blocks={run_len};stages={n_blocks // run_len}"
         f";blocks={n_blocks}")

    if args.json_out:
        write_bench_json(args.json_out, "swap_path", args.smoke)


if __name__ == "__main__":
    main()
