"""Fig. 8 (a-d) — P95/P99/P99.9 TTFT and P99.9 TBT for both models and
both context-switch patterns, with the paper's incremental-optimization
breakdown (vLLM -> +DBG -> +DBG+Reuse -> FastSwitch)."""
from benchmarks.common import POLICY_ORDER, csv_line, run_policy


def main(emit=print, scenarios=("llama8b-a10", "qwen32b-a100"),
         patterns=("markov", "random")):
    out = {}
    for sc in scenarios:
        for pat in patterns:
            base = None
            for pol in POLICY_ORDER:
                eng = run_policy(sc, pol, pattern=pat)
                s = eng.metrics.summary()
                out[(sc, pat, pol)] = s
                if pol == "vllm":
                    base = s
                for metric in ("p95_ttft_ms", "p99_ttft_ms",
                               "p999_ttft_ms", "p999_tbt_ms"):
                    speedup = base[metric] / max(s[metric], 1e-9)
                    emit(csv_line(
                        f"fig8_{sc}_{pat}_{pol}_{metric}",
                        s[metric] * 1e3,
                        f"speedup_vs_vllm={speedup:.2f}x"))
    return out


if __name__ == "__main__":
    main(scenarios=("llama8b-a10",), patterns=("markov",))
