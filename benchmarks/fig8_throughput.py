"""Fig. 8 (e-f) — end-to-end throughput across priority-update
frequencies (paper: up to 1.33x LLaMA-8B, 1.44x Qwen-32B at high freq)."""
from benchmarks.common import csv_line, run_policy


def main(emit=print, scenario="llama8b-a10",
         freqs=(0.01, 0.02, 0.04, 0.08)):
    rows = {}
    for freq in freqs:
        thr = {}
        for pol in ("vllm", "fastswitch"):
            eng = run_policy(scenario, pol, update_freq=freq)
            thr[pol] = eng.metrics.summary()["throughput_tok_s"]
        gain = thr["fastswitch"] / max(thr["vllm"], 1e-9)
        rows[freq] = (thr, gain)
        emit(csv_line(f"fig8e_{scenario}_freq{freq}",
                      1e6 / max(thr["fastswitch"], 1e-9),
                      f"throughput_gain={gain:.3f}x"))
    return rows


if __name__ == "__main__":
    main()
