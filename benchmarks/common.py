"""Shared benchmark infrastructure: paper-matched serving scenarios."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import EngineConfig, FastSwitchEngine
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import sample_conversations
from repro.io.cost_model import A10_PCIE4, A100_PCIE4

# Paper §4: LLaMA-8B on A10 24 GB and Qwen-32B on A100 80 GB, each with
# 60 GB CPU swap space, ShareGPT multi-turn, Poisson 1 req/s.  The block
# budgets are scaled to CPU-tractable trace sizes while keeping the same
# contention regime (working set >> GPU pool).
SCENARIOS: Dict[str, dict] = {
    "llama8b-a10": dict(
        engine=dict(hardware=A10_PCIE4, num_gpu_blocks=1024,
                    num_cpu_blocks=8192, max_running=32,
                    model_params=8_000_000_000, kv_bytes_per_token=131072),
        workload=dict(rate_req_s=0.4, n_convs=100, max_context=4000),
        update_freq=0.04,          # paper: doubled for the smaller model
    ),
    "qwen32b-a100": dict(
        engine=dict(hardware=A100_PCIE4, num_gpu_blocks=1536,
                    num_cpu_blocks=12288, max_running=32,
                    model_params=32_000_000_000,
                    kv_bytes_per_token=262144),
        workload=dict(rate_req_s=0.4, n_convs=100, max_context=6000),
        update_freq=0.02,
    ),
}

POLICY_ORDER = ["vllm", "+dbg", "+dbg+reuse", "fastswitch"]


def run_policy(scenario: str, policy: str, pattern: str = "markov",
               update_freq: Optional[float] = None, seed: int = 7,
               engine_overrides: Optional[dict] = None,
               workload_overrides: Optional[dict] = None):
    """Run one (scenario x policy x pattern) serving trace; returns the
    engine (metrics + component stats attached)."""
    sc = SCENARIOS[scenario]
    eng_kw = dict(sc["engine"])
    eng_kw.update(engine_overrides or {})
    wl = dict(sc["workload"])
    wl.update(workload_overrides or {})
    convs = sample_conversations(wl["n_convs"], rate_req_s=wl["rate_req_s"],
                                 seed=seed,
                                 max_context=wl.get("max_context", 6000))
    cfg = EngineConfig(mode="sim", **eng_kw).with_policy(policy)
    freq = update_freq if update_freq is not None else sc["update_freq"]
    eng = FastSwitchEngine(
        cfg, convs, trace=PriorityTrace(pattern, freq, seed=seed))
    eng.run(max_iterations=2_000_000)
    assert eng.done(), f"{scenario}/{policy}: trace did not drain"
    return eng


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# ---------------------------------------------------------------------------
# machine-readable benchmark artifacts (BENCH_<name>.json)
# ---------------------------------------------------------------------------

# module-level row collector: benchmark scripts print one CSV row per
# result through ``emit`` from anywhere (including helper functions),
# and ``write_bench_json`` dumps everything collected since process
# start — the CI artifact a perf dashboard can diff across commits.
_BENCH_ROWS: list = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """Print one ``name,us_per_call,derived`` CSV row AND record it for
    ``write_bench_json``.  ``derived`` stays the semi-structured
    ``k=v;k=v`` string the CSV format uses; the JSON row also carries it
    parsed where the values are numeric."""
    print(csv_line(name, us_per_call, derived))
    parsed = {}
    for part in derived.split(";"):
        if "=" in part:
            k, _, val = part.partition("=")
            try:
                parsed[k] = float(val.rstrip("x"))
            except ValueError:
                parsed[k] = val
    _BENCH_ROWS.append({"name": name,
                        "us_per_call": round(us_per_call, 3),
                        "derived": derived, **parsed})


def write_bench_json(path: str, bench: str, smoke: bool) -> None:
    """Write (or MERGE into) the artifact: when ``path`` already holds
    rows for the same bench, rows re-measured this process replace their
    namesakes and the rest are kept — so a second invocation under a
    different environment (e.g. ``--mesh 1x4``, which needs forced host
    devices) folds its rows into the same committed file."""
    import json
    import os
    import platform
    rows = list(_BENCH_ROWS)
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if prev.get("bench") == bench:
                fresh = {r["name"] for r in rows}
                rows = [r for r in prev.get("rows", [])
                        if r["name"] not in fresh] + rows
        except (json.JSONDecodeError, KeyError):
            pass                      # unreadable artifact: overwrite
    with open(path, "w") as f:
        json.dump({"bench": bench, "smoke": smoke,
                   "machine": platform.machine(),
                   "rows": rows}, f, indent=1)
        f.write("\n")
    print(f"wrote {path} ({len(rows)} rows)")
