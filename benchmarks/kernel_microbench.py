"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU time —
these measure call overhead and validate the grouped-copy op-count
advantage; the structural perf story lives in the roofline report)."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line
from repro.kernels.ops import copy_block_runs, copy_blocks, paged_attention


def _time(fn, n=5):
    fn()                                     # compile
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn()
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / n * 1e6


def main(emit=print):
    key = jax.random.PRNGKey(0)
    B, Hq, Hkv, D, bs, npages = 4, 8, 2, 64, 16, 8
    nb = 64
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kp = jax.random.normal(ks[1], (nb, bs, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (nb, bs, Hkv, D), jnp.float32)
    bt = jax.random.permutation(ks[3], nb)[:B * npages].reshape(B, npages)
    ctx = jnp.full((B,), npages * bs, jnp.int32)
    t = _time(lambda: paged_attention(q, kp, vp, bt.astype(jnp.int32),
                                      ctx, D ** -0.5))
    emit(csv_line("kernel_paged_attention_interp", t,
                  f"B{B}xH{Hq}x{npages}pages"))

    src = jax.random.normal(key, (64, 2048), jnp.float32)
    dst = jnp.zeros((64, 2048), jnp.float32)
    si = jnp.arange(32, dtype=jnp.int32)
    di = jnp.arange(32, 64, dtype=jnp.int32)
    t_pb = _time(lambda: copy_blocks(src, dst, si, di))
    t_gr = _time(lambda: copy_block_runs(src, dst, [(0, 32)], [32]))
    emit(csv_line("kernel_block_copy_per_block", t_pb, "ops=32"))
    emit(csv_line("kernel_block_copy_grouped", t_gr,
                  f"ops=1 speed_ratio={t_pb / max(t_gr, 1e-9):.2f}x"))


if __name__ == "__main__":
    main()
