"""Fig. 10 — context-switch overhead (swap stall / end-to-end) across
priority-update frequencies; paper: Dynamic Block Groups give up to
3.11x context-switch speedup over vLLM."""
from benchmarks.common import csv_line, run_policy


def main(emit=print, freqs=(0.01, 0.02, 0.04, 0.08)):
    rows = {}
    for freq in freqs:
        stalls = {}
        for pol in ("vllm", "+dbg"):
            eng = run_policy("llama8b-a10", pol, update_freq=freq)
            m = eng.metrics
            stalls[pol] = (eng.swap.total_stall_us,
                           eng.swap.total_stall_us / max(m.total_time_us, 1))
        speedup = stalls["vllm"][0] / max(stalls["+dbg"][0], 1e-9)
        rows[freq] = (stalls, speedup)
        emit(csv_line(f"fig10_freq{freq}_ctx_switch_stall",
                      stalls["+dbg"][0],
                      f"dbg_speedup={speedup:.2f}x "
                      f"share_vllm={stalls['vllm'][1]:.3f}"))
    return rows


if __name__ == "__main__":
    main()
