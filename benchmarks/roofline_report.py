"""Roofline report (§Roofline): reads the dry-run JSON artifact and emits
the three-term roofline table per (arch x shape x mesh)."""
import json
import os

from benchmarks.common import csv_line

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun_full.json")


def main(emit=print, path: str = DEFAULT_PATH):
    if not os.path.exists(path):
        emit(csv_line("roofline_report_missing", 0.0,
                      f"run `python -m repro.launch.dryrun --all "
                      f"--multi-pod both --out {path}` first"))
        return None
    with open(path) as f:
        results = json.load(f)
    rows = []
    for r in results:
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        if r["mesh"] != "16x16":
            continue                        # roofline table is single-pod
        rf = r["roofline"]
        total = rf["t_compute_s"] + rf["t_memory_s"] + rf["t_collective_s"]
        emit(csv_line(
            f"roofline_{r['arch']}_{r['shape']}",
            total * 1e6,
            f"dom={rf['dominant']} tc={rf['t_compute_s']:.2e}s "
            f"tm={rf['t_memory_s']:.2e}s tcoll={rf['t_collective_s']:.2e}s "
            f"useful={rf['useful_flops_ratio']:.2f}"))
        rows.append(r)
    return rows


if __name__ == "__main__":
    main()
