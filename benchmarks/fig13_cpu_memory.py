"""Fig. 13 — CPU swap-space sensitivity: more CPU memory => fewer
contaminated copies => less context-switch overhead, with diminishing
returns (paper: ~60 GB is the knee for their setup)."""
from benchmarks.common import csv_line, run_policy


def main(emit=print, cpu_blocks=(1024, 2048, 4096, 8192, 16384)):
    rows = {}
    for nb in cpu_blocks:
        eng = run_policy("llama8b-a10", "fastswitch",
                         engine_overrides={"num_cpu_blocks": nb})
        stall = eng.swap.total_stall_us
        contam = eng.reuse.n_contaminations
        out_blocks = eng.swap.blocks_by_dir["out"]
        rows[nb] = (stall, contam, out_blocks)
        emit(csv_line(f"fig13_cpu{nb}blocks", stall,
                      f"contaminations={contam} swap_out_blocks={out_blocks}"))
    return rows


if __name__ == "__main__":
    main()
