"""Fig. 12 — token-generation efficiency (tokens per unit time in fixed
5-iteration windows), FastSwitch (async swap) vs the same system without
the Multithreading Swap Manager (paper: +21.8% at P99, +12.6% at P99.9)."""
import numpy as np

from benchmarks.common import csv_line, run_policy


def _efficiency_percentiles(eng, window=5):
    """Tokens per second in fixed 5-iteration windows, excluding windows
    that contain a prefill (prefill compute dwarfs swap stall and would
    mask the async-swap effect this figure isolates)."""
    recs = eng.metrics.iter_records  # (t_end, batch, t_iter, prefills, stall)
    effs = []
    for i in range(0, len(recs) - window, window):
        chunk = recs[i:i + window]
        if any(r[3] for r in chunk):
            continue
        if min(r[1] for r in chunk) < 8:
            continue                      # drain/idle phases: no service load
        tokens = sum(r[1] for r in chunk)
        dt = chunk[-1][0] - (chunk[0][0] - chunk[0][2])
        if dt > 0:
            effs.append(tokens / (dt / 1e6))
    return np.asarray(effs)


def main(emit=print):
    base = run_policy("llama8b-a10", "+dbg+reuse")   # all but async swap
    fast = run_policy("llama8b-a10", "fastswitch")
    e_base = _efficiency_percentiles(base)
    e_fast = _efficiency_percentiles(fast)
    rows = {}
    # low percentiles = the slow windows (where stalls bite)
    for p in (1, 0.1):
        b = float(np.percentile(e_base, p))
        f = float(np.percentile(e_fast, p))
        gain = (f - b) / max(b, 1e-9)
        label = {1: "p99", 0.1: "p999"}[p]
        rows[label] = (b, f, gain)
        emit(csv_line(f"fig12_{label}_token_efficiency", f,
                      f"gain_vs_sync={gain * 100:+.1f}%"))
    emit(csv_line("fig12_median_token_efficiency",
                  float(np.median(e_fast)),
                  f"baseline={float(np.median(e_base)):.1f}tok_s"))
    return rows


if __name__ == "__main__":
    main()
