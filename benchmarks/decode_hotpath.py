"""Decode hot-path benchmark: steps/s, jit-cache growth and prefill
insertion over a growing-context run (ISSUE 1 acceptance: bucketed
shapes compile O(log2 max_pages) variants, the legacy exact-shape path
compiled one per page-boundary crossing; ISSUE 2: runner-managed prefill
insertion replaces the host KV round-trip).

Single-request runs over the same token budget, context growing from
1 token across page boundaries:
  * ``legacy``   — exact-width block tables through ``paged_decode_step``
                   (recompiles at every page boundary, host sync per step)
  * ``bucketed`` — the DecodeRunner (persistent device block table,
                   pow2 buckets, donated pool, deferred token sync)
plus a prefill-insertion comparison:
  * ``prefill_host``   — ``PagedPools.write_tokens``-style path: KV pulled
                         to the host and scattered back per request
  * ``prefill_runner`` — ``DecodeRunner.prefill``: jitted shape-bucketed
                         scatter, KV stays on device end to end
and a monolithic-vs-chunked prefill row (ISSUE 4): decode tokens the
4-row batch emits DURING a long prompt's prefill window — zero for the
monolithic path (the prompt lands inside one admission iteration), a
full batch per chunk for the bucketed chunked path (DESIGN.md §5).

CSV: name,us_per_call,derived  (derived = steps/s and compile counts).
``--smoke`` shrinks the run for the tier-1 verify wrapper.
"""
import argparse
import math
import os
import sys
import time


def _force_mesh_devices() -> None:
    """``--mesh DxM`` needs D*M host devices, and XLA only honours
    ``xla_force_host_platform_device_count`` BEFORE the first jax
    import — so pre-scan argv here, above the jax import."""
    for i, a in enumerate(sys.argv):
        if a == "--mesh" or a.startswith("--mesh="):
            v = a.split("=", 1)[1] if "=" in a else sys.argv[i + 1]
            d, _, m = v.lower().partition("x")
            n = int(d) * int(m)
            flags = os.environ.get("XLA_FLAGS", "")
            if n > 1 and "host_platform_device_count" not in flags:
                os.environ["XLA_FLAGS"] = (
                    f"{flags} --xla_force_host_platform_device_count={n}"
                ).strip()


_force_mesh_devices()

import jax
import jax.numpy as jnp
import numpy as np

try:                                    # package run (benchmarks/run.py)
    from benchmarks.common import emit, write_bench_json
except ImportError:                     # direct run (tier1.sh)
    from common import emit, write_bench_json

from repro.configs import get_smoke_config
from repro.core.decode_runner import DecodeRequestView, DecodeRunner
from repro.kernels.ops import insert_prefill_cache_size
from repro.models import transformer as T
from repro.models.paged import paged_decode_step, prefill_kv

BS = 8              # tokens per page (small so boundaries come fast)


def _setup(max_pages):
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    nb = max_pages + 2                      # + spare + trash
    pool = jnp.zeros((cfg.n_layers, 2, nb, BS, cfg.n_kv_heads,
                      cfg.resolved_head_dim), jnp.bfloat16)
    return cfg, params, pool, nb - 1        # trash = last block


def _blocks_for(ctx: int) -> list:
    """Identity block table covering positions [0, ctx] (the write slot)."""
    return list(range(ctx // BS + 1))


def run_legacy(cfg, params, pool, n_steps):
    hist = [1]
    c0 = paged_decode_step._cache_size()
    t0 = time.perf_counter()
    for _ in range(n_steps):
        ctx = len(hist) - 1
        bt = jnp.asarray([_blocks_for(ctx)], jnp.int32)   # exact width
        nxt, _, pool = paged_decode_step(
            params, pool, bt, jnp.asarray([ctx], jnp.int32),
            jnp.asarray([hist[-1]], jnp.int32), cfg=cfg)
        hist.append(int(nxt[0]))                          # per-step sync
    dt = time.perf_counter() - t0
    return dt, paged_decode_step._cache_size() - c0, hist


def run_bucketed(cfg, params, pool, trash, n_steps):
    runner = DecodeRunner({"cfg": cfg, "params": params},
                          block_size=BS, trash_block=trash)
    hist = [1]
    c0 = DecodeRunner.jit_cache_size()
    t0 = time.perf_counter()
    # the context counter is driver-owned (like the engine's
    # ``context_tokens``): with the deferred token sync, len(hist) lags
    # the device state by one step at the time blocks are allocated
    for ctx in range(n_steps):
        pool = runner.decode(
            [DecodeRequestView(0, _blocks_for(ctx), hist)], pool)
    runner.flush()
    dt = time.perf_counter() - t0
    return dt, DecodeRunner.jit_cache_size() - c0, hist, runner.stats


def run_prefill_host(cfg, params, pool, prompts):
    """Legacy path, exactly as the pre-refactor engine ran it: KV pulled
    to the host, then ``PagedPools.write_tokens`` (fused block-aligned
    scatter) back into the pool."""
    from repro.cache.paged import PagedPools, PoolSpec
    nb = pool.shape[2]
    pools = PagedPools(PoolSpec(n_layers=cfg.n_layers,
                                n_kv_heads=cfg.n_kv_heads,
                                head_dim=cfg.resolved_head_dim,
                                block_size=BS, num_gpu_blocks=nb,
                                num_cpu_blocks=1))
    pools.gpu = pool
    t0 = time.perf_counter()
    for toks in prompts:
        _, k, v = prefill_kv(params, jnp.asarray([toks], jnp.int32), cfg=cfg)
        nblk = (len(toks) + BS - 1) // BS
        pools.write_tokens(list(range(nblk)), 0,
                           np.asarray(k), np.asarray(v))  # d2h round trip
    pools.gpu.block_until_ready()
    return time.perf_counter() - t0, pools.gpu


def run_prefill_runner(cfg, params, pool, trash, prompts):
    """Runner-managed insertion: device-resident, bucketed jit scatter."""
    runner = DecodeRunner({"cfg": cfg, "params": params},
                          block_size=BS, trash_block=trash)
    c0 = insert_prefill_cache_size()
    t0 = time.perf_counter()
    for toks in prompts:
        hist = list(toks)
        view = DecodeRequestView(0, _blocks_for(len(hist) - 1), hist)
        pool = runner.prefill(view, pool, emit_first=True)
    pool.block_until_ready()
    return time.perf_counter() - t0, insert_prefill_cache_size() - c0, pool


def run_prefill_interleave(smoke: bool):
    """ISSUE 4 row: monolithic vs chunked prefill through the REAL
    engine — decode tokens emitted during a long prompt's prefill window
    (monolithic admits the whole prompt inside one iteration: zero
    interleaving; chunked emits a full decode batch between chunks)."""
    from dataclasses import replace

    from repro.core import EngineConfig, FastSwitchEngine
    from repro.core.policies import POLICIES
    from repro.data.priority import PriorityTrace
    from repro.data.sharegpt import Conversation, Turn

    cfg_m = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg_m, jax.random.PRNGKey(0))
    model = {"cfg": cfg_m, "params": params}
    prompt = 256 if smoke else 1024
    chunk = 64
    resp = 16 if smoke else 40

    def run(chunked):
        pol = replace(POLICIES["fastswitch"], initial_group_blocks=4)
        if chunked:
            pol = replace(pol, chunked_prefill_tokens=chunk)
        convs = [Conversation(conv_id=i, arrival_s=0.0,
                              turns=[Turn(8, resp)], think_time_s=0.1)
                 for i in range(4)]
        convs.append(Conversation(conv_id=4, arrival_s=0.0,
                                  turns=[Turn(prompt, 2)], think_time_s=0.1))
        cfg = EngineConfig(mode="real", num_gpu_blocks=prompt // 16 + 24,
                           num_cpu_blocks=512, max_running=8, max_batch=8,
                           block_size=16, policy=pol)
        eng = FastSwitchEngine(cfg, convs, trace=PriorityTrace(),
                               model_bundle=model)
        reqs = {}
        decode_in_window = chunk_iters = 0
        t0 = time.perf_counter()
        while not eng.done() and eng.metrics.iterations < 5000:
            before = {r: q.generated for r, q in eng.sched.requests.items()
                      if r < 4}
            reqs.update(eng.sched.requests)
            eng.step()
            long_req = reqs.get(4)
            if long_req is not None and long_req.prefill_remaining > 0:
                chunk_iters += 1
                decode_in_window += sum(
                    q.generated - before.get(r, q.generated)
                    for r, q in eng.sched.requests.items() if r < 4)
        dt = time.perf_counter() - t0
        eng.swap.shutdown()
        return dt, eng.metrics.iterations, decode_in_window, chunk_iters

    for name, chunked in (("monolithic", False), ("chunked", True)):
        dt, iters, toks, citers = run(chunked)
        emit(f"prefill_{name}", dt / max(iters, 1) * 1e6,
             f"decode_tokens_during_prefill={toks}"
             f";prefill_window_iters={citers};prompt={prompt}")


def run_online_overhead(smoke: bool):
    """ISSUE 5 row: serving-API overhead — the same sim workload driven
    (a) through the closed-world ``FastSwitchEngine.run()`` replay client
    and (b) through a direct open-world ``add_request``/``step()`` loop.
    Both drive the SAME ServingEngine core, so the steps/s delta is the
    pure cost of the client layer (arrival feeding, output collection)."""
    from repro.core import (EngineConfig, FastSwitchEngine, SamplingParams,
                            ServingEngine)
    from repro.data.priority import PriorityTrace
    from repro.data.sharegpt import sample_conversations

    n_conv = 40 if smoke else 200
    convs = sample_conversations(n_conv, rate_req_s=4.0, seed=3)
    cfg = EngineConfig(mode="sim", num_gpu_blocks=1024, num_cpu_blocks=4096,
                       max_running=16).with_policy("fastswitch")

    eng = FastSwitchEngine(cfg, [c for c in convs],
                           trace=PriorityTrace("markov", 0.04, seed=7))
    t0 = time.perf_counter()
    m = eng.run(max_iterations=300_000)
    dt_replay = time.perf_counter() - t0
    it_replay, tok = m.iterations, m.total_tokens

    core = ServingEngine(cfg, trace=PriorityTrace("markov", 0.04, seed=7))
    pending = sorted(convs, key=lambda c: c.arrival_s)
    by_handle = {c.conv_id: c for c in convs}
    sleeping = []
    t0 = time.perf_counter()
    it = 0
    while (pending or sleeping or core.has_work()) and it < 300_000:
        now_s = core.clock.now_us / 1e6
        while pending and pending[0].arrival_s <= now_s:
            conv = pending.pop(0)
            core.add_request(conv.turns[0].prompt_tokens,
                             SamplingParams(
                                 max_tokens=conv.turns[0].response_tokens),
                             handle=conv.conv_id,
                             retain_kv=len(conv.turns) > 1)
        for w in list(sleeping):
            if w[0] <= now_s:
                sleeping.remove(w)
                _, conv, tix = w
                core.continue_session(
                    conv.conv_id, conv.turns[tix].prompt_tokens,
                    SamplingParams(
                        max_tokens=conv.turns[tix].response_tokens),
                    retain_kv=tix + 1 < len(conv.turns))
        events = [w[0] * 1e6 for w in sleeping]
        if pending:
            events.append(pending[0].arrival_s * 1e6)
        for out in core.step(until_us=min(events) if events else None):
            if out.finished and out.finish_reason == "length":
                conv = by_handle[out.handle]
                if out.turn + 1 < len(conv.turns):
                    sleeping.append((out.t_us / 1e6 + conv.think_time_s,
                                     conv, out.turn + 1))
        it += 1
    dt_direct = time.perf_counter() - t0
    core.shutdown()
    assert core.metrics.total_tokens == tok, \
        "direct step() loop served a different token count"

    emit("online_api_replay", dt_replay / max(it_replay, 1) * 1e6,
         f"steps_s={it_replay / dt_replay:.0f};tokens={tok}")
    emit("online_api_direct", dt_direct / max(it, 1) * 1e6,
         f"steps_s={it / dt_direct:.0f};"
         f"overhead_pct={(dt_replay / max(it_replay, 1) / (dt_direct / max(it, 1)) - 1) * 100:.1f}")


def run_prefix_cache_rows(smoke: bool):
    """DESIGN.md §10 rows: N requests sharing a 49-token system prompt
    through the REAL serving engine, prefix cache off vs on.  The cache
    must shrink ``prefill_tokens`` (prompt tokens actually forwarded —
    sharers seed the pinned prefix instead of recomputing it) while the
    emitted token histories stay bit-identical."""
    from repro.core import EngineConfig, SamplingParams, ServingEngine
    from repro.data.priority import PriorityTrace

    cfg_m = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg_m, jax.random.PRNGKey(0))
    model = {"cfg": cfg_m, "params": params}
    n_req = 4 if smoke else 8
    rng = np.random.RandomState(5)
    sys_prefix = rng.randint(1, cfg_m.vocab_size, 49).tolist()
    prompts = [sys_prefix
               + rng.randint(1, cfg_m.vocab_size, 6 + 3 * i).tolist()
               for i in range(n_req)]

    def run(on):
        cfg = EngineConfig(mode="real", num_gpu_blocks=64,
                           num_cpu_blocks=256, max_running=n_req,
                           max_batch=4, prefix_cache=on,
                           ).with_policy("fastswitch")
        eng = ServingEngine(cfg, trace=PriorityTrace(), model_bundle=model,
                            stream_tokens=True)
        t0 = time.perf_counter()
        hists = {}
        it = 0

        def drain(budget):
            nonlocal it
            n = 0
            while eng.has_work() and n < budget:
                for out in eng.step():
                    if out.token_ids:
                        hists.setdefault(out.handle,
                                         []).extend(out.token_ids)
                it += 1
                n += 1

        # the leader's prefill must complete (and donate its blocks to
        # the tree) before the sharers arrive — same staggering a live
        # arrival process produces
        eng.add_request(list(prompts[0]), SamplingParams(max_tokens=8),
                        handle=0)
        drain(2)
        for h, toks in enumerate(prompts[1:], start=1):
            eng.add_request(list(toks), SamplingParams(max_tokens=8),
                            handle=h)
        drain(5000)
        dt = time.perf_counter() - t0
        pt = eng.runner.stats.prefill_tokens
        stats = eng.prefix.stats() if eng.prefix is not None else {}
        eng.shutdown()
        return dt, pt, stats, hists

    dt_off, pt_off, _, h_off = run(False)
    dt_on, pt_on, st, h_on = run(True)
    assert h_on == h_off, "prefix cache changed the token histories"
    assert pt_on < pt_off, \
        f"prefix cache saved no prefill compute ({pt_on} vs {pt_off})"
    emit("prefix_cache_off", dt_off / n_req * 1e6,
         f"prefill_tokens={pt_off};requests={n_req}")
    emit("prefix_cache_on", dt_on / n_req * 1e6,
         f"prefill_tokens={pt_on};hit_rate={st['hit_rate']:.2f}"
         f";tokens_saved={st['tokens_saved']}"
         f";evictions={st['evictions']}")


def run_mesh_rows(args, mesh_shape) -> None:
    """ISSUE 8 rows: runner-driven decode steps/s per mesh shape on a
    uniformly shardable model (4 q / 4 kv heads).  Both shapes run in
    THIS process (same forced-device env) so the @1x1 row is the
    apples-to-apples no-regression reference, and their greedy token
    histories are asserted bit-identical."""
    import dataclasses
    d, m = mesh_shape
    cfg = dataclasses.replace(get_smoke_config("llama3.2-3b"),
                              n_heads=4, n_kv_heads=4, head_dim=16,
                              d_model=64, n_layers=2, d_ff=128,
                              vocab_size=256)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n_steps = 16 if args.smoke else 64
    max_pages = n_steps // BS + 2
    hists = {}
    for shape in ((1, 1), (d, m)):
        mesh = None if shape == (1, 1) else jax.make_mesh(
            shape, ("data", "model"))
        nb = max_pages + 2
        pool = jnp.zeros((cfg.n_layers, 2, nb, BS, cfg.n_kv_heads,
                          cfg.resolved_head_dim), jnp.bfloat16)
        if mesh is not None:
            from repro.models.sharding import pool_pspec
            pool = jax.device_put(
                pool, jax.sharding.NamedSharding(mesh, pool_pspec()))
        runner = DecodeRunner({"cfg": cfg, "params": params},
                              block_size=BS, trash_block=nb - 1, mesh=mesh)
        hist = [1]
        c0 = DecodeRunner.jit_cache_size()
        t0 = time.perf_counter()
        for ctx in range(n_steps):
            pool = runner.decode(
                [DecodeRequestView(0, _blocks_for(ctx), hist)], pool)
        runner.flush()
        dt = time.perf_counter() - t0
        hists[shape] = list(hist)
        emit(f"decode_hotpath@{shape[0]}x{shape[1]}", dt / n_steps * 1e6,
             f"steps_s={n_steps / dt:.2f};shards={1 if mesh is None else m}"
             f";compiles={DecodeRunner.jit_cache_size() - c0}")
    assert hists[(1, 1)] == hists[(d, m)], \
        "mesh decode diverged from single-device greedy history"
    # vocab-sharded unembed (ISSUE 9): greedy decode all-gathers TWO
    # scalars per shard per row (max value + global argmax index)
    # instead of every shard redundantly computing the full (B, V)
    # logits; batches with a sampled row fall back to one full-logits
    # gather.  B = 1 here (single-request run).
    V = cfg.vocab_size
    emit(f"unembed_collective@{d}x{m}", 0.0,
         f"greedy_gather_elems={2 * m};sampled_fallback_elems={V}"
         f";shrink={V / (2 * m):.0f}x"
         f";per_shard_matmul_cols={V // m}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced run for the tier-1 verify wrapper")
    ap.add_argument("--json-out", default=None,
                    help="also write the rows as a JSON artifact "
                         "(BENCH_decode_hotpath.json in CI)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="emit ONLY the mesh-sharded decode rows for this "
                         "(data, model) shape (plus the in-process 1x1 "
                         "reference); forces D*M host devices itself")
    # parse_known_args: benchmarks/run.py invokes main() with its own
    # positional selectors still in sys.argv
    args, _ = ap.parse_known_args()
    if args.mesh:
        d, _, m = args.mesh.lower().partition("x")
        run_mesh_rows(args, (int(d), int(m)))
        if args.json_out:
            write_bench_json(args.json_out, "decode_hotpath", args.smoke)
        return
    max_pages = 4 if args.smoke else 10
    n_steps = max_pages * BS - 2
    bound = math.ceil(math.log2(max_pages)) + 1

    cfg, params, pool0, trash = _setup(max_pages)
    dt_l, compiles_l, hist_l = run_legacy(cfg, params, pool0, n_steps)
    _, _, pool0, trash = _setup(max_pages)        # fresh pool (donated away)
    dt_b, compiles_b, hist_b, stats = run_bucketed(cfg, params, pool0,
                                                   trash, n_steps)

    assert hist_b == hist_l, "bucketed decode diverged from exact-shape path"
    assert compiles_b <= bound, \
        f"bucketed path compiled {compiles_b} > bound {bound}"

    emit("decode_hotpath_legacy", dt_l / n_steps * 1e6,
         f"steps_s={n_steps / dt_l:.2f};compiles={compiles_l}")
    emit("decode_hotpath_bucketed", dt_b / n_steps * 1e6,
         f"steps_s={n_steps / dt_b:.2f};compiles={compiles_b}"
         f";bound={bound};rows_updated={stats.rows_updated}"
         f";host_syncs={stats.host_syncs}")

    # prefill insertion: same prompt lengths through both paths
    rng = np.random.RandomState(0)
    lens = [5, 11, 18, 25][: 2 if args.smoke else 4]
    prompts = [rng.randint(1, cfg.vocab_size, n).tolist() for n in lens]
    _, _, pool0, trash = _setup(max_pages)
    dt_h, _ = run_prefill_host(cfg, params, pool0, prompts)
    _, _, pool0, trash = _setup(max_pages)
    dt_r, icompiles, _ = run_prefill_runner(cfg, params, pool0, trash,
                                            prompts)
    n = len(prompts)
    emit("prefill_insert_host", dt_h / n * 1e6, f"prefills_s={n / dt_h:.2f}")
    emit("prefill_insert_runner", dt_r / n * 1e6,
         f"prefills_s={n / dt_r:.2f};insert_compiles={icompiles}")

    # chunked-vs-monolithic prefill: decode tokens during the prefill
    # window (ISSUE 4 — the tail-TBT lever)
    run_prefill_interleave(args.smoke)

    # serving-API overhead: run() replay vs direct step() loop (ISSUE 5)
    run_online_overhead(args.smoke)

    # cross-request prefix cache: shared-system-prompt prefill savings
    # with bit-identical outputs (ISSUE 9 / DESIGN.md §10)
    run_prefix_cache_rows(args.smoke)

    if args.json_out:
        write_bench_json(args.json_out, "decode_hotpath", args.smoke)


if __name__ == "__main__":
    main()
