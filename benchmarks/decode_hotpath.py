"""Decode hot-path benchmark: steps/s and jit-cache growth over a
growing-context run (ISSUE 1 acceptance: bucketed shapes compile
O(log2 max_pages) variants, the legacy exact-shape path compiled one per
page-boundary crossing).

Two single-request runs over the same token budget, context growing from
1 token across >= 8 page boundaries:
  * ``legacy``   — exact-width block tables through ``paged_decode_step``
                   (recompiles at every page boundary, host sync per step)
  * ``bucketed`` — the DecodeRunner (persistent device block table,
                   pow2 buckets, donated pool, deferred token sync)

CSV: name,us_per_call,derived  (derived = steps/s and compile counts).
"""
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.decode_runner import DecodeRequestView, DecodeRunner
from repro.models import transformer as T
from repro.models.paged import paged_decode_step, paged_decode_step_device

BS = 8              # tokens per page (small so boundaries come fast)
MAX_PAGES = 10      # context grows across MAX_PAGES - 1 = 9 boundaries
N_STEPS = MAX_PAGES * BS - 2


def _setup():
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    nb = MAX_PAGES + 2                      # + spare + trash
    pool = jnp.zeros((cfg.n_layers, 2, nb, BS, cfg.n_kv_heads,
                      cfg.resolved_head_dim), jnp.bfloat16)
    return cfg, params, pool, nb - 1        # trash = last block


def _blocks_for(ctx: int) -> list:
    """Identity block table covering positions [0, ctx] (the write slot)."""
    return list(range(ctx // BS + 1))


def run_legacy(cfg, params, pool):
    hist = [1]
    c0 = paged_decode_step._cache_size()
    t0 = time.perf_counter()
    for _ in range(N_STEPS):
        ctx = len(hist) - 1
        bt = jnp.asarray([_blocks_for(ctx)], jnp.int32)   # exact width
        nxt, _, pool = paged_decode_step(
            params, pool, bt, jnp.asarray([ctx], jnp.int32),
            jnp.asarray([hist[-1]], jnp.int32), cfg=cfg)
        hist.append(int(nxt[0]))                          # per-step sync
    dt = time.perf_counter() - t0
    return dt, paged_decode_step._cache_size() - c0, hist


def run_bucketed(cfg, params, pool, trash):
    runner = DecodeRunner({"cfg": cfg, "params": params},
                          block_size=BS, trash_block=trash)
    hist = [1]
    c0 = DecodeRunner.jit_cache_size()
    t0 = time.perf_counter()
    # the context counter is driver-owned (like the engine's
    # ``context_tokens``): with the deferred token sync, len(hist) lags
    # the device state by one step at the time blocks are allocated
    for ctx in range(N_STEPS):
        pool = runner.decode(
            [DecodeRequestView(0, _blocks_for(ctx), hist)], pool)
    runner.flush()
    dt = time.perf_counter() - t0
    return dt, DecodeRunner.jit_cache_size() - c0, hist, runner.stats


def main() -> None:
    cfg, params, pool0, trash = _setup()
    bound = math.ceil(math.log2(MAX_PAGES)) + 1

    dt_l, compiles_l, hist_l = run_legacy(cfg, params, pool0)
    _, _, pool0, trash = _setup()                 # fresh pool (donated away)
    dt_b, compiles_b, hist_b, stats = run_bucketed(cfg, params, pool0, trash)

    assert hist_b == hist_l, "bucketed decode diverged from exact-shape path"
    assert compiles_b <= bound, \
        f"bucketed path compiled {compiles_b} > bound {bound}"

    print(f"decode_hotpath_legacy,{dt_l / N_STEPS * 1e6:.1f},"
          f"steps_s={N_STEPS / dt_l:.2f};compiles={compiles_l}")
    print(f"decode_hotpath_bucketed,{dt_b / N_STEPS * 1e6:.1f},"
          f"steps_s={N_STEPS / dt_b:.2f};compiles={compiles_b}"
          f";bound={bound};rows_updated={stats.rows_updated}"
          f";host_syncs={stats.host_syncs}")


if __name__ == "__main__":
    main()
