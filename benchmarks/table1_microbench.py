"""Table 1 — swap-out microbenchmark: total swapped blocks, transfer ops
and cumulative latency, traditional (vLLM) vs KV-reuse swap-out
(paper: 122,030 -> 58,187 blocks (-53%), 13,076 -> 10,713 ops,
15.5 s -> 6.7 s)."""
from benchmarks.common import csv_line, run_policy
from repro.io.cost_model import A10_PCIE4, dispatch_time_us, exec_time_us


def main(emit=print):
    rows = {}
    for pol, label in (("vllm", "traditional"),
                       ("fastswitch", "kv_reuse")):
        eng = run_policy("llama8b-a10", pol)
        sw = eng.swap.stats()
        # cumulative d2h swap-out latency from the cost model
        # (ops and blocks are exact; latency = dispatch + exec per op)
        n_ops = sw["ops_out"]
        n_blocks = sw["blocks_out"]
        avg_run = n_blocks / max(n_ops, 1)
        lat_s = (n_ops * dispatch_time_us(A10_PCIE4)
                 + n_ops * exec_time_us(
                     A10_PCIE4, int(avg_run * eng.block_bytes), False)) / 1e6
        rows[label] = dict(blocks=n_blocks, ops=n_ops, latency_s=lat_s)
        emit(csv_line(f"table1_{label}_swap_out", lat_s * 1e6,
                      f"blocks={n_blocks} ops={n_ops} "
                      f"latency={lat_s:.2f}s"))
    red = 1 - rows["kv_reuse"]["blocks"] / max(rows["traditional"]["blocks"], 1)
    speed = rows["traditional"]["latency_s"] / max(
        rows["kv_reuse"]["latency_s"], 1e-9)
    emit(csv_line("table1_block_reduction", red * 1e6,
                  f"blocks_reduced={red * 100:.1f}% latency_speedup={speed:.2f}x"))
    return rows


if __name__ == "__main__":
    main()
