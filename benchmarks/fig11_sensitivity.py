"""Fig. 11 — initial block-group size sensitivity: average swap
granularity across initial sizes 64..3000 tokens and update frequencies
(paper: <= 15.13% variation — granularity is governed by GPU memory, not
by the initial size)."""
from dataclasses import replace

from benchmarks.common import SCENARIOS, csv_line
from repro.core import EngineConfig, FastSwitchEngine
from repro.core.policies import FASTSWITCH
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import sample_conversations


def main(emit=print, sizes_tokens=(64, 256, 1000, 3000),
         freqs=(0.02, 0.04)):
    rows = {}
    for freq in freqs:
        grans = {}
        for size in sizes_tokens:
            blocks = max(1, size // 16)
            sc = SCENARIOS["llama8b-a10"]
            pol = replace(FASTSWITCH, initial_group_blocks=blocks)
            cfg = replace(EngineConfig(mode="sim", **sc["engine"]),
                          policy=pol)
            convs = sample_conversations(120, rate_req_s=2.0, seed=7)
            eng = FastSwitchEngine(cfg, convs,
                                   trace=PriorityTrace("markov", freq, seed=7))
            eng.run(max_iterations=2_000_000)
            sw = eng.swap.stats()
            grans[size] = sw["total_blocks"] / max(sw["total_ops"], 1)
        lo, hi = min(grans.values()), max(grans.values())
        spread = (hi - lo) / max(lo, 1e-9)
        rows[freq] = (grans, spread)
        for size, g in grans.items():
            emit(csv_line(f"fig11_freq{freq}_init{size}tok", g * 1e3,
                          f"avg_blocks_per_op={g:.1f}"))
        emit(csv_line(f"fig11_freq{freq}_spread", spread * 1e6,
                      f"relative_spread={spread:.3f}"))
    return rows


if __name__ == "__main__":
    main()
