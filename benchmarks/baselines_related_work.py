"""Related-work baseline ladder (paper §2 motivation): recompute-preemption
vs vLLM per-block swapping vs Llumnix staging-buffer merging vs FastSwitch.
Reproduces the paper's qualitative ordering and its Challenge-#1 claim that
a small merge buffer cannot recover the lost granularity."""
from benchmarks.common import csv_line, run_policy

LADDER = ("vllm-recompute", "vllm", "llumnix", "fastswitch",
          "fastswitch+zip")


def main(emit=print):
    rows = {}
    base = None
    for pol in LADDER:
        eng = run_policy("llama8b-a10", pol, pattern="markov")
        s = eng.metrics.summary()
        sw = eng.swap.stats()
        if pol == "vllm":
            base = s
        rows[pol] = (s, sw)
        gran = sw["total_blocks"] / max(sw["total_ops"], 1)
        emit(csv_line(
            f"baseline_{pol}", s["p99_ttft_ms"] * 1e3,
            f"p999tbt={s['p999_tbt_ms']:.0f}ms thr={s['throughput_tok_s']:.1f} "
            f"stall={sw['total_stall_us'] / 1e6:.2f}s ops={sw['total_ops']} "
            f"gran={gran:.1f}"))
    return rows


if __name__ == "__main__":
    main()
