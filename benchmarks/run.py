"""Benchmark harness entry point — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (spec'd format)."""
import sys
import time
import traceback

from benchmarks import (baselines_related_work, decode_hotpath,
                        fig1_latency_breakdown, fig2_waiting_requests,
                        fig8_slo_latency, fig8_throughput, fig9_callstack,
                        fig10_ctx_switch, fig11_sensitivity,
                        fig12_token_efficiency, fig13_cpu_memory,
                        kernel_microbench, roofline_report,
                        table1_microbench)

ALL = [
    ("fig1", fig1_latency_breakdown),
    ("fig2", fig2_waiting_requests),
    ("fig8_slo", fig8_slo_latency),
    ("fig8_throughput", fig8_throughput),
    ("fig9", fig9_callstack),
    ("fig10", fig10_ctx_switch),
    ("fig11", fig11_sensitivity),
    ("fig12", fig12_token_efficiency),
    ("fig13", fig13_cpu_memory),
    ("table1", table1_microbench),
    ("baselines", baselines_related_work),
    ("kernels", kernel_microbench),
    ("decode_hotpath", decode_hotpath),
    ("roofline", roofline_report),
]


def main() -> None:
    only = set(sys.argv[1:])
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in ALL:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            if name == "fig8_slo":
                # full 2-model x 2-pattern grid is the EXPERIMENTS.md run;
                # the default harness does the paper's primary scenario
                mod.main(scenarios=("llama8b-a10",),
                         patterns=("markov", "random"))
            else:
                mod.main()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
