"""Fig. 1 — latency breakdown across percentiles: swap-induced stall vs
inference time under the vLLM baseline (the paper's motivating plot:
P99 ~1.6x P50 with ~60% of it preemption stall)."""
import numpy as np

from benchmarks.common import csv_line, run_policy


def main(emit=print):
    eng = run_policy("llama8b-a10", "vllm")
    m = eng.metrics
    # per-token latency = TBT samples; stall share from the swap manager
    tbts = np.asarray(m.tbts_us)
    infer_us = np.median([r[2] for r in m.iter_records])
    rows = []
    for p in (50, 90, 99, 99.9):
        lat = float(np.percentile(tbts, p))
        stall = max(0.0, lat - infer_us)
        rows.append((p, lat, stall / max(lat, 1e-9)))
        emit(csv_line(f"fig1_p{p}_token_latency", lat,
                      f"stall_share={stall / max(lat, 1e-9):.2f}"))
    p50 = rows[0][1]
    p99 = rows[2][1]
    emit(csv_line("fig1_p99_over_p50", p99, f"ratio={p99 / p50:.2f}"))
    return rows


if __name__ == "__main__":
    main()
