"""Quickstart: serve a reduced Llama-3.2 with FastSwitch on CPU.

Real tokens flow through the paged KV pool (Pallas paged attention in
interpret mode), with priority-driven preemption, block-group swaps and
KV reuse across conversation turns.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_smoke_config
from repro.core import EngineConfig, FastSwitchEngine
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import Conversation, Turn
from repro.models import transformer as T


def main():
    cfg = get_smoke_config("llama3.2-3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name}  "
          f"({sum(x.size for x in jax.tree.leaves(params)):,} params)")

    conversations = [
        Conversation(conv_id=i, arrival_s=0.2 * i,
                     turns=[Turn(prompt_tokens=16, response_tokens=12),
                            Turn(prompt_tokens=8, response_tokens=12)],
                     think_time_s=1.0)
        for i in range(6)
    ]

    engine_cfg = EngineConfig(
        mode="real", num_gpu_blocks=96, num_cpu_blocks=512,
        max_running=4, max_batch=4).with_policy("fastswitch")
    engine = FastSwitchEngine(
        engine_cfg, conversations,
        trace=PriorityTrace("markov", update_freq=0.05, seed=1),
        model_bundle={"cfg": cfg, "params": params})

    metrics = engine.run()
    s = metrics.summary()
    sw = engine.swap.stats()
    print(f"served {s['total_tokens']} tokens over {s['iterations']} iters")
    print(f"p99 TTFT {s['p99_ttft_ms']:.1f} ms   "
          f"p99 TBT {s['p99_tbt_ms']:.2f} ms (modelled A10 latency)")
    print(f"preemptions {s['preemptions']}  swap ops {sw['total_ops']}  "
          f"avg granularity {sw['total_blocks'] / max(sw['total_ops'], 1):.1f} "
          f"blocks/op")
    for cid, hist in sorted(engine._token_hist_by_conv.items()):
        print(f"conv {cid}: ...{hist[-6:]}")


if __name__ == "__main__":
    main()
