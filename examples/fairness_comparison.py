"""Scenario: fairness under frequent priority updates — vLLM baseline vs
FastSwitch on the paper's LLaMA-8B/A10 serving scenario (trace-driven).

    PYTHONPATH=src python examples/fairness_comparison.py
"""
import sys

sys.path.insert(0, ".")  # for benchmarks.common when run from repo root

from benchmarks.common import POLICY_ORDER, run_policy


def main():
    print(f"{'policy':14s} {'p95 TTFT':>12s} {'p99 TTFT':>12s} "
          f"{'p99.9 TBT':>12s} {'tok/s':>8s} {'swap ops':>9s} {'stall':>9s}")
    base = None
    for pol in POLICY_ORDER:
        eng = run_policy("llama8b-a10", pol, pattern="markov")
        s = eng.metrics.summary()
        sw = eng.swap.stats()
        if base is None:
            base = s
        print(f"{pol:14s} {s['p95_ttft_ms']:10.0f} ms {s['p99_ttft_ms']:10.0f} ms "
              f"{s['p999_tbt_ms']:10.0f} ms {s['throughput_tok_s']:8.1f} "
              f"{sw['total_ops']:9d} {sw['total_stall_us'] / 1e6:7.1f}s")
    print("\nspeedups are FastSwitch's contribution: block-group I/O, "
          "KV reuse, async swapping (see EXPERIMENTS.md)")


if __name__ == "__main__":
    main()
