"""Scenario: end-to-end training driver — train a reduced (~1-10M param)
model from the assigned pool for a few hundred steps on CPU and watch the
loss drop; saves a checkpoint.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-1.5b --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import steps as S
from repro.models import transformer as T
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optimizer import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint", default="/tmp/repro_ckpt.npz")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt = adamw_init(params)
    print(f"{cfg.name}: {sum(x.size for x in jax.tree.leaves(params)):,} params")

    # learnable synthetic task: next token = (token + 1) % V over a small
    # alphabet — the loss should fall well below ln(alphabet)
    alphabet = 64

    def make_batch(i):
        k = jax.random.fold_in(key, i)
        start = jax.random.randint(k, (args.batch, 1), 0, alphabet)
        seq = (start + jnp.arange(args.seq + 1)[None, :]) % alphabet
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    step_fn = jax.jit(lambda p, o, b: S.train_step(p, o, b, cfg=cfg,
                                                   lr=1e-3, remat=False))
    t0 = time.time()
    first = last = None
    for i in range(args.steps):
        params, opt, loss = step_fn(params, opt, make_batch(i))
        if first is None:
            first = float(loss)
        last = float(loss)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}  "
                  f"({time.time() - t0:.0f}s)", flush=True)
    assert last < first, "loss did not improve"
    save_checkpoint(args.checkpoint, params)
    restored = load_checkpoint(args.checkpoint, params)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: bool(jnp.all(a == b)), params, restored))
    print(f"loss {first:.3f} -> {last:.3f}; checkpoint round-trip OK "
          f"({args.checkpoint})")


if __name__ == "__main__":
    main()
