"""Paged decode (engine data plane) vs contiguous decode (dry-run path):
identical logits through scattered block tables."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import steps, transformer as T
from repro.models.paged import paged_decode_step, prefill_kv


def test_paged_equals_contiguous():
    cfg = get_smoke_config("qwen2-1.5b")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    bs = 16
    T0 = 24
    tokens = jax.random.randint(key, (1, T0), 0, cfg.vocab_size)

    # contiguous reference
    logits_ref, raw = steps.prefill(params, cfg, tokens)
    caches = steps.caches_from_prefill(cfg, raw, 1, 64)

    # paged: write prefill K/V into a pool through a SCATTERED block table
    _, k, v = prefill_kv(params, tokens, cfg=cfg)      # (L, T0, H, D)
    L = cfg.n_layers
    nb = 8
    pool = jnp.zeros((L, 2, nb, bs, cfg.n_kv_heads, cfg.resolved_head_dim),
                     jnp.bfloat16)
    table = [5, 2, 7]                                   # scattered on purpose
    for i, blk in enumerate(table[:2]):                 # T0=24 -> 2 blocks
        t0, t1 = i * bs, min((i + 1) * bs, T0)
        pool = pool.at[:, 0, blk, :t1 - t0].set(
            k[:, t0:t1].astype(jnp.bfloat16))
        pool = pool.at[:, 1, blk, :t1 - t0].set(
            v[:, t0:t1].astype(jnp.bfloat16))
    bt = jnp.asarray([table], jnp.int32)

    tok = jnp.argmax(logits_ref, -1).astype(jnp.int32)
    ctx = jnp.asarray([T0], jnp.int32)
    for i in range(3):
        # contiguous
        nxt_ref, logits_c, caches = steps.serve_step(
            params, caches, tok, jnp.int32(T0 + i), cfg=cfg)
        # paged
        nxt_p, logits_p, pool = paged_decode_step(
            params, pool, bt, ctx + i, tok, cfg=cfg)
        np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                                   np.asarray(logits_c, np.float32),
                                   atol=0.15)
        assert int(nxt_p[0]) == int(nxt_ref[0]), f"step {i} token diverged"
        tok = nxt_ref
