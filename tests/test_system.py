"""End-to-end system behaviour: the full FastSwitch stack (priority
scheduler + block groups + swap manager + reuse + real model + Pallas
paged attention) serving multi-turn conversations."""
import jax
import pytest

from repro.configs import get_smoke_config
from repro.core import EngineConfig, FastSwitchEngine
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import sample_conversations
from repro.models import transformer as T


@pytest.fixture(scope="module")
def bundle():
    cfg = get_smoke_config("llama3.2-3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return {"cfg": cfg, "params": params}


def test_end_to_end_real_serving(bundle):
    convs = sample_conversations(6, rate_req_s=4.0, seed=5, prompt_mu=2.5,
                                 resp_mu=2.2, max_tokens=48)
    total_resp = sum(t.response_tokens for c in convs for t in c.turns)
    ec = EngineConfig(mode="real", num_gpu_blocks=96, num_cpu_blocks=512,
                      max_running=4, max_batch=4).with_policy("fastswitch")
    eng = FastSwitchEngine(ec, [c for c in convs],
                           trace=PriorityTrace("markov", 0.05, seed=2),
                           model_bundle=bundle)
    m = eng.run(max_iterations=50_000)
    assert eng.done()
    assert m.total_tokens == total_resp
    s = m.summary()
    assert s["throughput_tok_s"] > 0
    assert len(m.ttfts_us) == sum(len(c.turns) for c in convs)
    # system stayed consistent
    eng.gpu_mgr.check_invariants()
    eng.reuse.mgr.check_invariants()


def test_end_to_end_policies_agree_on_tokens(bundle):
    """Different policies change WHEN work happens, never WHAT is computed:
    identical token streams across all four policies."""
    hists = {}
    for pol in ("vllm", "fastswitch"):
        convs = sample_conversations(4, rate_req_s=4.0, seed=9, prompt_mu=2.5,
                                     resp_mu=2.0, max_tokens=32)
        ec = EngineConfig(mode="real", num_gpu_blocks=48, num_cpu_blocks=512,
                          max_running=3, max_batch=4).with_policy(pol)
        eng = FastSwitchEngine(ec, convs,
                               trace=PriorityTrace("random", 0.2, seed=4),
                               model_bundle=bundle)
        eng.run(max_iterations=50_000)
        assert eng.done()
        hists[pol] = eng._token_hist_by_conv
    assert hists["vllm"] == hists["fastswitch"]
