"""End-to-end system behaviour: the full FastSwitch stack (priority
scheduler + block groups + swap manager + reuse + real model + Pallas
paged attention) serving multi-turn conversations.

Each test runs its engine workload in a FRESH SUBPROCESS.  Running
these last in a full-suite process segfaults inside jaxlib's native
``backend_compile`` (XLA CPU) — the crash is in XLA, not repo code:
the faulting thread is compiling a ``lax.cond`` that every other run
compiles fine, it only reproduces after the preceding ~70 test files
have accumulated hundreds of compiled executables in one process, and
this module passes standalone in any order.  A fresh process sidesteps
the accumulated-jit-state crash and also makes these tests immune to
compilation-cache crosstalk from earlier tests.
"""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_PRELUDE = """
import json
import jax

from repro.configs import get_smoke_config
from repro.core import EngineConfig, FastSwitchEngine
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import sample_conversations
from repro.models import transformer as T

cfg = get_smoke_config("llama3.2-3b")
params = T.init_params(cfg, jax.random.PRNGKey(0))
bundle = {"cfg": cfg, "params": params}
"""


def _run_isolated(code, timeout=1200):
    env = {**os.environ, "PYTHONPATH": SRC}
    r = subprocess.run([sys.executable, "-c", _PRELUDE + code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return json.loads(r.stdout.splitlines()[-1])


def test_end_to_end_real_serving():
    out = _run_isolated("""
convs = sample_conversations(6, rate_req_s=4.0, seed=5, prompt_mu=2.5,
                             resp_mu=2.2, max_tokens=48)
total_resp = sum(t.response_tokens for c in convs for t in c.turns)
ec = EngineConfig(mode="real", num_gpu_blocks=96, num_cpu_blocks=512,
                  max_running=4, max_batch=4).with_policy("fastswitch")
eng = FastSwitchEngine(ec, [c for c in convs],
                       trace=PriorityTrace("markov", 0.05, seed=2),
                       model_bundle=bundle)
m = eng.run(max_iterations=50_000)
assert eng.done()
s = m.summary()
# system stayed consistent
eng.gpu_mgr.check_invariants()
eng.reuse.mgr.check_invariants()
print(json.dumps({
    "total_tokens": m.total_tokens,
    "total_resp": total_resp,
    "throughput_tok_s": s["throughput_tok_s"],
    "n_ttfts": len(m.ttfts_us),
    "n_turns": sum(len(c.turns) for c in convs),
}))
""")
    assert out["total_tokens"] == out["total_resp"]
    assert out["throughput_tok_s"] > 0
    assert out["n_ttfts"] == out["n_turns"]


def test_end_to_end_policies_agree_on_tokens():
    """Different policies change WHEN work happens, never WHAT is computed:
    identical token streams across policies."""
    out = _run_isolated("""
hists = {}
for pol in ("vllm", "fastswitch"):
    convs = sample_conversations(4, rate_req_s=4.0, seed=9, prompt_mu=2.5,
                                 resp_mu=2.0, max_tokens=32)
    ec = EngineConfig(mode="real", num_gpu_blocks=48, num_cpu_blocks=512,
                      max_running=3, max_batch=4).with_policy(pol)
    eng = FastSwitchEngine(ec, convs,
                           trace=PriorityTrace("random", 0.2, seed=4),
                           model_bundle=bundle)
    eng.run(max_iterations=50_000)
    assert eng.done()
    hists[pol] = {str(k): v for k, v in eng._token_hist_by_conv.items()}
print(json.dumps({"agree": hists["vllm"] == hists["fastswitch"]}))
""")
    assert out["agree"] is True
