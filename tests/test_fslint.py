"""fslint (src/repro/analysis) — fixture-driven rule tests.

Each rule gets a positive (planted violation detected), a negative
(disciplined code stays clean), and a suppressed variant (inline
disable honoured).  Plus: baseline round-trip, malformed-suppression
reporting, the CLI json contract, and a self-run over ``src/repro``
asserting the shipped tree carries zero non-baselined findings (the
tier-1 CI gate).
"""
import json
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis import Config, run_analysis
from repro.analysis.baseline import Baseline
from repro.analysis.core import parse_suppressions
from repro.analysis.driver import AnalysisResult

REPO = Path(__file__).resolve().parents[1]


def _run(tmp_path, sources, rules=None):
    for name, text in sources.items():
        (tmp_path / name).write_text(text, encoding="utf-8")
    cfg = Config(rules=tuple(rules) if rules else None)
    return run_analysis([str(tmp_path)], cfg, repo_root=str(tmp_path))


def _rules_of(result):
    return sorted({f.rule for f in result.findings})


JIT_PRELUDE = """\
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, donate_argnums=(0,))
def donate_step(pool, x):
    return pool + x
"""


# ---------------------------------------------------------------------------
# FS001 use-after-donate
# ---------------------------------------------------------------------------

class TestFS001:
    def test_positive_read_after_donate(self, tmp_path):
        res = _run(tmp_path, {"m.py": JIT_PRELUDE + """

def bad(pool, x):
    out = donate_step(pool, x)
    return pool.sum() + out
"""}, rules=["FS001"])
        assert [f.rule for f in res.findings] == ["FS001"]
        assert "'pool'" in res.findings[0].message

    def test_positive_donate_in_loop_without_rebind(self, tmp_path):
        res = _run(tmp_path, {"m.py": JIT_PRELUDE + """

def bad_loop(pool, xs):
    acc = None
    for x in xs:
        acc = donate_step(pool, x)
    return acc
"""}, rules=["FS001"])
        assert [f.rule for f in res.findings] == ["FS001"]
        assert "loop" in res.findings[0].message

    def test_positive_through_wrapper_propagation(self, tmp_path):
        res = _run(tmp_path, {"m.py": JIT_PRELUDE + """

def wrapper(pool, x):
    return donate_step(pool, x)


def caller(pool, x):
    y = wrapper(pool, x)
    return pool * 2
"""}, rules=["FS001"])
        assert [f.rule for f in res.findings] == ["FS001"]
        assert res.findings[0].qualname.endswith("caller")

    def test_negative_rebind_and_return(self, tmp_path):
        res = _run(tmp_path, {"m.py": JIT_PRELUDE + """

def good(pool, x):
    pool = donate_step(pool, x)
    return pool


def good_return(pool, x):
    return donate_step(pool, x)


def good_loop(pool, xs):
    for x in xs:
        pool = donate_step(pool, x)
    return pool
"""}, rules=["FS001"])
        assert res.findings == []

    def test_suppressed(self, tmp_path):
        res = _run(tmp_path, {"m.py": JIT_PRELUDE + """

def waived(pool, x):
    out = donate_step(pool, x)
    # fslint: disable=FS001(test fixture reads a donated buffer on purpose)
    return pool.sum() + out
"""}, rules=["FS001"])
        assert res.findings == []
        assert [f.rule for f in res.suppressed] == ["FS001"]


# ---------------------------------------------------------------------------
# FS002 jit-variant budget
# ---------------------------------------------------------------------------

FS002_PRELUDE = """\
import functools

import jax
import jax.numpy as jnp


def _next_pow2(n):
    p = 1
    while p < n:
        p *= 2
    return p


@functools.partial(jax.jit, static_argnames=("n",))
def padded(x, n):
    return x[:n]
"""


class TestFS002:
    def test_positive_unbucketed_static_arg(self, tmp_path):
        res = _run(tmp_path, {"m.py": FS002_PRELUDE + """

def decode(items):
    return padded(jnp.zeros((4,)), n=len(items))
"""}, rules=["FS002"])
        assert [f.rule for f in res.findings] == ["FS002"]
        assert "static arg 'n'" in res.findings[0].message

    def test_positive_unbucketed_traced_shape(self, tmp_path):
        res = _run(tmp_path, {"m.py": FS002_PRELUDE + """

def step(items):
    return padded(jnp.zeros((len(items),)), n=4)
"""}, rules=["FS002"])
        assert [f.rule for f in res.findings] == ["FS002"]
        assert "traced array arg" in res.findings[0].message

    def test_negative_bucketed(self, tmp_path):
        res = _run(tmp_path, {"m.py": FS002_PRELUDE + """

def step(items):
    n = max(_next_pow2(len(items)), 4)
    return padded(jnp.zeros((n,)), n=n)
"""}, rules=["FS002"])
        assert res.findings == []

    def test_cold_path_not_checked(self, tmp_path):
        # only hot-path-reachable call sites are budget-checked
        res = _run(tmp_path, {"m.py": FS002_PRELUDE + """

def offline_eval(items):
    return padded(jnp.zeros((4,)), n=len(items))
"""}, rules=["FS002"])
        assert res.findings == []

    def test_degrees_reported_for_audit(self, tmp_path):
        res = _run(tmp_path, {"m.py": FS002_PRELUDE + """

def step(items):
    n = _next_pow2(len(items))
    return padded(jnp.zeros((n,)), n=n)
"""}, rules=["FS002"])
        (qual, deg), = res.jit_degrees.items()
        assert qual.endswith("padded") and deg == 1

    def test_suppressed(self, tmp_path):
        res = _run(tmp_path, {"m.py": FS002_PRELUDE + """

def step(items):
    # fslint: disable=FS002(bounded offline batch, at most 3 variants)
    return padded(jnp.zeros((4,)), n=len(items))
"""}, rules=["FS002"])
        assert res.findings == []
        assert [f.rule for f in res.suppressed] == ["FS002"]


# ---------------------------------------------------------------------------
# FS003 host sync in hot path
# ---------------------------------------------------------------------------

class TestFS003:
    def test_positive_np_asarray_on_device(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import numpy as np
import jax.numpy as jnp


def step(xs):
    dev = jnp.asarray(xs) * 2
    return np.asarray(dev)[0]
"""}, rules=["FS003"])
        assert [f.rule for f in res.findings] == ["FS003"]
        assert "np.asarray" in res.findings[0].message

    def test_positive_int_item_and_branch(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import jax.numpy as jnp


def step(xs):
    dev = jnp.sum(jnp.asarray(xs))
    a = int(dev)
    b = dev.item()
    if dev > 0:
        a += 1
    return a + b
"""}, rules=["FS003"])
        kinds = sorted(f.message.split(" ")[0] for f in res.findings)
        assert len(res.findings) == 3
        assert any("int()" in f.message for f in res.findings), kinds
        assert any(".item()" in f.message for f in res.findings)
        assert any("branching" in f.message for f in res.findings)

    def test_positive_device_attr_ring_buffer(self, tmp_path):
        # device values parked in a container attribute keep their
        # taint when read back in a later step (deferred-sync pattern)
        res = _run(tmp_path, {"m.py": """\
import numpy as np
import jax.numpy as jnp


class Runner:
    def __init__(self):
        self._pending = []

    def decode(self, xs):
        nxt = jnp.asarray(xs) + 1
        self._pending.append(nxt)

    def step(self):
        for nxt in self._pending:
            print(np.asarray(nxt))
        if not self._pending:      # host len check: NOT a sync
            return
"""}, rules=["FS003"])
        assert [f.rule for f in res.findings] == ["FS003"]

    def test_negative_host_values_and_cold_path(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import numpy as np
import jax.numpy as jnp


def step(xs):
    host = np.asarray(xs)          # unknown input: no device taint
    return int(host[0])


def offline(xs):
    dev = jnp.asarray(xs)
    return np.asarray(dev)         # not reachable from a hot root
"""}, rules=["FS003"])
        assert res.findings == []

    def test_allowlisted_staged_sync_point(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import jax
import jax.numpy as jnp


class PagedPools:
    def copy_in_staged(self, blocks):
        self.gpu = jnp.asarray(blocks)
        jax.block_until_ready(self.gpu)


def step(pools, blocks):
    pools.copy_in_staged(blocks)
"""}, rules=["FS003"])
        assert res.findings == []

    def test_suppressed(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import numpy as np
import jax.numpy as jnp


def step(xs):
    dev = jnp.asarray(xs) * 2
    # fslint: disable=FS003(documented deferred sync point)
    return np.asarray(dev)[0]
"""}, rules=["FS003"])
        assert res.findings == []
        assert [f.rule for f in res.suppressed] == ["FS003"]


# ---------------------------------------------------------------------------
# FS004 swap-plane thread discipline
# ---------------------------------------------------------------------------

FS004_COMMON = """\
import functools

import jax
from concurrent.futures import ThreadPoolExecutor


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter(pool, x):
    return pool


class Pools:
    def copy_in_staged(self, blocks):
        self.gpu = scatter(self.gpu, blocks)

    def copy_out_staged(self, blocks):
        return list(blocks)


def make_task(pools, task, direction):
    if direction == "out":
        copy_fn = lambda: pools.copy_out_staged([1])
    else:
        copy_fn = lambda: pools.copy_in_staged([1])
    task.copy_fn = copy_fn
    return task
"""


class TestFS004:
    def test_positive_unguarded_submit(self, tmp_path):
        res = _run(tmp_path, {"m.py": FS004_COMMON + """

class Manager:
    def __init__(self):
        self._executor = ThreadPoolExecutor(1)

    def dispatch(self, task, direction):
        task.future = self._executor.submit(self._run, task)

    def _run(self, task):
        task.copy_fn()
"""}, rules=["FS004"])
        assert [f.rule for f in res.findings] == ["FS004"]
        assert "copy_in_staged" in res.findings[0].message

    def test_negative_direction_guarded_submit(self, tmp_path):
        res = _run(tmp_path, {"m.py": FS004_COMMON + """

class Manager:
    def __init__(self):
        self._executor = ThreadPoolExecutor(1)

    def dispatch(self, task, direction, asynchronous):
        if asynchronous and direction == "out":
            task.future = self._executor.submit(self._run, task)
        else:
            self._run(task)

    def _run(self, task):
        task.copy_fn()
"""}, rules=["FS004"])
        assert res.findings == []

    def test_suppressed(self, tmp_path):
        res = _run(tmp_path, {"m.py": FS004_COMMON + """

class Manager:
    def __init__(self):
        self._executor = ThreadPoolExecutor(1)

    def dispatch(self, task, direction):
        # fslint: disable=FS004(single-threaded executor used as a queue)
        task.future = self._executor.submit(self._run, task)

    def _run(self, task):
        task.copy_fn()
"""}, rules=["FS004"])
        assert res.findings == []
        assert [f.rule for f in res.suppressed] == ["FS004"]


# ---------------------------------------------------------------------------
# FS005 lock discipline
# ---------------------------------------------------------------------------

class TestFS005:
    def test_positive_await_under_lock(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import threading


class M:
    def __init__(self):
        self._pool_lock = threading.Lock()

    def bad(self, task):
        with self._pool_lock:
            task.future.result()
"""}, rules=["FS005"])
        assert [f.rule for f in res.findings] == ["FS005"]
        assert "_pool_lock" in res.findings[0].message

    def test_positive_transitive_await_under_lock(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import threading


class M:
    def __init__(self):
        self._pool_lock = threading.Lock()

    def waiter(self, task):
        task.future.result()

    def bad(self, task):
        with self._pool_lock:
            self.waiter(task)
"""}, rules=["FS005"])
        assert len(res.findings) == 1
        assert "awaits a future" in res.findings[0].message

    def test_positive_lock_order_cycle(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import threading


class M:
    def __init__(self):
        self._a_lock = threading.Lock()
        self._b_lock = threading.Lock()

    def f1(self):
        with self._a_lock:
            with self._b_lock:
                pass

    def f2(self):
        with self._b_lock:
            with self._a_lock:
                pass
"""}, rules=["FS005"])
        assert res.findings and \
            all("cycle" in f.message for f in res.findings)

    def test_negative_await_before_lock(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import threading


class M:
    def __init__(self):
        self._pool_lock = threading.Lock()

    def good(self, task, deps):
        for d in deps:
            d.result()
        with self._pool_lock:
            task.run()
"""}, rules=["FS005"])
        assert res.findings == []

    def test_suppressed(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import threading


class M:
    def __init__(self):
        self._pool_lock = threading.Lock()

    def waived(self, task):
        with self._pool_lock:
            # fslint: disable=FS005(future is already done at this point)
            task.future.result()
"""}, rules=["FS005"])
        assert res.findings == []
        assert [f.rule for f in res.suppressed] == ["FS005"]


# ---------------------------------------------------------------------------
# FS006 un-donated pool write
# ---------------------------------------------------------------------------

class TestFS006:
    def test_positive_whole_pool_at_set(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import jax.numpy as jnp


class P:
    def copy_in(self, data, blocks):
        self.gpu = self.gpu.at[:, blocks].set(data)
"""}, rules=["FS006"])
        assert [f.rule for f in res.findings] == ["FS006"]

    def test_negative_inside_jit_and_non_pool(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import functools

import jax


@functools.partial(jax.jit, donate_argnums=(0,))
def update(pool, x):
    return pool.at[0].set(x)


def helper(pool, x):
    return pool.at[0].set(x)      # reachable only from the jit body


@functools.partial(jax.jit, donate_argnums=(0,))
def outer(pool, x):
    return helper(pool, x)


def table_update(bt, rows, vals):
    return bt.at[rows].set(vals)  # not a pool-named buffer
"""}, rules=["FS006"])
        assert res.findings == []

    def test_suppressed(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import jax.numpy as jnp


class P:
    def write_debug(self, data, blocks):
        # fslint: disable=FS006(host-side debug utility)
        self.gpu = self.gpu.at[:, blocks].set(data)
"""}, rules=["FS006"])
        assert res.findings == []
        assert [f.rule for f in res.suppressed] == ["FS006"]


# ---------------------------------------------------------------------------
# shard_map-wrapped jit sites (ISSUE 8): a mesh-sharded step defined as
# ``g = jax.jit(shard_map(f, ...), donate_argnums=..., static_argnames=...)``
# must carry the same donation / bucketing / inside-trace facts as a
# directly-jitted def — no false FS001/FS002/FS006 on disciplined code,
# and the SAME positives when the discipline is broken.
# ---------------------------------------------------------------------------

SHARDED_PRELUDE = """\
import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

MESH = object()
SPEC = object()


def _next_pow2(n):
    p = 1
    while p < n:
        p *= 2
    return p


def _step_body(pool, tok, n):
    pool = pool.at[0].set(tok[:n])
    return pool, tok


step_sharded = jax.jit(
    shard_map(_step_body, mesh=MESH, in_specs=(SPEC, SPEC, SPEC),
              out_specs=(SPEC, SPEC), check_rep=False),
    static_argnames=("n",), donate_argnums=(0,))
"""


class TestShardMapJit:
    def test_donation_seen_through_shard_map(self, tmp_path):
        # positive: pool read after the sharded step donated it
        res = _run(tmp_path, {"m.py": SHARDED_PRELUDE + """

def bad(pool, tok):
    out, _ = step_sharded(pool, tok, n=4)
    return pool.sum() + out.sum()
"""}, rules=["FS001"])
        assert [f.rule for f in res.findings] == ["FS001"]
        assert "'pool'" in res.findings[0].message

    def test_negative_disciplined_sharded_caller(self, tmp_path):
        # rebind + pow2 bucket + inside-trace pool update: fully clean
        res = _run(tmp_path, {"m.py": SHARDED_PRELUDE + """

def decode(pool, tok, items):
    n = max(_next_pow2(len(items)), 4)
    pool, tok = step_sharded(pool, tok, n=n)
    return pool, tok
"""}, rules=["FS001", "FS002", "FS006"])
        assert res.findings == []

    def test_variant_budget_applies_to_sharded_alias(self, tmp_path):
        # positive: unbucketed static arg on the shard_map-wrapped jit
        res = _run(tmp_path, {"m.py": SHARDED_PRELUDE + """

def decode(pool, tok, items):
    pool, tok = step_sharded(pool, tok, n=len(items))
    return pool, tok
"""}, rules=["FS002"])
        assert [f.rule for f in res.findings] == ["FS002"]
        assert "static arg 'n'" in res.findings[0].message

    def test_wrapped_body_counts_as_inside_trace(self, tmp_path):
        # the .at[].set inside _step_body is donated by the alias's jit
        # — FS006 must not flag it (directly-jitted defs already pass)
        res = _run(tmp_path, {"m.py": SHARDED_PRELUDE}, rules=["FS006"])
        assert res.findings == []


# ---------------------------------------------------------------------------
# suppression parsing / FS000
# ---------------------------------------------------------------------------

class TestSuppressions:
    def test_reason_required(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import numpy as np
import jax.numpy as jnp


def step(xs):
    dev = jnp.asarray(xs)
    # fslint: disable=FS003
    return np.asarray(dev)
"""})
        rules = _rules_of(res)
        assert "FS000" in rules          # malformed suppression reported
        assert "FS003" in rules          # and the finding is NOT waived

    def test_multi_clause_parsing(self):
        sup = parse_suppressions(
            "x = 1  # fslint: disable=FS001(a b), FS003(c)\n")
        assert sup.by_line[1] == {"FS001": "a b", "FS003": "c"}
        assert sup.covers(1, "FS001") and sup.covers(2, "FS003")
        assert not sup.covers(1, "FS002") and not sup.covers(3, "FS001")

    def test_fs000_cannot_be_disabled(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
# fslint: disable=FS000(nope)
x = 1
"""})
        assert "FS000" in _rules_of(res)


# ---------------------------------------------------------------------------
# baseline round-trip
# ---------------------------------------------------------------------------

class TestBaseline:
    FIXTURE = {"m.py": """\
import jax.numpy as jnp


class P:
    def copy_in(self, data, blocks):
        self.gpu = self.gpu.at[:, blocks].set(data)
"""}

    def test_round_trip_and_stale(self, tmp_path):
        res = _run(tmp_path, self.FIXTURE, rules=["FS006"])
        assert len(res.findings) == 1

        bl_path = tmp_path / "baseline.json"
        bl = Baseline(bl_path)
        bl.save(res.findings)

        # reload: the finding is now grandfathered
        bl2 = Baseline.load(bl_path)
        new, known, stale = bl2.split(res.findings)
        assert new == [] and len(known) == 1 and stale == []

        # fingerprints survive line shifts (edits above the finding)
        shifted = _run(tmp_path, {
            "m.py": "# a new leading comment\n" + self.FIXTURE["m.py"]},
            rules=["FS006"])
        new, known, stale = bl2.split(shifted.findings)
        assert new == [] and len(known) == 1 and stale == []

        # fixing the violation leaves a prunable stale entry, not a gate
        clean = _run(tmp_path, {"m.py": "x = 1\n"}, rules=["FS006"])
        new, known, stale = bl2.split(clean.findings)
        assert new == [] and known == [] and len(stale) == 1


# ---------------------------------------------------------------------------
# FS007 blocking call in async def
# ---------------------------------------------------------------------------

class TestFS007:
    def test_positive_time_sleep_in_async(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import time


async def handler(req):
    time.sleep(0.1)
    return req
"""}, rules=["FS007"])
        assert [f.rule for f in res.findings] == ["FS007"]
        assert "time.sleep" in res.findings[0].message

    def test_positive_future_result_and_socket_recv(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
async def pump(fut, sock):
    data = sock.recv(4096)
    return fut.result(), data
"""}, rules=["FS007"])
        assert len(res.findings) == 2
        assert all(f.rule == "FS007" for f in res.findings)

    def test_positive_device_sync_in_async(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import jax


async def stream(out):
    jax.block_until_ready(out)
    return out
"""}, rules=["FS007"])
        assert [f.rule for f in res.findings] == ["FS007"]

    def test_negative_sync_def_and_awaited_calls(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import asyncio
import time


def worker_thread(fut):
    time.sleep(0.1)            # fine: not on the event loop
    return fut.result()


async def handler(rep, ws):
    data = await ws.recv()     # directly awaited: yields to the loop
    res = await asyncio.wrap_future(rep.call())
    await asyncio.sleep(0.01)
    return data, res
"""}, rules=["FS007"])
        assert res.findings == []

    def test_suppressed(self, tmp_path):
        res = _run(tmp_path, {"m.py": """\
import time


async def shutdown():
    # fslint: disable=FS007(final drain, loop is exiting anyway)
    time.sleep(0.01)
"""}, rules=["FS007"])
        assert res.findings == []
        assert [f.rule for f in res.suppressed] == ["FS007"]


# ---------------------------------------------------------------------------
# CLI contract + self-run gate
# ---------------------------------------------------------------------------

def _cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd, env=env)


class TestCLI:
    def test_dirty_fixture_exits_1_with_json(self, tmp_path):
        (tmp_path / "m.py").write_text(
            "import jax.numpy as jnp\n\n\n"
            "class P:\n"
            "    def copy_in(self, d, b):\n"
            "        self.gpu = self.gpu.at[:, b].set(d)\n",
            encoding="utf-8")
        proc = _cli(["m.py", "--format", "json", "--baseline",
                     "absent.json"], cwd=tmp_path)
        assert proc.returncode == 1, proc.stderr
        payload = json.loads(proc.stdout)
        assert payload["exit"] == 1
        assert [f["rule"] for f in payload["new"]] == ["FS006"]

    def test_clean_fixture_exits_0(self, tmp_path):
        (tmp_path / "m.py").write_text("x = 1\n", encoding="utf-8")
        proc = _cli(["m.py"], cwd=tmp_path)
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_unknown_rule_exits_2(self, tmp_path):
        (tmp_path / "m.py").write_text("x = 1\n", encoding="utf-8")
        proc = _cli(["m.py", "--rule", "FS999"], cwd=tmp_path)
        assert proc.returncode == 2

    def test_missing_path_exits_2(self, tmp_path):
        proc = _cli(["does_not_exist_dir"], cwd=tmp_path)
        assert proc.returncode == 2


class TestSelfRun:
    def test_shipped_tree_is_clean(self):
        """The tier-1 gate: zero non-baselined findings on src/repro."""
        res = run_analysis([str(REPO / "src" / "repro")],
                           repo_root=str(REPO))
        bl = Baseline.load(REPO / "fslint-baseline.json")
        new, _known, _stale = bl.split(res.findings)
        assert new == [], "\n".join(f.render() for f in new)

    def test_real_tree_donation_registry_sane(self):
        """The donation registry must keep seeing the real hot-path
        chain — if these disappear, FS001/FS004 have gone blind."""
        from repro.analysis.callgraph import Project
        p = Project([REPO / "src" / "repro"], REPO, Config())
        donated = set(p.donated_params)
        for suffix in ("kernels.ops._scatter_swap",
                       "models.paged.paged_decode_step_device",
                       "core.decode_runner.DecodeRunner.decode",
                       "core.decode_runner.DecodeRunner.prefill_insert"):
            assert any(q.endswith(suffix) for q in donated), suffix

    def test_variant_bound_shape(self):
        assert AnalysisResult.variant_bound(0, 1024) == 13 ** 2
        assert AnalysisResult.variant_bound(3, 1024) == 13 ** 3
        assert AnalysisResult.variant_bound(2, 2048) == 14 ** 2
