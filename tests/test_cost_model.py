"""I/O cost model + trace generators."""
import numpy as np

from repro.data.priority import PriorityTrace
from repro.data.sharegpt import sample_conversations, trace_stats
from repro.io.cost_model import (A10_PCIE4, IterationCostModel,
                                 dispatch_time_us, exec_time_us,
                                 transfer_time_us)


def test_small_transfers_are_dispatch_bound():
    """Paper Fig. 3: a 128 KB per-block copy is dominated by dispatch."""
    t = transfer_time_us(A10_PCIE4, 128 * 1024, h2d=False)
    d = dispatch_time_us(A10_PCIE4)
    assert d / t > 0.85


def test_large_transfers_amortize_dispatch():
    nbytes = 20 * 128 * 1024                       # a ~20-block group
    t = transfer_time_us(A10_PCIE4, nbytes, h2d=False)
    d = dispatch_time_us(A10_PCIE4)
    assert d / t < 0.65
    # grouped moves the same bytes faster than per-block
    per_block = 20 * transfer_time_us(A10_PCIE4, 128 * 1024, h2d=False)
    assert t < per_block / 3


def test_bandwidth_ramp_monotone():
    xs = [exec_time_us(A10_PCIE4, n, True) / max(n, 1)
          for n in (16 * 1024, 64 * 1024, 320 * 1024, 1 << 20)]
    assert all(a >= b - 1e-12 for a, b in zip(xs, xs[1:]))


def test_iteration_cost_scales():
    m = IterationCostModel(A10_PCIE4, model_params=8e9,
                           kv_bytes_per_token=131072)
    t1 = m.decode_iter_us(1, 1000)
    t2 = m.decode_iter_us(64, 64000)
    assert t2 > t1
    assert m.prefill_us(4096) > m.prefill_us(128)
    assert m.decode_iter_us(0, 0) == 0.0


def test_sharegpt_stats_match_paper_shape():
    convs = sample_conversations(500, seed=0)
    s = trace_stats(convs)
    assert 4.0 < s["mean_turns"] < 7.0              # paper: 5.5
    assert 0.7 < s["multi_turn_frac"] < 0.86        # paper: 78%
    # Poisson arrivals at ~1 req/s
    arr = [c.arrival_s for c in convs]
    rate = len(arr) / (arr[-1] - arr[0])
    assert 0.8 < rate < 1.25


def test_priority_trace_reproducible():
    t1 = PriorityTrace("markov", 0.05, seed=3)
    t2 = PriorityTrace("markov", 0.05, seed=3)
    ids = list(range(20))
    for _ in range(200):
        t1.step(ids, ids[:4])
        t2.step(ids, ids[:4])
    assert all(t1.priority(i) == t2.priority(i) for i in ids)
