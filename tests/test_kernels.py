"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_copy import block_copy, block_copy_grouped
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import block_copy_ref, mha_ref, paged_attention_ref


@pytest.mark.parametrize("B,Hq,Hkv,D,bs,npages", [
    (1, 4, 4, 64, 16, 2),      # MHA
    (3, 8, 2, 64, 16, 4),      # GQA group=4
    (2, 16, 16, 128, 16, 3),   # MHA wide head
    (2, 12, 2, 128, 32, 2),    # qwen2-like, bigger block
    (1, 8, 1, 64, 16, 8),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, Hq, Hkv, D, bs, npages, dtype):
    key = jax.random.PRNGKey(42)
    nb = npages * B + 3
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kp = jax.random.normal(ks[1], (nb, bs, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (nb, bs, Hkv, D), dtype)
    bt = jax.random.permutation(ks[3], nb)[:B * npages] \
        .reshape(B, npages).astype(jnp.int32)
    # context lens including edge cases: 1 token, partial block, full
    lens = np.linspace(1, npages * bs, B).astype(np.int32)
    ctx = jnp.asarray(lens)
    scale = D ** -0.5
    out = paged_attention(q, kp, vp, bt, ctx, scale)
    ref = paged_attention_ref(q, jnp.stack([kp, vp]), bt, ctx, scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("ppcb", [1, 2, 4])
@pytest.mark.parametrize("npages", [3, 4, 5, 8])
def test_paged_attention_multipage_tiles(ppcb, npages):
    """pages_per_compute_block > 1 streams several KV pages per grid step;
    npages not divisible by ppcb exercises the ragged final tile."""
    B, Hq, Hkv, D, bs = 2, 8, 2, 64, 16
    nb = npages * B + 3
    ks = jax.random.split(jax.random.PRNGKey(42), 4)
    q = jax.random.normal(ks[0], (B, Hq, D))
    kp = jax.random.normal(ks[1], (nb, bs, Hkv, D))
    vp = jax.random.normal(ks[2], (nb, bs, Hkv, D))
    bt = jax.random.permutation(ks[3], nb)[:B * npages] \
        .reshape(B, npages).astype(jnp.int32)
    ctx = jnp.asarray(np.linspace(1, npages * bs, B).astype(np.int32))
    scale = D ** -0.5
    out = paged_attention(q, kp, vp, bt, ctx, scale,
                          pages_per_compute_block=ppcb)
    ref = paged_attention_ref(q, jnp.stack([kp, vp]), bt, ctx, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=1e-5, rtol=1e-5)


def test_paged_attention_zero_context_is_finite():
    q = jnp.ones((2, 4, 64))
    kp = jnp.ones((4, 16, 2, 64))
    vp = jnp.ones((4, 16, 2, 64))
    bt = jnp.zeros((2, 2), jnp.int32)
    ctx = jnp.array([0, 5], jnp.int32)
    out = paged_attention(q, kp, vp, bt, ctx, 0.125)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("n_src,n_dst,n_copy,E", [
    (8, 8, 3, 128), (16, 4, 4, 256), (32, 32, 32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_copy_sweep(n_src, n_dst, n_copy, E, dtype):
    key = jax.random.PRNGKey(0)
    src = jax.random.normal(key, (n_src, E), dtype)
    dst = jnp.zeros((n_dst, E), dtype)
    rng = np.random.RandomState(1)
    si = jnp.asarray(rng.choice(n_src, n_copy, replace=False), jnp.int32)
    di = jnp.asarray(rng.choice(n_dst, n_copy, replace=False), jnp.int32)
    out = block_copy(src, dst, si, di)
    ref = block_copy_ref(src, dst, si, di)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("runs", [
    [(0, 4), (10, 2)],
    [(3, 1)],
    [(0, 8), (8, 8), (20, 4)],
])
def test_block_copy_grouped_sweep(runs):
    key = jax.random.PRNGKey(7)
    src = jax.random.normal(key, (32, 96), jnp.float32)
    dst = jnp.zeros((40, 96), jnp.float32)
    dst_starts = []
    d = 1
    for _, n in runs:
        dst_starts.append(d)
        d += n + 1
    ref = dst
    for (s, n), ds in zip(runs, dst_starts):
        ref = ref.at[ds:ds + n].set(src[s:s + n])
    out = block_copy_grouped(
        src, dst,
        jnp.asarray([r[0] for r in runs], jnp.int32),
        jnp.asarray(dst_starts, jnp.int32),
        jnp.asarray([r[1] for r in runs], jnp.int32),
        run_blocks=max(r[1] for r in runs))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("B,H,T,D,bq,bk", [
    (1, 2, 128, 64, 64, 64),
    (2, 4, 256, 64, 128, 64),
    (1, 1, 512, 128, 128, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, T, D, bq, bk, causal, dtype):
    key = jax.random.PRNGKey(3)
    q, k, v = (jax.random.normal(kk, (B, H, T, D), dtype)
               for kk in jax.random.split(key, 3))
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = mha_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                  v.transpose(0, 2, 1, 3), causal=causal).transpose(0, 2, 1, 3)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# Pallas chunked GLA (Mamba2/SSD scalar decay)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,T,N,P,chunk", [
    (1, 1, 64, 8, 8, 16),
    (2, 3, 128, 16, 32, 32),
    (1, 2, 96, 32, 16, 32),      # T not a chunk multiple of 64
    (2, 1, 64, 64, 64, 64),      # one chunk == T
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gla_scan_scalar_sweep(B, H, T, N, P, chunk, dtype):
    from repro.kernels.gla_scan import gla_scan_scalar
    from repro.models.gla import gla_scan_ref
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = (jax.random.normal(ks[0], (B, H, T, N)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, H, T, N)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, H, T, P)) * 0.5).astype(dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, T)) * 0.5 - 1.0)
    y, S = gla_scan_scalar(q, k, v, logw, chunk=chunk)
    ref, S_ref = gla_scan_ref(
        q, k, v, jnp.broadcast_to(logw[..., None], (B, H, T, N)),
        mode="mamba")
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref),
                               atol=tol, rtol=tol)
