"""Real-mode multi-turn conversations end to end (ISSUE 4 satellite).

Three-turn conversations through the real engine under storm preemption:
emitted tokens must be bit-exact with the reuse manager ON vs OFF (the
KV Cache Reuse Mechanism is a pure transfer optimization — it must never
change tokens), and on a clean run the d2h transfer accounting must
prove the reuse path swaps out ONLY the increment on later turns while
the disabled baseline re-writes whole contexts.
"""
from dataclasses import replace

import jax
import pytest

from repro.configs import get_smoke_config
from repro.core import EngineConfig, FastSwitchEngine
from repro.core.policies import POLICIES
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import Conversation, Turn
from repro.models import transformer as T

BS = 16
TURNS = [Turn(12, 6), Turn(10, 5), Turn(8, 4)]


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return {"cfg": cfg, "params": params}


def _mk_convs(n=3):
    return [Conversation(conv_id=i, arrival_s=0.0, turns=list(TURNS),
                         think_time_s=0.2) for i in range(n)]


def _run(model, *, use_reuse, storm, gpu_blocks=64):
    pol = replace(POLICIES["fastswitch"], initial_group_blocks=4)
    if not use_reuse:
        pol = replace(pol, name="fastswitch-noreuse", use_reuse=False,
                      prealloc_blocks=0)
    trace = PriorityTrace("random", 0.5, seed=13) if storm \
        else PriorityTrace()
    cfg = EngineConfig(mode="real", num_gpu_blocks=gpu_blocks,
                       num_cpu_blocks=256, max_running=4, max_batch=4,
                       block_size=BS, swap_chunk_blocks=1, policy=pol)
    eng = FastSwitchEngine(cfg, _mk_convs(), trace=trace,
                           model_bundle=model)
    eng.run(max_iterations=30_000)
    assert eng.done()
    return eng


@pytest.mark.slow
def test_multi_turn_storm_reuse_on_vs_off_bitexact(model):
    """>=3 turns per request under storm preemption: the reuse manager
    must be invisible in the emitted tokens (bit-exact on vs off), and
    both must match the schedule-independent pre-refactor replay."""
    from test_decode_consistency import _replay_prerefactor
    e_on = _run(model, use_reuse=True, storm=True, gpu_blocks=10)
    e_off = _run(model, use_reuse=False, storm=True, gpu_blocks=10)
    assert e_on.metrics.preemptions > 0, "schedule never preempted"
    assert e_off.metrics.preemptions > 0
    assert e_on._token_hist_by_conv == e_off._token_hist_by_conv, \
        "reuse manager changed emitted tokens"
    for cid, conv in enumerate(_mk_convs()):
        assert len(e_on._token_hist_by_conv[cid]) == \
            sum(t.prompt_tokens + t.response_tokens for t in TURNS)
        assert e_on._token_hist_by_conv[cid] == \
            _replay_prerefactor(model, conv, cid), \
            f"conv {cid} diverged from the pre-refactor replay"


def _expected_turn_blocks(incremental: bool):
    """d2h blocks a clean (preemption-free) run moves per conversation:
    one swap-out per turn boundary over ``context - 1`` tokens (the last
    slot's KV is produced by the next decode step).  The reuse path
    transfers only [valid_before, total) — re-touching at most the
    boundary partial block; the disabled baseline re-writes the whole
    context every turn."""
    total_blocks = 0
    ctx = 0
    valid = 0
    for t in TURNS:
        ctx += t.prompt_tokens + t.response_tokens
        total = ctx - 1
        b0 = (valid // BS) if incremental else 0
        total_blocks += -(-total // BS) - b0
        valid = total
    return total_blocks


def test_multi_turn_clean_run_swaps_increment_only(model):
    """ISSUE 4 satellite acceptance: on later turns the reuse path's d2h
    traffic is exactly the per-turn increment (plus the re-touched
    boundary block), while the disabled baseline re-writes every turn's
    whole context — proven from the swap manager's d2h block counter."""
    e_on = _run(model, use_reuse=True, storm=False)
    e_off = _run(model, use_reuse=False, storm=False)
    # the preemption counter includes turn-boundary retains (_finish_turn
    # swaps the KV copy out); a clean run has EXACTLY those and no more
    n_turn_ends = len(_mk_convs()) * len(TURNS)
    assert e_on.metrics.preemptions == n_turn_ends, "mid-turn preemption"
    assert e_off.metrics.preemptions == n_turn_ends
    assert e_on._token_hist_by_conv == e_off._token_hist_by_conv
    n = len(_mk_convs())
    assert e_on.swap.blocks_by_dir["out"] == n * _expected_turn_blocks(True)
    assert e_off.swap.blocks_by_dir["out"] == n * _expected_turn_blocks(False)
    assert e_on.swap.blocks_by_dir["out"] < e_off.swap.blocks_by_dir["out"]
