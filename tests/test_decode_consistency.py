"""Decode-path correctness: prefill(T) + decode k steps must match
prefill(T + k) logits for every architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import steps, transformer as T

# tolerance: decode recomputes in bf16 with different reduction orders
ATOL = 0.12


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_prefill(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    B, T0, K = 2, 24, 4
    tokens = jax.random.randint(key, (B, T0 + K), 0, cfg.vocab_size)
    extras = {}
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        extras["extra_embeds"] = 0.1 * jnp.ones(
            (B, cfg.frontend.n_tokens, cfg.frontend.d_embed), jnp.float32)
    if cfg.encoder_decoder:
        extras["encoder_frames"] = 0.1 * jnp.ones(
            (B, cfg.n_encoder_tokens, cfg.d_model), jnp.float32)

    # reference: full prefill over T0+K tokens
    ref_logits, _, _ = T.forward_seq(params, cfg, tokens, **{
        k: v for k, v in extras.items() if k == "extra_embeds"},
        encoder_frames=extras.get("encoder_frames"))
    n_img = (cfg.frontend.n_tokens
             if cfg.frontend and cfg.frontend.kind == "vision" else 0)

    # prefill T0 then decode K steps
    logits0, raw = steps.prefill(params, cfg, tokens[:, :T0],
                                 extra_embeds=extras.get("extra_embeds"),
                                 encoder_frames=extras.get("encoder_frames"))
    caches = steps.caches_from_prefill(cfg, raw, B, T0 + K + n_img + 8)
    np.testing.assert_allclose(
        np.asarray(logits0, np.float32),
        np.asarray(ref_logits[:, n_img + T0 - 1], np.float32), atol=ATOL,
        err_msg="prefill last-token logits mismatch")

    for i in range(K):
        pos = n_img + T0 + i
        _, logits, caches = steps.serve_step(
            params, caches, tokens[:, T0 + i], jnp.int32(pos), cfg=cfg)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(ref_logits[:, n_img + T0 + i], np.float32),
            atol=ATOL, err_msg=f"{arch}: decode step {i} diverged")


# ---------------------------------------------------------------------------
# device-side sampling (ISSUE 2): fused temperature/top-k/top-p
# ---------------------------------------------------------------------------


def _fixed_logits(B, V, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randn(B, V), jnp.float32)


def _keys(B, seed=1):
    return jnp.asarray(
        np.random.RandomState(seed).randint(0, 2 ** 31, (B, 2)), jnp.uint32)


def _ctx(B, val=0):
    return jnp.full((B,), val, jnp.int32)


def _smp(B, temp, k, p):
    """Per-row (B, 3) sampling array, every row identical."""
    return jnp.tile(jnp.asarray([temp, float(k), p], jnp.float32), (B, 1))


def test_sample_tokens_greedy_paths_are_argmax():
    """temperature 0, top_k 1 and a vanishing nucleus all collapse to the
    bit-exact argmax — through the SAME code path as sampled runs."""
    from repro.models.paged import sample_tokens
    logits, keys = _fixed_logits(8, 32), _keys(8)
    ref = np.argmax(np.asarray(logits), -1)
    for temp, k, p in ((0.0, 0, 1.0), (1.0, 1, 1.0), (1.0, 0, 1e-6)):
        toks = sample_tokens(logits, keys, _ctx(8), _smp(8, temp, k, p))
        np.testing.assert_array_equal(np.asarray(toks), ref, err_msg=str((temp, k, p)))


def test_sample_tokens_pure_function_of_key_and_position():
    """The draw is stateless: same (key, position) always yields the same
    token (reproducible under any preemption/re-registration order),
    different positions draw fresh randomness."""
    from repro.models.paged import sample_tokens
    logits, keys = _fixed_logits(16, 64), _keys(16)
    smp = _smp(16, 1.0, 0, 1.0)
    t1 = sample_tokens(logits, keys, _ctx(16, 5), smp)
    t2 = sample_tokens(logits, keys, _ctx(16, 5), smp)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    t3 = sample_tokens(logits, keys, _ctx(16, 6), smp)
    assert not np.array_equal(np.asarray(t3), np.asarray(t1))


def test_sample_tokens_statistics_match_softmax():
    """Unfiltered temperature-1 sampling reproduces the softmax
    distribution (B independent rows of the same logits = B draws)."""
    from repro.models.paged import sample_tokens
    V, B = 8, 4000
    row = np.random.RandomState(3).randn(V).astype(np.float32)
    logits = jnp.asarray(np.tile(row, (B, 1)))
    toks = sample_tokens(logits, _keys(B, seed=5), _ctx(B),
                         _smp(B, 1.0, 0, 1.0))
    freq = np.bincount(np.asarray(toks), minlength=V) / B
    probs = np.exp(row - row.max())
    probs /= probs.sum()
    np.testing.assert_allclose(freq, probs, atol=0.035)


def test_sample_tokens_top_k_top_p_restrict_support():
    from repro.models.paged import sample_tokens
    V, B = 16, 800
    row = np.random.RandomState(4).randn(V).astype(np.float32)
    logits = jnp.asarray(np.tile(row, (B, 1)))
    # top-k=3: only the 3 largest logits may ever be sampled
    toks = sample_tokens(logits, _keys(B, seed=6), _ctx(B),
                         _smp(B, 1.0, 3, 1.0))
    top3 = set(np.argsort(row)[-3:].tolist())
    assert set(np.asarray(toks).tolist()) <= top3
    # top-p: support limited to the smallest prefix reaching the mass
    probs = np.exp(row - row.max())
    probs /= probs.sum()
    order = np.argsort(-row)
    cum = np.cumsum(probs[order])
    p = 0.5
    nucleus = set(order[:int(np.searchsorted(cum, p) + 1)].tolist())
    toks = sample_tokens(logits, _keys(B, seed=7), _ctx(B),
                         _smp(B, 1.0, 0, p))
    assert set(np.asarray(toks).tolist()) <= nucleus


def test_sample_tokens_per_row_mixed_batch_keeps_greedy_rows_exact():
    """ISSUE 8 satellite: rows with different sampling params coexist in
    ONE batch (one compiled variant) and the greedy rows stay bit-exact
    to a pure-greedy call — sampled neighbours must not perturb them."""
    from repro.models.paged import sample_tokens
    B, V = 8, 32
    logits, keys = _fixed_logits(B, V, seed=9), _keys(B, seed=10)
    rows = np.zeros((B, 3), np.float32)
    rows[:, 2] = 1.0                       # all greedy: (0, 0, 1)
    rows[1] = (0.8, 5, 0.9)                # two sampled rows mixed in
    rows[6] = (1.2, 0, 0.7)
    mixed = sample_tokens(logits, keys, _ctx(B, 3), jnp.asarray(rows))
    pure = sample_tokens(logits, keys, _ctx(B, 3), _smp(B, 0.0, 0, 1.0))
    got, ref = np.asarray(mixed), np.asarray(pure)
    greedy_rows = [i for i in range(B) if i not in (1, 6)]
    np.testing.assert_array_equal(got[greedy_rows], ref[greedy_rows])
    assert (got[[1, 6]] < V).all() and (got[[1, 6]] >= 0).all()


# ---------------------------------------------------------------------------
# engine-level greedy parity: the runner-managed prefill + fused-sampling
# pipeline must be bit-identical to the pre-refactor data plane
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_model():
    from repro.configs import get_smoke_config as smoke
    cfg = smoke("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return {"cfg": cfg, "params": params}


def _mk_convs():
    from repro.data.sharegpt import Conversation, Turn
    return [Conversation(conv_id=i, arrival_s=0.05 * i,
                         turns=[Turn(10, 6), Turn(8, 5)], think_time_s=0.3)
            for i in range(3)]


def _run_real_engine(model, temperature=0.0, top_k=0, top_p=1.0, seed=0):
    from repro.core import EngineConfig, FastSwitchEngine
    from repro.data.priority import PriorityTrace
    cfg = EngineConfig(mode="real", num_gpu_blocks=64, num_cpu_blocks=256,
                       max_running=4, max_batch=4, block_size=16,
                       temperature=temperature, top_k=top_k, top_p=top_p,
                       seed=seed).with_policy("fastswitch")
    eng = FastSwitchEngine(cfg, _mk_convs(),
                           trace=PriorityTrace("markov", 0.04, seed=7),
                           model_bundle=model)
    eng.run(max_iterations=20_000)
    assert eng.done()
    return eng


def _replay_prerefactor(engine_model, conv, cid):
    """Straight-line replay of one conversation through the PRE-REFACTOR
    data plane (host-side ``PagedPools.write_tokens`` prefill + argmax
    ``paged_decode_step``) — schedule-independent greedy reference."""
    from repro.cache.paged import PagedPools, PoolSpec
    from repro.models.paged import paged_decode_step, prefill_kv
    cfg, params = engine_model["cfg"], engine_model["params"]
    bs = 16
    pools = PagedPools(PoolSpec.from_config(cfg, 64, 64, bs))
    hist = []
    for tix, turn in enumerate(conv.turns):
        rng = np.random.RandomState((cid * 1009 + tix) % (2 ** 31))
        hist.extend(rng.randint(1, cfg.vocab_size,
                                size=turn.prompt_tokens).tolist())
        logits, k, v = prefill_kv(
            params, jnp.asarray([hist], jnp.int32), cfg=cfg)
        nblk = (len(hist) + bs - 1) // bs
        pools.write_tokens(list(range(nblk)), 0,
                           np.asarray(k), np.asarray(v))
        hist.append(int(np.argmax(np.asarray(logits))))
        for _ in range(turn.response_tokens - 1):
            ctx = len(hist) - 1
            bt = jnp.asarray([list(range(ctx // bs + 1))], jnp.int32)
            nxt, _, pools.gpu = paged_decode_step(
                params, pools.gpu, bt, jnp.asarray([ctx], jnp.int32),
                jnp.asarray([hist[-1]], jnp.int32), cfg=cfg)
            hist.append(int(nxt[0]))
    return hist


def test_engine_real_greedy_parity_with_prerefactor_path(engine_model):
    """Greedy real-mode engine run vs the pre-refactor straight-line
    replay: token histories must be bit-identical per conversation."""
    eng = _run_real_engine(engine_model)
    for cid, conv in enumerate(_mk_convs()):
        got = eng._token_hist_by_conv[cid]
        assert got == _replay_prerefactor(engine_model, conv, cid), \
            f"conv {cid} diverged from pre-refactor replay"


@pytest.mark.slow
def test_engine_real_greedy_parity_under_preemption_swap(engine_model):
    """ISSUE 3 acceptance: the same parity must hold under a schedule
    full of preemptions and staged (chunked) swaps — a tiny pool and
    violent priority churn force swap-out -> conflict -> swap-in round
    trips through the run-coalesced donated data plane, and greedy decode
    output must STILL be bit-identical to the pre-refactor replay."""
    from repro.core import EngineConfig, FastSwitchEngine
    from repro.data.priority import PriorityTrace
    from repro.data.sharegpt import Conversation, Turn

    def mk():
        return [Conversation(conv_id=i, arrival_s=0.0,
                             turns=[Turn(16, 12), Turn(8, 8)],
                             think_time_s=0.2) for i in range(4)]

    cfg = EngineConfig(mode="real", num_gpu_blocks=8, num_cpu_blocks=256,
                       max_running=4, max_batch=4, block_size=16,
                       swap_chunk_blocks=1).with_policy("fastswitch")
    eng = FastSwitchEngine(cfg, mk(),
                           trace=PriorityTrace("random", 0.5, seed=13),
                           model_bundle=engine_model)
    eng.run(max_iterations=20_000)
    assert eng.done()
    assert eng.metrics.preemptions > 0, "schedule never preempted"
    assert eng.metrics.swap_in_count > 0, "schedule never swapped in"
    for cid, conv in enumerate(mk()):
        got = eng._token_hist_by_conv[cid]
        assert got == _replay_prerefactor(engine_model, conv, cid), \
            f"conv {cid} diverged under the preemption+swap schedule"


@pytest.mark.slow
def test_engine_real_greedy_parity_chunked_prefill(engine_model):
    """ISSUE 4 acceptance: real-mode CHUNKED prefill (pow2-bucketed
    position-masked chunks interleaved with decode iterations,
    DESIGN.md §5) stays bit-identical to the monolithic pre-refactor
    replay — including under storm preemption that aborts prefills
    mid-chunk and re-admits them through the reuse path."""
    from dataclasses import replace
    from repro.core import EngineConfig, FastSwitchEngine
    from repro.core.policies import POLICIES
    from repro.data.priority import PriorityTrace
    from repro.data.sharegpt import Conversation, Turn

    def mk():
        return [Conversation(conv_id=i, arrival_s=0.0,
                             turns=[Turn(40, 6), Turn(30, 6)],
                             think_time_s=0.2) for i in range(4)]

    pol = replace(POLICIES["fastswitch"], chunked_prefill_tokens=16)
    cfg = EngineConfig(mode="real", num_gpu_blocks=16, num_cpu_blocks=256,
                       max_running=4, max_batch=4, block_size=16,
                       swap_chunk_blocks=1, policy=pol)
    eng = FastSwitchEngine(cfg, mk(),
                           trace=PriorityTrace("random", 0.5, seed=13),
                           model_bundle=engine_model)
    eng.run(max_iterations=20_000)
    assert eng.done()
    st = eng.runner.stats
    assert st.prefill_chunks > st.prefills, "prefills never actually chunked"
    assert st.prefill_aborts > 0, "storm never aborted a prefill mid-chunk"
    for cid, conv in enumerate(mk()):
        assert eng._token_hist_by_conv[cid] == \
            _replay_prerefactor(engine_model, conv, cid), \
            f"conv {cid} diverged under chunked prefill + storm preemption"


def test_engine_real_sampling_deterministic_under_seed(engine_model):
    """Sampled real-mode runs are reproducible under a fixed seed (the
    per-row device PRNG folds from (seed, rid, ctx)) and actually sample
    (token streams differ from greedy)."""
    e1 = _run_real_engine(engine_model, temperature=0.8, top_p=0.9, seed=3)
    e2 = _run_real_engine(engine_model, temperature=0.8, top_p=0.9, seed=3)
    assert e1._token_hist_by_conv == e2._token_hist_by_conv
    greedy = _run_real_engine(engine_model)
    assert e1._token_hist_by_conv != greedy._token_hist_by_conv
    assert e1.metrics.total_tokens == greedy.metrics.total_tokens


def test_int8_kv_cache_decode_close():
    """kv-int8 §Perf variant: quantized-cache decode stays close to bf16."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("llama3.2-3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tok = jnp.array([1, 2], jnp.int32)
    c_bf = T.init_caches(cfg, 2, 32)
    c_i8 = T.init_caches(cfg, 2, 32, kv_dtype=jnp.int8)
    assert c_i8.k.dtype == jnp.int8
    for i in range(5):
        _, l1, c_bf = steps.serve_step(params, c_bf, tok, jnp.int32(i), cfg=cfg)
        _, l2, c_i8 = steps.serve_step(params, c_i8, tok, jnp.int32(i), cfg=cfg)
        err = float(jnp.max(jnp.abs(l1.astype(jnp.float32)
                                    - l2.astype(jnp.float32))))
        assert err < 0.25, f"step {i}: int8 cache drifted {err}"
        tok = jnp.argmax(l1, -1).astype(jnp.int32)
