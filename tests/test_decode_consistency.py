"""Decode-path correctness: prefill(T) + decode k steps must match
prefill(T + k) logits for every architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import steps, transformer as T

# tolerance: decode recomputes in bf16 with different reduction orders
ATOL = 0.12


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_prefill(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    B, T0, K = 2, 24, 4
    tokens = jax.random.randint(key, (B, T0 + K), 0, cfg.vocab_size)
    extras = {}
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        extras["extra_embeds"] = 0.1 * jnp.ones(
            (B, cfg.frontend.n_tokens, cfg.frontend.d_embed), jnp.float32)
    if cfg.encoder_decoder:
        extras["encoder_frames"] = 0.1 * jnp.ones(
            (B, cfg.n_encoder_tokens, cfg.d_model), jnp.float32)

    # reference: full prefill over T0+K tokens
    ref_logits, _, _ = T.forward_seq(params, cfg, tokens, **{
        k: v for k, v in extras.items() if k == "extra_embeds"},
        encoder_frames=extras.get("encoder_frames"))
    n_img = (cfg.frontend.n_tokens
             if cfg.frontend and cfg.frontend.kind == "vision" else 0)

    # prefill T0 then decode K steps
    logits0, raw = steps.prefill(params, cfg, tokens[:, :T0],
                                 extra_embeds=extras.get("extra_embeds"),
                                 encoder_frames=extras.get("encoder_frames"))
    caches = steps.caches_from_prefill(cfg, raw, B, T0 + K + n_img + 8)
    np.testing.assert_allclose(
        np.asarray(logits0, np.float32),
        np.asarray(ref_logits[:, n_img + T0 - 1], np.float32), atol=ATOL,
        err_msg="prefill last-token logits mismatch")

    for i in range(K):
        pos = n_img + T0 + i
        _, logits, caches = steps.serve_step(
            params, caches, tokens[:, T0 + i], jnp.int32(pos), cfg=cfg)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(ref_logits[:, n_img + T0 + i], np.float32),
            atol=ATOL, err_msg=f"{arch}: decode step {i} diverged")


def test_int8_kv_cache_decode_close():
    """kv-int8 §Perf variant: quantized-cache decode stays close to bf16."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("llama3.2-3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tok = jnp.array([1, 2], jnp.int32)
    c_bf = T.init_caches(cfg, 2, 32)
    c_i8 = T.init_caches(cfg, 2, 32, kv_dtype=jnp.int8)
    assert c_i8.k.dtype == jnp.int8
    for i in range(5):
        _, l1, c_bf = steps.serve_step(params, c_bf, tok, jnp.int32(i), cfg=cfg)
        _, l2, c_i8 = steps.serve_step(params, c_i8, tok, jnp.int32(i), cfg=cfg)
        err = float(jnp.max(jnp.abs(l1.astype(jnp.float32)
                                    - l2.astype(jnp.float32))))
        assert err < 0.25, f"step {i}: int8 cache drifted {err}"
        tok = jnp.argmax(l1, -1).astype(jnp.int32)
