"""Failure containment under deterministic fault injection (DESIGN.md §7).

Three layers:
  * deterministic unit matrix — one scenario per ladder rung: transient
    copy failures absorbed by inline retries, permanent swap-out/in
    failures degrading to recompute resumes, fatal failures faulting the
    one owning request, stuck copies rescued by the watchdog, poison
    requests contained, overload reject/shed, drain mode, injected
    allocation pressure, and the invariant sanitizer catching planted
    corruption;
  * a real-mode containment check — a poisoned request faults while the
    survivor's token history stays bit-exact vs a fault-free run;
  * a hypothesis property — random seeded FaultPlans across policies:
    ``step()`` never raises, every request ends terminally, zero
    block/swap-task leaks, survivors complete their full budget, and the
    sanitizer (on every step) never trips.
"""
import numpy as np
import pytest

from repro.core import (EngineConfig, EngineDrainingError,
                        EngineOverloadError, FaultInjector, FaultPlan,
                        InvariantViolation, SamplingParams, ServingEngine,
                        SLOSpec, check_engine_invariants)
from repro.core.faults import PermanentSwapFault, TransientSwapFault
from repro.core.scheduler import ReqState
from repro.data.priority import PriorityTrace

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                 # pragma: no cover
    HAVE_HYPOTHESIS = False


def _engine(policy="fastswitch", **kw):
    trace = kw.pop("trace", None) or PriorityTrace("random", 1e-9, seed=0)
    defaults = dict(mode="sim", num_gpu_blocks=64, num_cpu_blocks=256,
                    block_size=16, max_running=8)
    defaults.update(kw)
    return ServingEngine(EngineConfig(**defaults).with_policy(policy),
                         trace=trace)


def _drain(eng, max_iters=4000):
    outs = []
    it = 0
    while eng.has_work() and it < max_iters:
        outs += eng.step()
        it += 1
    assert it < max_iters, "engine failed to drain"
    return outs


def _assert_fully_reclaimed(eng):
    eng.clock.advance(1e9)
    eng.swap.synchronize(eng.clock, list(eng.swap.ongoing_swap_in)
                         + list(eng.swap.ongoing_swap_out))
    eng.swap.poll_completed(eng.clock)
    assert eng.gpu_mgr.free_blocks() == eng.gpu_mgr.num_blocks, \
        "leaked GPU blocks"
    assert eng.reuse.mgr.free_blocks() == eng.reuse.mgr.num_blocks, \
        "leaked CPU blocks"
    assert not eng.swap.ongoing_swap_in and not eng.swap.ongoing_swap_out, \
        "stranded swap task"
    # copies can fail on worker threads AFTER the engine's last step (a
    # finished request's final parking swap-out, e.g.) — those are
    # benign, but a failed task for a LIVE request means the recovery
    # ladder missed it
    for t in eng.swap.take_failed():
        assert t.req_id not in eng.sched.requests, \
            f"unprocessed failed swap task for live rid {t.req_id}"
    eng.gpu_mgr.check_invariants()
    eng.reuse.mgr.check_invariants()


# ---------------------------------------------------------------------------
# injector determinism
# ---------------------------------------------------------------------------


def test_fault_injector_is_deterministic():
    """Same plan -> bit-identical draw sequence at every site, across
    injector instances (chaos schedules must replay exactly)."""
    plan = FaultPlan.chaos(seed=42, intensity=2.0)
    a, b = FaultInjector(plan), FaultInjector(plan)
    for rid in range(20):
        for direction in ("in", "out"):
            for seq in range(5):
                sa = a.swap_fault(rid, direction, seq)
                sb = b.swap_fault(rid, direction, seq)
                assert (sa is None) == (sb is None)
                if sa is not None:
                    assert (sa.kind, sa.failures, sa.stall_us) == \
                        (sb.kind, sb.failures, sb.stall_us)
        assert a.poisoned(rid) == b.poisoned(rid)
    other = FaultInjector(FaultPlan.chaos(seed=43, intensity=2.0))
    draws_a = [(a.swap_fault(r, "out", 9) or None) and 1 for r in range(50)]
    draws_o = [(other.swap_fault(r, "out", 9) or None) and 1
               for r in range(50)]
    assert draws_a != draws_o, "different seeds produced identical draws"


def test_wrap_copy_transient_then_success():
    from repro.core.faults import SwapFaultSpec
    calls = []
    fn = FaultInjector.wrap_copy(SwapFaultSpec("transient", 2, 0.0),
                                 lambda: calls.append(1))
    with pytest.raises(TransientSwapFault):
        fn()
    with pytest.raises(TransientSwapFault):
        fn()
    fn()                                     # third attempt succeeds
    assert calls == [1]
    always = FaultInjector.wrap_copy(SwapFaultSpec("permanent", 1, 0.0),
                                     lambda: calls.append(2))
    for _ in range(3):
        with pytest.raises(PermanentSwapFault):
            always()
    assert calls == [1]


# ---------------------------------------------------------------------------
# degradation ladder, rung by rung (sim)
# ---------------------------------------------------------------------------


def test_transient_copy_failures_absorbed_by_retries():
    """Rung 1: every copy fails once, inline retries absorb it — no
    request faults, no recompute resumes, backoff charged to the task."""
    eng = _engine(fault_plan=FaultPlan(seed=0, p_swap_transient=1.0),
                  check_invariants_every=1)
    h = eng.add_request(40, SamplingParams(max_tokens=30))
    eng.step()
    eng._preempt(h)
    outs = _drain(eng)
    assert eng.swap.n_retries > 0
    assert eng.swap.n_copy_failures == 0
    assert eng.metrics.faulted == 0 and eng.metrics.swap_failure_resumes == 0
    fin = [o for o in outs if o.handle == h and o.finished]
    assert fin[-1].finish_reason == "length"
    assert any(e.kind == "retry" for e in eng.events)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_permanent_swap_failure_degrades_to_recompute_resume():
    """Rung 3: the preempt's d2h increment fails terminally -> the CPU
    copy is voided and the SWAPPED request converts to a recompute-mode
    resume; it still completes its full token budget."""
    eng = _engine(fault_plan=FaultPlan(seed=0, p_swap_permanent=1.0),
                  check_invariants_every=1)
    h = eng.add_request(40, SamplingParams(max_tokens=30))
    eng.step()
    eng._preempt(h)
    assert eng._req(h).state is ReqState.SWAPPED
    outs = _drain(eng)
    assert eng.metrics.swap_failure_resumes >= 1
    assert eng.metrics.faulted == 0
    assert eng.reuse.valid_tokens(h) == 0 or h not in eng.reuse.copies
    fin = [o for o in outs if o.handle == h and o.finished]
    assert fin[-1].finish_reason == "length"
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_fatal_swap_failure_faults_only_the_owner():
    """Rung 4: a fatal copy failure ends the owning request with
    ``finish_reason="error"`` — the other request is untouched."""
    eng = _engine(fault_plan=FaultPlan(seed=0, p_swap_fatal=1.0),
                  check_invariants_every=1)
    h = eng.add_request(40, SamplingParams(max_tokens=30))
    h2 = eng.add_request(24, SamplingParams(max_tokens=10))
    eng.step()
    eng._preempt(h)
    outs = _drain(eng)
    by = {o.handle: o for o in outs if o.finished}
    assert by[h].finish_reason == "error"
    assert "Fatal" in by[h].error
    assert by[h2].finish_reason == "length" and by[h2].generated == 10
    assert eng.metrics.faulted == 1
    ev = [e for e in eng.events if e.handle == h and e.kind == "error"]
    assert len(ev) == 1
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_stalled_async_copy_rescued_by_watchdog():
    """Rung 2: an injected stall parks the completion signal far in the
    future; the watchdog forces the data plane synchronously and clamps
    the signal, so the request still promotes promptly."""
    eng = _engine(fault_plan=FaultPlan(seed=0, p_swap_stall=1.0,
                                       stall_us=5_000_000.0),
                  swap_watchdog_us=60_000.0, check_invariants_every=1)
    eng.swap.adaptive = False               # force async dispatch
    h = eng.add_request(40, SamplingParams(max_tokens=30))
    # a second request keeps the engine decoding (and its clock moving
    # in iteration-sized increments) while h's copies sit stalled
    eng.add_request(24, SamplingParams(max_tokens=200))
    eng.step()
    eng._preempt(h)
    outs = _drain(eng)
    assert eng.swap.n_watchdog > 0
    assert eng.metrics.faulted == 0
    fin = [o for o in outs if o.handle == h and o.finished]
    assert fin[-1].finish_reason == "length"
    assert any(e.kind == "retry" and e.data.get("watchdog")
               for e in eng.events)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_poison_request_contained():
    """A poisoned request faults at its first-token hook; the other
    requests are unaffected and the pool fully reclaims."""
    eng = _engine(fault_plan=FaultPlan(seed=0, p_poison=1.0),
                  check_invariants_every=1)
    h = eng.add_request(16, SamplingParams(max_tokens=8))
    outs = _drain(eng)
    fin = [o for o in outs if o.handle == h and o.finished]
    assert fin[-1].finish_reason == "error"
    assert "poison" in fin[-1].error
    assert eng.faults.fired["poison"] >= 1
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_alloc_pressure_spike_reserves_and_releases():
    plan = FaultPlan(seed=0, alloc_spikes=((0, 10_000, 6),))
    eng = _engine(fault_plan=plan, check_invariants_every=1)
    h = eng.add_request(16, SamplingParams(max_tokens=40))
    eng.step()
    assert eng._pressure_blocks == 6
    assert eng.gpu_mgr.free_blocks() <= eng.gpu_mgr.num_blocks - 6
    check_engine_invariants(eng)     # phantom rid must not trip B2
    _drain(eng)
    assert eng._pressure_blocks == 0
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_chaos_preset_fires_multiple_fault_kinds():
    """Acceptance: a contentious run under the chaos preset injects at
    least three distinct fault kinds and still drains clean."""
    eng = _engine(num_gpu_blocks=32, num_cpu_blocks=96, max_running=4,
                  trace=PriorityTrace("random", 2e-5, seed=1),
                  fault_plan=FaultPlan(seed=11, p_swap_transient=0.3,
                                       p_swap_permanent=0.25,
                                       p_swap_fatal=0.1, p_swap_stall=0.3,
                                       p_poison=0.1,
                                       alloc_spikes=((5, 40, 8),)),
                  check_invariants_every=1, swap_watchdog_us=80_000.0)
    hs = [eng.add_request(50 + 17 * i, SamplingParams(max_tokens=16))
          for i in range(10)]
    outs = _drain(eng)
    kinds = {k for k, n in eng.faults.fired.items() if n > 0}
    assert len(kinds) >= 3, f"only fired {kinds}"
    by = {o.handle: o for o in outs if o.finished}
    assert set(by) == set(hs), "a request vanished without a terminal"
    for o in by.values():
        assert o.finish_reason in ("length", "error")
    _assert_fully_reclaimed(eng)
    eng.shutdown()


# ---------------------------------------------------------------------------
# overload protection / drain
# ---------------------------------------------------------------------------


def test_overload_reject_bounds_waiting_queue():
    eng = _engine(max_waiting=2, overload_policy="reject")
    eng.add_request(16, SamplingParams(max_tokens=4))
    eng.add_request(16, SamplingParams(max_tokens=4))
    with pytest.raises(EngineOverloadError) as ei:
        eng.add_request(16, SamplingParams(max_tokens=4))
    assert ei.value.queue_depth == 2 and ei.value.max_waiting == 2
    assert ei.value.predicted_ttft_us > 0
    assert eng.metrics.rejected == 1
    _drain(eng)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_overload_shed_evicts_least_valuable_waiting():
    """With policy "shed" the new request is admitted and the least
    valuable WAITING one (doomed-SLO first, then lowest priority, then
    newest) is terminated with ``finish_reason="shed"``."""
    eng = _engine(max_waiting=2, overload_policy="shed")
    h1 = eng.add_request(16, SamplingParams(max_tokens=4))
    h2 = eng.add_request(16, SamplingParams(max_tokens=4))
    h3 = eng.add_request(16, SamplingParams(max_tokens=4))   # forces a shed
    assert len(eng.sched.waiting) == 2
    assert eng.metrics.shed == 1
    shed_ev = [e for e in eng.events if e.kind == "shed"]
    assert len(shed_ev) == 1 and shed_ev[0].handle in (h1, h2, h3)
    outs = _drain(eng)
    shed_out = [o for o in outs
                if o.finished and o.finish_reason == "shed"]
    assert len(shed_out) == 1 and shed_out[0].handle == shed_ev[0].handle
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_shed_prefers_requests_doomed_to_miss_slo():
    """A waiting request whose predicted TTFT already blows its deadline
    is shed before a viable same-priority one, regardless of age."""
    eng = _engine(max_waiting=2, overload_policy="shed")
    doomed = eng.add_request(16, SamplingParams(max_tokens=4),
                             slo=SLOSpec(ttft_ms=1e-6))   # already missed
    viable = eng.add_request(16, SamplingParams(max_tokens=4),
                             slo=SLOSpec(ttft_ms=1e9))
    eng.clock.advance(50_000.0)
    eng.add_request(16, SamplingParams(max_tokens=4))
    shed_ev = [e for e in eng.events if e.kind == "shed"]
    assert len(shed_ev) == 1 and shed_ev[0].handle == doomed
    assert viable in eng.sched.waiting
    _drain(eng)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_drain_mode_refuses_new_work_finishes_in_flight():
    eng = _engine()
    h = eng.add_request(16, SamplingParams(max_tokens=6), retain_kv=True)
    eng.step()
    eng.drain()
    assert eng.draining
    with pytest.raises(EngineDrainingError):
        eng.add_request(16, SamplingParams(max_tokens=4))
    outs = _drain(eng)
    fin = [o for o in outs if o.handle == h and o.finished]
    assert fin[-1].finish_reason == "length"
    with pytest.raises(EngineDrainingError):
        eng.continue_session(h, 8, SamplingParams(max_tokens=2))
    assert eng.metrics.rejected == 2
    assert any(e.kind == "drain" and e.handle < 0 for e in eng.events)
    eng.release_session(h)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


# ---------------------------------------------------------------------------
# invariant sanitizer
# ---------------------------------------------------------------------------


def test_sanitizer_passes_on_healthy_engine_every_step():
    eng = _engine(check_invariants_every=1)
    for i in range(4):
        eng.add_request(20 + 8 * i, SamplingParams(max_tokens=8))
    _drain(eng)                      # raises InvariantViolation if unsound
    assert eng.metrics.invariant_checks > 0
    eng.shutdown()


def test_sanitizer_detects_planted_corruption():
    eng = _engine()
    h = eng.add_request(16, SamplingParams(max_tokens=8))
    eng.step()
    check_engine_invariants(eng)              # healthy
    eng.sched.running.append(9999)            # Q1: ghost queue entry
    eng.gpu_mgr.allocate_tokens(8888, 16)     # B2: blocks for a dead rid
    eng.gpu_mgr.note_tokens(8888, 16)
    with pytest.raises(InvariantViolation) as ei:
        check_engine_invariants(eng)
    v = ei.value
    assert any(s.startswith("Q1") for s in v.violations)
    assert any(s.startswith("B2") for s in v.violations)
    assert v.state_dump["running"] == eng.sched.running
    # repair and confirm the sanitizer agrees
    eng.sched.running.remove(9999)
    eng.gpu_mgr.release_request(8888)
    check_engine_invariants(eng)
    eng.abort(h)
    eng.shutdown()


def test_sanitizer_exempts_phantom_pressure_rid():
    eng = _engine()
    eng.add_request(16, SamplingParams(max_tokens=8))
    eng.gpu_mgr.allocate_tokens(-7777, 32)
    eng.gpu_mgr.note_tokens(-7777, 32)
    check_engine_invariants(eng)              # negative rid: not a leak
    eng.gpu_mgr.release_request(-7777)
    eng.shutdown()


# ---------------------------------------------------------------------------
# real mode: containment keeps survivors bit-exact
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_model():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return {"cfg": cfg, "params": params}


def _real_engine(tiny_model, **kw):
    defaults = dict(mode="real", num_gpu_blocks=64, num_cpu_blocks=256,
                    block_size=16, max_running=4, max_batch=4)
    defaults.update(kw)
    return ServingEngine(
        EngineConfig(**defaults).with_policy("fastswitch"),
        trace=PriorityTrace("random", 1e-9, seed=0),
        model_bundle=tiny_model)


def _ids(n, vocab, seed=0):
    return np.random.RandomState(seed).randint(1, vocab, size=n).tolist()


def _real_histories(tiny_model, plan):
    vocab = tiny_model["cfg"].vocab_size
    eng = _real_engine(tiny_model, fault_plan=plan,
                       check_invariants_every=2)
    h1 = eng.add_request(_ids(12, vocab, 1), SamplingParams(max_tokens=10))
    h2 = eng.add_request(_ids(12, vocab, 2), SamplingParams(max_tokens=10))
    outs = _drain(eng, max_iters=400)
    by = {o.handle: o for o in outs if o.finished}
    hist = {h: list(eng._token_hist_by_conv.get(h, []))
            for h in (h1, h2)}
    _assert_fully_reclaimed(eng)
    eng.shutdown()
    return (h1, h2), by, hist


def test_real_poison_contained_survivor_bit_exact(tiny_model):
    """A poisoned request faults in the REAL prefill path; the
    survivor's sampled token ids are bit-exact vs a fault-free run."""
    (h1, h2), base_by, base_hist = _real_histories(tiny_model, None)
    assert base_by[h1].finish_reason == "length"

    # poison seeded to hit exactly one of the two handles (verified via
    # the injector itself — the draw is a pure function of seed+handle)
    plan = FaultPlan(seed=5, p_poison=0.5)
    inj = FaultInjector(plan)
    assert inj.poisoned(h1) != inj.poisoned(h2), \
        "pick a seed separating the two handles"
    (f1, f2), by, hist = _real_histories(tiny_model, plan)
    poisoned = f1 if inj.poisoned(f1) else f2
    survivor = f2 if poisoned == f1 else f1
    assert by[poisoned].finish_reason == "error"
    assert by[survivor].finish_reason == "length"
    assert hist[survivor] == base_hist[survivor], \
        "survivor token history diverged under containment"


def test_real_permanent_swap_fault_recompute_matches(tiny_model):
    """Real mode, permanent swap-out failure after a forced preempt: the
    request resumes by recomputation and, because sampling is a pure
    function of (seed, rid, position), reproduces the fault-free token
    history bit-exactly."""
    vocab = tiny_model["cfg"].vocab_size

    def run(plan, preempt_at=2):
        eng = _real_engine(tiny_model, fault_plan=plan,
                           check_invariants_every=2)
        h = eng.add_request(_ids(12, vocab, 3),
                            SamplingParams(max_tokens=12))
        for _ in range(preempt_at):
            eng.step()
        eng._preempt(h)
        outs = _drain(eng, max_iters=400)
        by = {o.handle: o for o in outs if o.finished}
        hist = list(eng._token_hist_by_conv.get(h, []))
        resumes = eng.metrics.swap_failure_resumes
        _assert_fully_reclaimed(eng)
        eng.shutdown()
        return by[h], hist, resumes

    base_out, base_hist, _ = run(None)
    out, hist, resumes = run(FaultPlan(seed=0, p_swap_permanent=1.0))
    assert out.finish_reason == "length"
    assert resumes >= 1
    assert hist == base_hist, "recompute resume diverged from baseline"


# ---------------------------------------------------------------------------
# hypothesis: random chaos schedules across policies
# ---------------------------------------------------------------------------


def _run_chaos_schedule(seed, policy, intensity, n_req, storm_freq):
    rng = np.random.RandomState(seed)
    prompts = [int(rng.randint(8, 90)) for _ in range(n_req)]
    budgets = [int(rng.randint(1, 24)) for _ in range(n_req)]

    def run(plan):
        eng = _engine(policy, num_gpu_blocks=24, num_cpu_blocks=96,
                      max_running=4,
                      trace=PriorityTrace("random", storm_freq, seed=seed),
                      fault_plan=plan, check_invariants_every=1,
                      swap_watchdog_us=80_000.0)
        hs = [eng.add_request(p, SamplingParams(max_tokens=b))
              for p, b in zip(prompts, budgets)]
        outs = _drain(eng)               # sanitizer runs EVERY step
        by = {o.handle: o for o in outs if o.finished}
        assert set(by) == set(hs), "request vanished without a terminal"
        _assert_fully_reclaimed(eng)
        eng.shutdown()
        return dict(zip(hs, budgets)), by

    budget_by, by = run(FaultPlan.chaos(seed=seed, intensity=intensity))
    for h, o in by.items():
        assert o.finish_reason in ("length", "error"), o.finish_reason
        if o.finish_reason == "length":
            # a surviving request is UNAFFECTED: full token budget served
            assert o.generated == budget_by[h], \
                f"survivor {h} served {o.generated}/{budget_by[h]}"


@pytest.mark.parametrize("seed,policy,intensity,storm", [
    (0, "fastswitch", 1.0, 0.4),
    (1, "fastswitch+chunked", 2.0, 0.4),
    (2, "vllm-recompute", 1.5, 0.4),
    (3, "vllm", 2.5, 1e-9),
])
def test_chaos_schedule_fixed_seeds(seed, policy, intensity, storm):
    """Deterministic instances of the chaos property (run even without
    hypothesis installed)."""
    _run_chaos_schedule(seed, policy, intensity, n_req=6,
                        storm_freq=storm)


if HAVE_HYPOTHESIS:
    def _property(seed, policy, intensity, n_req, storm):
        _run_chaos_schedule(seed, policy, intensity, n_req, storm)

    test_chaos_never_crashes_never_leaks = settings(
        max_examples=25, deadline=None)(given(
            seed=st.integers(0, 2 ** 20),
            policy=st.sampled_from(["fastswitch", "fastswitch+chunked",
                                    "vllm", "vllm-recompute"]),
            intensity=st.sampled_from([0.5, 1.0, 2.0, 4.0]),
            n_req=st.integers(2, 8),
            storm=st.sampled_from([1e-9, 0.4]),
        )(_property))
else:                                               # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_chaos_never_crashes_never_leaks():
        pass
