"""KV Cache Reuse Mechanism invariants (FastSwitch §3.3)."""
import pytest

pytest.importorskip("hypothesis",
                    reason="dev-only dep; see requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.reuse import KVCacheReuseManager


def test_increment_only_transfer():
    r = KVCacheReuseManager(1024, 16, enabled=True)
    r.update_priority(1, 0.5)
    inc, runs = r.record_swap_out(1, 500, requesting_priority=0.5)
    assert inc == 500                       # first swap-out: everything
    assert r.valid_tokens(1) == 500
    # swap in retains the copy
    assert r.record_swap_in(1) == 500
    assert r.valid_tokens(1) == 500
    # next turn grew the context: only the delta moves
    inc2, _ = r.record_swap_out(1, 800, requesting_priority=0.5)
    assert inc2 == 300
    assert r.valid_tokens(1) == 800


def test_disabled_baseline_always_full():
    r = KVCacheReuseManager(4096, 16, enabled=False)
    r.update_priority(1, 0.5)
    inc, _ = r.record_swap_out(1, 500)
    assert inc == 500
    inc, _ = r.record_swap_out(1, 800)
    assert inc == 800                       # baseline re-writes everything
    assert r.record_swap_in(1) == 0         # no reuse accounting


def test_contamination_only_hits_lower_priority():
    r = KVCacheReuseManager(64, 16, enabled=True, prealloc_blocks=0)
    r.update_priority(1, 0.9)               # high priority victim candidate
    r.record_swap_out(1, 64 * 16 - 256, requesting_priority=0.9)
    r.update_priority(2, 0.5)
    # lower-priority requester cannot contaminate the higher-priority copy
    before = r.valid_tokens(1)
    r.record_swap_out(2, 1024, requesting_priority=0.5)
    assert r.valid_tokens(1) == before


def test_contamination_shrinks_victim_prefix():
    r = KVCacheReuseManager(64, 16, enabled=True, prealloc_blocks=0)
    r.update_priority(1, 0.1)               # low priority
    r.record_swap_out(1, 60 * 16, requesting_priority=0.1)
    assert r.valid_tokens(1) == 960
    r.update_priority(2, 0.9)
    inc, _ = r.record_swap_out(2, 30 * 16, requesting_priority=0.9)
    assert inc == 480
    # the victim's copy shrank but never exceeds what is physically stored
    assert r.valid_tokens(1) < 960
    assert r.n_contaminations >= 1
    cap = r.mgr.request_tokens(1)
    assert r.valid_tokens(1) <= cap


@settings(max_examples=80, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(1, 900),
                          st.floats(0, 1)),
                min_size=1, max_size=30))
def test_valid_prefix_never_exceeds_stored(ops):
    """Property: valid_tokens(r) <= tokens physically allocated on CPU —
    a request can never reuse contaminated/unstored cache."""
    r = KVCacheReuseManager(128, 16, enabled=True, prealloc_blocks=2)
    for rid, tokens, prio in ops:
        r.update_priority(rid, prio)
        r.record_swap_out(rid, tokens, requesting_priority=prio)
        for other in list(r.copies):
            assert r.valid_tokens(other) <= r.mgr.request_tokens(other)
        r.mgr.check_invariants()


def test_release_frees_cpu_space():
    r = KVCacheReuseManager(64, 16, enabled=True, prealloc_blocks=0)
    r.update_priority(1, 0.5)
    r.record_swap_out(1, 500, requesting_priority=0.5)
    used = r.mgr.free_blocks()
    r.release(1)
    assert r.mgr.free_blocks() == 64
    assert r.valid_tokens(1) == 0
