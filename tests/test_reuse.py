"""KV Cache Reuse Mechanism invariants (FastSwitch §3.3).

Hypothesis is a dev-only dependency (requirements-dev.txt): when it is
absent only the property tests skip — the example-based regressions in
this file still run (they guard engine-behaviour fixes)."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:               # stub the decorators: defs still parse,
    class _NoStrategies:          # the property tests skip individually
        def __getattr__(self, name):
            return lambda *a, **k: None
    st = _NoStrategies()

    def given(*a, **k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed; see requirements-dev.txt")(fn)

    def settings(*a, **k):
        return lambda fn: fn

from repro.core.reuse import KVCacheReuseManager  # noqa: E402


def test_increment_only_transfer():
    r = KVCacheReuseManager(1024, 16, enabled=True)
    r.update_priority(1, 0.5)
    inc, runs = r.record_swap_out(1, 500, requesting_priority=0.5)
    assert inc == 500                       # first swap-out: everything
    assert r.valid_tokens(1) == 500
    # swap in retains the copy
    assert r.record_swap_in(1) == 500
    assert r.valid_tokens(1) == 500
    # next turn grew the context: only the delta moves
    inc2, _ = r.record_swap_out(1, 800, requesting_priority=0.5)
    assert inc2 == 300
    assert r.valid_tokens(1) == 800


def test_disabled_baseline_always_full():
    r = KVCacheReuseManager(4096, 16, enabled=False)
    r.update_priority(1, 0.5)
    inc, _ = r.record_swap_out(1, 500)
    assert inc == 500
    inc, _ = r.record_swap_out(1, 800)
    assert inc == 800                       # baseline re-writes everything
    assert r.record_swap_in(1) == 0         # no reuse accounting


def test_contamination_only_hits_lower_priority():
    r = KVCacheReuseManager(64, 16, enabled=True, prealloc_blocks=0)
    r.update_priority(1, 0.9)               # high priority victim candidate
    r.record_swap_out(1, 64 * 16 - 256, requesting_priority=0.9)
    r.update_priority(2, 0.5)
    # lower-priority requester cannot contaminate the higher-priority copy
    before = r.valid_tokens(1)
    r.record_swap_out(2, 1024, requesting_priority=0.5)
    assert r.valid_tokens(1) == before


def test_contamination_shrinks_victim_prefix():
    r = KVCacheReuseManager(64, 16, enabled=True, prealloc_blocks=0)
    r.update_priority(1, 0.1)               # low priority
    r.record_swap_out(1, 60 * 16, requesting_priority=0.1)
    assert r.valid_tokens(1) == 960
    r.update_priority(2, 0.9)
    inc, _ = r.record_swap_out(2, 30 * 16, requesting_priority=0.9)
    assert inc == 480
    # the victim's copy shrank but never exceeds what is physically stored
    assert r.valid_tokens(1) < 960
    assert r.n_contaminations >= 1
    cap = r.mgr.request_tokens(1)
    assert r.valid_tokens(1) <= cap


@settings(max_examples=80, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(1, 900),
                          st.floats(0, 1)),
                min_size=1, max_size=30))
def test_valid_prefix_never_exceeds_stored(ops):
    """Property: valid_tokens(r) <= tokens physically allocated on CPU —
    a request can never reuse contaminated/unstored cache."""
    r = KVCacheReuseManager(128, 16, enabled=True, prealloc_blocks=2)
    for rid, tokens, prio in ops:
        r.update_priority(rid, prio)
        r.record_swap_out(rid, tokens, requesting_priority=prio)
        for other in list(r.copies):
            assert r.valid_tokens(other) <= r.mgr.request_tokens(other)
        r.mgr.check_invariants()


def test_release_frees_cpu_space():
    r = KVCacheReuseManager(64, 16, enabled=True, prealloc_blocks=0)
    r.update_priority(1, 0.5)
    r.record_swap_out(1, 500, requesting_priority=0.5)
    used = r.mgr.free_blocks()
    r.release(1)
    assert r.mgr.free_blocks() == 64
    assert r.valid_tokens(1) == 0


def test_disabled_baseline_rewrites_in_place():
    """Regression for the ISSUE 4 dead-code removal in
    ``_ensure_cpu_tokens``: the disabled-baseline rewrite path re-writes
    the SAME CPU blocks every preemption — the allocation only grows
    with the context, it is never re-acquired (the old ``replace``
    branch recomputed the identical growth)."""
    r = KVCacheReuseManager(4096, 16, enabled=False, prealloc_blocks=0)
    r.update_priority(1, 0.5)
    inc, runs1 = r.record_swap_out(1, 500)
    assert inc == 500
    blocks1 = r.mgr.request_block_ids(1)
    # same-size rewrite: full re-transfer, IDENTICAL allocation
    inc, runs2 = r.record_swap_out(1, 500)
    assert inc == 500
    assert r.mgr.request_block_ids(1) == blocks1
    assert runs2 == runs1
    # growth: the old blocks stay in place, only the tail is appended
    inc, _ = r.record_swap_out(1, 800)
    assert inc == 800
    blocks3 = r.mgr.request_block_ids(1)
    assert blocks3[:len(blocks1)] == blocks1
    assert len(blocks3) == -(-800 // 16)


def test_contamination_victim_prefix_matches_capacity():
    """ISSUE 4 satellite invariant: after a contamination the victim's
    ``valid_tokens`` equals the uncontaminated prefix implied by its
    REMAINING CPU capacity minus its (now consumed) preallocation."""
    r = KVCacheReuseManager(64, 16, enabled=True, prealloc_blocks=2)
    r.update_priority(1, 0.1)
    r.record_swap_out(1, 40 * 16, requesting_priority=0.1)
    valid_before = r.valid_tokens(1)
    prealloc_before = r.copies[1].prealloc_tokens
    r.update_priority(2, 0.9)
    r.record_swap_out(2, 30 * 16, requesting_priority=0.9)
    assert r.n_contaminations >= 1
    cap_after = r.mgr.request_tokens(1)
    assert r.valid_tokens(1) == min(
        valid_before, max(0, cap_after - prealloc_before))
    assert r.copies[1].prealloc_tokens == 0


def test_contamination_refuses_equal_priority():
    """ISSUE 9 S1 regression: the victim guard used ``>`` — an
    EQUAL-priority victim could be contaminated, letting two peers
    ping-pong each other's prefixes away.  Only strictly-lower-priority
    copies may be reclaimed (paper §2.2)."""
    r = KVCacheReuseManager(64, 16, enabled=True, prealloc_blocks=0)
    r.update_priority(1, 0.5)
    r.record_swap_out(1, 64 * 16 - 256, requesting_priority=0.5)
    before = r.valid_tokens(1)
    r.update_priority(2, 0.5)
    r.record_swap_out(2, 1024, requesting_priority=0.5)
    assert r.valid_tokens(1) == before
    assert r.n_contaminations == 0


def test_contamination_falls_back_to_live_priority():
    """ISSUE 9 S1 regression: a victim never seen by ``update_priority``
    defaulted to priority 0.0 and became a preferential contamination
    victim regardless of its true priority.  With ``priority_fn`` wired
    (the engine points it at ``scheduler.priority``) the live priority
    protects it — and a genuinely higher-priority requester still wins."""
    r = KVCacheReuseManager(64, 16, enabled=True, prealloc_blocks=0)
    r.priority_fn = lambda rid: 0.9 if rid == 1 else 0.0
    # rid 1 swaps out WITHOUT any update_priority call
    r.record_swap_out(1, 64 * 16 - 256, requesting_priority=0.9)
    before = r.valid_tokens(1)
    r.update_priority(2, 0.5)
    r.record_swap_out(2, 1024, requesting_priority=0.5)
    assert r.valid_tokens(1) == before      # protected by the fallback
    assert r.n_contaminations == 0
    r.update_priority(3, 0.95)
    r.record_swap_out(3, 512, requesting_priority=0.95)
    assert r.valid_tokens(1) < before
    assert r.n_contaminations >= 1


def test_invalidate_resets_prealloc():
    """ISSUE 9 S3 regression (extends the PR 4 stale-prealloc tests to
    the invalidate path): ``invalidate`` zeroed valid/stored but left
    ``prealloc_tokens`` stale — nothing valid is stored, so nothing can
    be "reserved ahead" of it; the stale reserve made the next
    record_swap_out under-report and a later contamination over-shrink
    the victim's valid prefix."""
    r = KVCacheReuseManager(64, 16, enabled=True, prealloc_blocks=2)
    r.update_priority(1, 0.5)
    r.record_swap_out(1, 20 * 16, requesting_priority=0.5)
    assert r.copies[1].prealloc_tokens == 32
    r.invalidate(1)
    assert r.copies[1].valid_tokens == 0
    assert r.copies[1].stored_tokens == 0
    assert r.copies[1].prealloc_tokens == 0
    # re-swap-out after the failure: full re-transfer, coherent prealloc
    inc, _ = r.record_swap_out(1, 20 * 16, requesting_priority=0.5)
    assert inc == 20 * 16
    assert r.copies[1].prealloc_tokens == 32


def test_swap_out_floor_pins_shared_prefix():
    """Prefix-cache pinning (DESIGN.md §10.3): ``floor_tokens`` marks
    [0, floor) GPU-pinned — the copy is valid from 0 without any
    transfer, the increment covers only the private suffix, and the
    floor survives contamination of the phantom blocks below it."""
    r = KVCacheReuseManager(64, 16, enabled=True, prealloc_blocks=0)
    r.update_priority(1, 0.5)
    inc, _ = r.record_swap_out(1, 160, requesting_priority=0.5,
                               floor_tokens=48)
    assert inc == 160 - 48                  # only the private suffix moves
    assert r.valid_tokens(1) == 160
    # re-swap at the same context: nothing to transfer
    inc, _ = r.record_swap_out(1, 160, requesting_priority=0.5,
                               floor_tokens=48)
    assert inc == 0
    # a contamination can reclaim every CPU block — the floor keeps the
    # pinned prefix valid (its blocks are phantoms, never read)
    r.update_priority(2, 0.9)
    r.record_swap_out(2, 64 * 16, requesting_priority=0.9)
    assert r.valid_tokens(1) <= 160
    inc, _ = r.record_swap_out(1, 48, requesting_priority=0.5,
                               floor_tokens=48)
    assert inc == 0
    assert r.valid_tokens(1) >= 48


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(1, 900),
                          st.floats(0, 1)),
                min_size=1, max_size=30))
def test_contamination_property_valid_prefix_capacity(ops):
    """Property (ISSUE 4 satellite): under ANY interleaving of swap-outs
    the uncontaminated prefix is backed by physical capacity beyond the
    preallocation — ``valid <= stored <= capacity`` and
    ``valid + prealloc <= capacity`` for every live copy (a contaminated
    victim can never claim tokens its remaining blocks don't hold)."""
    r = KVCacheReuseManager(128, 16, enabled=True, prealloc_blocks=2)
    for rid, tokens, prio in ops:
        r.update_priority(rid, prio)
        r.record_swap_out(rid, tokens, requesting_priority=prio)
        for other, copy in r.copies.items():
            cap = r.mgr.request_tokens(other)
            assert copy.valid_tokens <= copy.stored_tokens <= cap
            assert copy.valid_tokens + copy.prealloc_tokens <= cap
        r.mgr.check_invariants()
