"""Network front-end (ISSUE 10): VTC fair admission, SLO->priority map,
session-affinity routing + migration, the event-log affinity audit, the
deterministic DirectCluster driver, and loopback driver-equivalence
against direct engine runs (bit-exact greedy token histories).
"""
import asyncio
import json
import random

import jax
import pytest

from repro.core import EngineConfig, SamplingParams, ServingEngine
from repro.core.request_api import SLOSpec
from repro.data.sharegpt import synth_prompt_ids
from repro.frontend.admission import (FairAdmissionQueue, QueueFullError,
                                      slo_priority)
from repro.frontend.router import Router, count_affinity_violations


@pytest.fixture(scope="module")
def engine_model():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return {"cfg": cfg, "params": params}


@pytest.fixture(scope="module", autouse=True)
def _release_jit_state():
    # this module compiles many real-engine variants; on jax-cpu the
    # accumulated global jit state can crash a LATER module's native
    # compile (the test_system segfault family) — hand the budget back
    yield
    jax.clear_caches()


# ---------------------------------------------------------------------------
# SLO -> scheduler priority (Equinox-style deadline mapping)
# ---------------------------------------------------------------------------


def test_slo_priority_monotone_in_deadline():
    tight = slo_priority(SLOSpec(ttft_ms=50.0, tbt_ms=40.0))
    mid = slo_priority(SLOSpec(ttft_ms=300.0, tbt_ms=90.0))
    loose = slo_priority(SLOSpec(ttft_ms=3000.0, tbt_ms=300.0))
    assert tight > mid > loose
    # no SLO: a low floor that yields to every deadline-carrying request
    floor = slo_priority(None)
    assert floor == slo_priority(SLOSpec(ttft_ms=None, tbt_ms=None))
    assert loose < 1.0 and tight <= 1.0
    assert 0.0 < floor < tight
    # TBT-only SLOs bind through the scaled deadline
    assert slo_priority(SLOSpec(ttft_ms=None, tbt_ms=40.0)) \
        > slo_priority(SLOSpec(ttft_ms=None, tbt_ms=400.0))


# ---------------------------------------------------------------------------
# VTC fair queue
# ---------------------------------------------------------------------------


def test_fair_queue_capacity_refusal():
    q = FairAdmissionQueue(capacity=2)
    q.push("a", 1)
    q.push("b", 2)
    with pytest.raises(QueueFullError) as ei:
        q.push("a", 3)
    assert ei.value.queue_depth == 2 and ei.value.capacity == 2
    assert q.depth() == 2


def test_fair_queue_requeue_front_uncharged():
    q = FairAdmissionQueue()
    q.push("a", "first")
    q.push("a", "second")
    c, item = q.pop()
    assert (c, item) == ("a", "first")
    q.requeue("a", item)                    # engine said "not now"
    assert q.norm_counter("a") == 0.0       # refusal bills nothing
    assert q.pop() == ("a", "first")        # keeps its queue position


def test_fair_queue_bounded_gap_and_no_starvation():
    """Seeded-random VTC property: with every client continuously
    backlogged and per-dispatch charges bounded by U tokens, any two
    clients' normalized counters stay within U/w_i + U/w_j, and no
    client starves — even with a whale whose dispatches charge the
    maximum while everyone else stays cheap."""
    rng = random.Random(0)
    U = 64
    weights = {"a": 1.0, "b": 2.0, "c": 1.0, "whale": 1.0}
    clients = sorted(weights)
    q = FairAdmissionQueue(weights=weights)
    for c in clients:
        q.push(c, 0)
    served = {c: 0 for c in clients}
    tokens_of = {c: U if c == "whale" else rng.randint(4, 12)
                 for c in clients}
    for _ in range(600):
        client, _ = q.pop()
        q.charge(client, tokens_of[client])
        q.done(client)
        served[client] += 1
        q.push(client, 0)                   # stays backlogged
        for i, ci in enumerate(clients):
            for cj in clients[i + 1:]:
                gap = abs(q.norm_counter(ci) - q.norm_counter(cj))
                bound = U / weights[ci] + U / weights[cj]
                assert gap <= bound, (ci, cj, gap, bound)
    assert all(served[c] > 0 for c in clients)
    # token-fair, not dispatch-fair: the whale gets far fewer turns...
    assert served["whale"] < served["a"] / 2
    # ...and the weight-2 client roughly twice client a's service
    assert served["b"] > served["a"]


def test_fair_queue_activation_lift_banks_no_credit():
    """A client that idles while others are served re-enters at the
    active minimum — sleeping earns no priority."""
    q = FairAdmissionQueue()
    q.push("busy", 0)
    c, _ = q.pop()
    q.charge(c, 1000)
    q.push("busy", 0)                       # keep busy active
    q.done(c)
    q.push("sleeper", 0)                    # first appearance, lanes busy
    assert q.norm_counter("sleeper") >= 1000.0
    # a sleeper lifted to the min does NOT monopolize the next dispatches
    got = {q.pop()[0], q.pop()[0]}
    assert got == {"busy", "sleeper"}


def test_fair_queue_property_randomized_interleavings():
    """Push/pop/requeue interleavings keep the bookkeeping coherent:
    depth matches, pop always picks the lowest normalized counter among
    backlogged clients, counters never decrease.  (Runs under
    hypothesis when available; seeded-random otherwise — the container
    does not ship hypothesis.)"""

    def check(ops):
        q = FairAdmissionQueue()
        clients = ["x", "y", "z"]
        pushed = popped = 0
        prev = {c: 0.0 for c in clients}
        for kind, val in ops:
            c = clients[val % 3]
            if kind == 0:
                q.push(c, pushed)
                pushed += 1
            elif kind == 1:
                got = q.pop()
                if got is None:
                    assert q.depth() == 0
                    continue
                gc, _ = got
                popped += 1
                norms = {cc: q.norm_counter(cc)
                         for cc in clients if cc in q.backlogged()}
                assert all(q.norm_counter(gc) <= v + 1e-9
                           for v in norms.values())
                q.charge(gc, 1 + val)
                q.done(gc)
            else:
                q.charge(c, val)
            for cc in clients:
                n = q.norm_counter(cc)
                assert n >= prev[cc] - 1e-9      # counters only grow
                prev[cc] = n
            assert q.depth() == pushed - popped

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @settings(deadline=None, max_examples=50)
        @given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 32)),
                        max_size=120))
        def run(ops):
            check(ops)

        run()
    except ImportError:
        rng = random.Random(7)
        for _ in range(60):
            ops = [(rng.randint(0, 2), rng.randint(0, 32))
                   for _ in range(rng.randint(1, 120))]
            check(ops)


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------


def _snap(ttft=0.0, waiting=0, running=0, swapped=0, swapping_in=0,
          parked=(), draining=False):
    return {"predicted_ttft_us": ttft, "waiting": waiting,
            "running": running, "swapped": swapped,
            "swapping_in": swapping_in, "parked": tuple(parked),
            "draining": draining}


def test_route_new_least_predicted_ttft_pins_affinity():
    r = Router(3)
    snaps = [_snap(ttft=500.0), _snap(ttft=100.0), _snap(ttft=300.0)]
    assert r.route_new(1, snaps) == 1
    assert r.route_followup(1) == 1         # pinned forever
    # ties break on load, then index
    snaps = [_snap(running=2), _snap(running=1), _snap(running=1)]
    assert r.route_new(2, snaps) == 1
    r.release(1)
    with pytest.raises(KeyError):
        r.route_followup(1)


def test_route_new_skips_draining_replicas():
    r = Router(2)
    assert r.route_new(1, [_snap(draining=True), _snap(ttft=9e9)]) == 1
    with pytest.raises(RuntimeError):
        r.route_new(2, [_snap(draining=True), _snap(draining=True)])


def test_plan_migrations_moves_parked_hot_to_cold():
    r = Router(2, migrate_threshold=4)
    for h in (10, 11, 12, 13):
        r.affinity[h] = 0
    snaps = [_snap(running=4, waiting=2, parked=(10, 11, 12, 13)),
             _snap()]
    plans = r.plan_migrations(snaps)
    # gap 6 -> move gap//2 = 3 sessions, lowest handles first
    assert plans == [(10, 0, 1), (11, 0, 1), (12, 0, 1)]
    # busy handles (a follow-up mid-dispatch) are never planned
    plans = r.plan_migrations(snaps, busy={10, 12})
    assert plans == [(11, 0, 1), (13, 0, 1)]
    # below the threshold: leave it alone (damping, not oscillation)
    assert r.plan_migrations([_snap(running=2), _snap()]) == []
    # never migrate INTO a draining replica
    assert r.plan_migrations(
        [_snap(running=9, parked=(10,)), _snap(draining=True)]) == []


# ---------------------------------------------------------------------------
# event-log affinity audit
# ---------------------------------------------------------------------------


def _ev(kind, h, **kw):
    d = {"kind": kind, "handle": h}
    d.update(kw)
    return d


def test_affinity_audit_clean_migration_is_zero():
    r0 = [_ev("arrive", 1), _ev("finish", 1, retained=True),
          _ev("migrate_out", 1),
          _ev("arrive", 2), _ev("finish", 2, retained=False)]
    r1 = [_ev("migrate_in", 1), _ev("swap_in", 1),
          _ev("finish", 1, retained=False)]
    assert count_affinity_violations([r0, r1]) == 0


def test_affinity_audit_flags_wrong_replica_followup():
    r0 = [_ev("arrive", 1), _ev("finish", 1, retained=True)]
    r1 = [_ev("swap_in", 1)]                # replica 1 never owned h=1
    assert count_affinity_violations([r0, r1]) == 1


def test_affinity_audit_flags_double_claim_without_handoff():
    # both replicas opened the handle, nobody migrated it out
    r0 = [_ev("arrive", 5)]
    r1 = [_ev("arrive", 5)]
    assert count_affinity_violations([r0, r1]) == 1
    # with the handoff recorded, the same pair is legal
    r0 = [_ev("arrive", 5), _ev("finish", 5, retained=True),
          _ev("migrate_out", 5)]
    r1 = [_ev("migrate_in", 5)]
    assert count_affinity_violations([r0, r1]) == 0
    # engine-level events (handle < 0, e.g. drain) are ignored
    assert count_affinity_violations([[_ev("drain", -1)]]) == 0


# ---------------------------------------------------------------------------
# DirectCluster: determinism + the fairness acceptance shape
# ---------------------------------------------------------------------------


def test_direct_cluster_deterministic_and_violation_free():
    from repro.frontend.loadgen import (DirectCluster, sim_engine_config,
                                        storm_workload)

    def once():
        wl = storm_workload(n_clients=4, duration_s=8.0, storms=1, seed=3)
        cluster = DirectCluster(2, config=sim_engine_config())
        cluster.run(wl)
        return cluster.results()

    r1, r2 = once(), once()
    assert r1 == r2                         # same seed, same bytes
    assert r1["turns_finished"] > 0
    assert r1["affinity_violations"] == 0
    assert set(r1["per_client_attainment"]) \
        == {f"client{i}" for i in range(4)}
    assert 0.0 < r1["jain_attainment"] <= 1.0


# ---------------------------------------------------------------------------
# real mode: migration round trip + loopback driver equivalence
# ---------------------------------------------------------------------------


def _real_cfg():
    return EngineConfig(mode="real", num_gpu_blocks=32, num_cpu_blocks=128,
                        max_running=4, max_batch=4).with_policy("fastswitch")


def _drain(eng, max_iters=20_000):
    outs = []
    it = 0
    while eng.has_work() and it < max_iters:
        outs.extend(eng.step())
        it += 1
    assert not eng.has_work()
    return outs


def _turn_tokens(outs, turn):
    return [t for o in outs if o.token_ids and o.turn == turn
            for t in o.token_ids]


def test_migration_round_trip_bit_exact(engine_model):
    """A parked session exported from replica A and imported into
    replica B continues with EXACTLY the tokens a never-migrated
    session would produce (greedy decode is scheduling-independent, so
    any drift is a migration bug: lost KV, wrong context length,
    corrupt history)."""
    vocab = engine_model["cfg"].vocab_size
    p1 = synth_prompt_ids(21, 0, 20, vocab)
    p2 = synth_prompt_ids(21, 1, 12, vocab)
    samp = SamplingParams(max_tokens=8)

    # reference: both turns on one engine
    ref = ServingEngine(_real_cfg(), model_bundle=engine_model,
                        stream_tokens=True)
    h = ref.add_request(p1, samp, retain_kv=True)
    outs = _drain(ref)
    ref.continue_session(h, p2, samp)
    outs += _drain(ref)
    ref_t0, ref_t1 = _turn_tokens(outs, 0), _turn_tokens(outs, 1)
    assert len(ref_t0) == 8 and len(ref_t1) == 8

    # migrated: turn 1 on A, export/import, turn 2 on B
    a = ServingEngine(_real_cfg(), model_bundle=engine_model,
                      stream_tokens=True)
    b = ServingEngine(_real_cfg(), model_bundle=engine_model,
                      stream_tokens=True)
    ha = a.add_request(p1, samp, retain_kv=True)
    outs_a = _drain(a)
    assert _turn_tokens(outs_a, 0) == ref_t0
    payload = a.export_session(ha)
    assert ha not in a.parked               # resources left the source
    hb = b.import_session(payload)
    b.continue_session(hb, p2, samp)
    outs_b = _drain(b)
    assert _turn_tokens(outs_b, 1) == ref_t1


async def _equivalence_client(host, port, convs, continue_idx, samp_tokens):
    """Submit every conversation, stream tokens, follow up on ONE
    retained session; returns {(conv_idx, turn): [token ids]}."""
    reader, writer = await asyncio.open_connection(host, port)
    for i, (pp1, _) in enumerate(convs):
        writer.write(json.dumps(
            {"op": "submit", "id": str(i), "client": "eq", "prompt": pp1,
             "max_tokens": samp_tokens}).encode() + b"\n")
    await writer.drain()
    conv_of, turn_of, streams = {}, {}, {}
    expected, n_finish, continued = len(convs), 0, False
    while n_finish < expected:
        line = await reader.readline()
        assert line, "server closed mid-stream"
        ev = json.loads(line)
        et = ev.get("event")
        if et == "accepted":
            rid = ev.get("id")
            if rid is not None and rid.isdigit():
                conv_of[ev["handle"]] = int(rid)
                turn_of.setdefault(ev["handle"], 0)
        elif et == "token":
            h = ev["handle"]
            key = (conv_of[h], turn_of[h])
            streams.setdefault(key, []).extend(ev.get("token_ids") or [])
        elif et == "finish":
            h = ev["handle"]
            n_finish += 1
            turn_of[h] += 1
            if ev.get("retained"):
                if conv_of[h] == continue_idx and not continued:
                    continued = True
                    expected += 1
                    writer.write(json.dumps(
                        {"op": "continue", "handle": h, "id": "fup",
                         "prompt": convs[conv_of[h]][1],
                         "max_tokens": samp_tokens}).encode() + b"\n")
                else:
                    writer.write(json.dumps(
                        {"op": "release", "handle": h}).encode() + b"\n")
                await writer.drain()
        elif et == "error":
            raise AssertionError(f"server error {ev}")
    writer.close()
    await writer.wait_closed()
    return streams


def test_loopback_driver_equivalence_bit_exact(engine_model, tmp_path):
    """The full network path — sockets, fair queue, router, threaded
    replicas — must emit the SAME greedy token streams as direct
    single-engine runs of each conversation, and its event logs must
    pass the affinity audit."""
    from repro.frontend.router import load_event_log
    from repro.frontend.server import FrontendServer

    vocab = engine_model["cfg"].vocab_size
    convs = [(synth_prompt_ids(30 + i, 0, 16 + 4 * i, vocab),
              synth_prompt_ids(30 + i, 1, 12, vocab))
             for i in range(3)]
    continue_idx, samp_tokens = 0, 6

    # reference: each conversation alone on a fresh engine
    ref = {}
    for i, (pp1, pp2) in enumerate(convs):
        eng = ServingEngine(_real_cfg(), model_bundle=engine_model,
                            stream_tokens=True)
        h = eng.add_request(pp1, SamplingParams(max_tokens=samp_tokens),
                            retain_kv=True)
        outs = _drain(eng)
        ref[(i, 0)] = _turn_tokens(outs, 0)
        if i == continue_idx:
            eng.continue_session(h, pp2,
                                 SamplingParams(max_tokens=samp_tokens))
            ref[(i, 1)] = _turn_tokens(_drain(eng), 1)

    paths = [str(tmp_path / f"eq_r{i}.jsonl") for i in range(2)]
    files = [open(p, "w") for p in paths]

    def mk_sink(i):
        def sink(ev):
            files[i].write(json.dumps(ev.as_dict()) + "\n")
        return sink

    engines = [ServingEngine(_real_cfg(), model_bundle=engine_model,
                             stream_tokens=True, event_sink=mk_sink(i))
               for i in range(2)]

    async def go():
        srv = FrontendServer(engines)
        host, port = await srv.start()
        try:
            return await _equivalence_client(host, port, convs,
                                             continue_idx, samp_tokens)
        finally:
            await srv.close()

    try:
        streams = asyncio.run(go())
    finally:
        for f in files:
            f.close()
    assert streams == ref                   # bit-exact, both turns
    logs = [load_event_log(p) for p in paths]
    assert count_affinity_violations(logs) == 0
