"""Serving engine end-to-end behaviour (sim + real modes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, FastSwitchEngine
from repro.core.swap_manager import SwapTask
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import Conversation, Turn, sample_conversations


def _engine(policy, convs, mode="sim", **kw):
    model_bundle = kw.pop("model_bundle", None)
    defaults = dict(mode=mode, num_gpu_blocks=512, num_cpu_blocks=4096,
                    max_running=16)
    defaults.update(kw)
    cfg = EngineConfig(**defaults).with_policy(policy)
    return FastSwitchEngine(
        cfg, [c for c in convs],
        trace=PriorityTrace("markov", update_freq=0.04, seed=7),
        model_bundle=model_bundle)


CONVS = sample_conversations(40, rate_req_s=2.0, seed=3)
TOTAL_RESP = sum(t.response_tokens for c in CONVS for t in c.turns)


@pytest.mark.parametrize("policy", ["vllm", "+dbg", "+dbg+reuse",
                                    "fastswitch"])
def test_all_tokens_served(policy):
    eng = _engine(policy, CONVS)
    m = eng.run(max_iterations=300_000)
    assert eng.done()
    assert m.total_tokens == TOTAL_RESP
    assert len(m.ttfts_us) == sum(len(c.turns) for c in CONVS)


def test_determinism():
    m1 = _engine("fastswitch", CONVS).run(max_iterations=300_000)
    m2 = _engine("fastswitch", CONVS).run(max_iterations=300_000)
    assert m1.total_time_us == m2.total_time_us
    assert m1.ttfts_us == m2.ttfts_us


def test_block_groups_reduce_ops():
    e1 = _engine("vllm", CONVS)
    e1.run(max_iterations=300_000)
    e2 = _engine("+dbg", CONVS)
    e2.run(max_iterations=300_000)
    s1, s2 = e1.swap.stats(), e2.swap.stats()
    assert s1["total_ops"] == s1["total_blocks"]       # per-block baseline
    assert s2["total_ops"] < s1["total_ops"] / 3       # coarse grouping
    gran = s2["total_blocks"] / max(s2["total_ops"], 1)
    assert gran > 4


def test_reuse_reduces_swap_out_volume():
    """Paper Table 1: the reuse mechanism cuts swap-out blocks (-53%)."""
    e1 = _engine("+dbg", CONVS)
    e1.run(max_iterations=300_000)
    e2 = _engine("+dbg+reuse", CONVS)
    e2.run(max_iterations=300_000)
    assert e2.swap.blocks_by_dir["out"] < 0.6 * e1.swap.blocks_by_dir["out"]


def test_async_reduces_stall():
    e1 = _engine("+dbg+reuse", CONVS)
    e1.run(max_iterations=300_000)
    e2 = _engine("fastswitch", CONVS)
    e2.run(max_iterations=300_000)
    assert e2.swap.total_stall_us < e1.swap.total_stall_us


def test_fastswitch_improves_tail_latency():
    m1 = _engine("vllm", CONVS).run(max_iterations=300_000)
    m2 = _engine("fastswitch", CONVS).run(max_iterations=300_000)
    s1, s2 = m1.summary(), m2.summary()
    assert s2["p999_tbt_ms"] < s1["p999_tbt_ms"]
    assert s2["throughput_tok_s"] > s1["throughput_tok_s"]


def test_gpu_blocks_never_leak():
    eng = _engine("fastswitch", CONVS)
    eng.run(max_iterations=300_000)
    assert eng.done()
    eng.gpu_mgr.check_invariants()
    assert eng.gpu_mgr.free_blocks() == eng.gpu_mgr.num_blocks


def test_conflict_free_decode_blocks():
    """While running, no in-flight swap-in targets a block owned by a
    *different* request (conflicts must have been resolved)."""
    eng = _engine("fastswitch", CONVS)
    for _ in range(3000):
        if eng.done():
            break
        eng.step()
        inflight = {}
        for t in eng.swap.ongoing_swap_in:
            for b in t.gpu_blocks:
                inflight[b] = t.req_id
        for rid in eng.sched.running:
            for b in eng.gpu_mgr.request_block_ids(rid):
                if b in inflight:
                    assert inflight[b] == rid or False, \
                        f"block {b} of running {rid} is swap-in target of {inflight[b]}"


# ---------------------------------------------------------------------------
# decode-batch desync regressions (ISSUE 2): preemption/allocation inside
# step 5 must never decode a request whose block table wasn't extended
# ---------------------------------------------------------------------------


def _assert_no_desync(eng):
    """Every running request's context must fit its allocated blocks —
    the invariant the old in-place ``rids.remove`` / bare ``continue``
    paths silently broke."""
    bs = eng.config.block_size
    for rid in eng.sched.running:
        req = eng.sched.requests[rid]
        cap = len(eng.gpu_mgr.request_block_ids(rid)) * bs
        assert req.context_tokens <= cap, (
            f"desync: rid {rid} context {req.context_tokens} "
            f"> block capacity {cap}")


def test_victim_inside_batch_preemption_no_desync():
    """Force an OutOfBlocksError mid-batch whose victim sits EARLIER in
    the decode list than the allocating request: the old code removed the
    victim from the list being iterated, skipping the next request's
    block allocation while still decoding and crediting it."""
    convs = [
        Conversation(conv_id=0, arrival_s=0.0, turns=[Turn(24, 40)],
                     think_time_s=0.1),
        Conversation(conv_id=1, arrival_s=0.030, turns=[Turn(8, 30)],
                     think_time_s=0.1),
        Conversation(conv_id=2, arrival_s=0.035, turns=[Turn(8, 30)],
                     think_time_s=0.1),
    ]
    cfg = EngineConfig(mode="sim", num_gpu_blocks=6, num_cpu_blocks=256,
                       block_size=16).with_policy("vllm")
    trace = PriorityTrace("random", update_freq=1e-9, seed=0)
    # fixed priorities, no rebalances: rid 0 is always the victim and was
    # admitted first, so it sits at the head of the running list
    trace._prio = {0: 0.1, 1: 0.9, 2: 0.5}
    eng = FastSwitchEngine(cfg, convs, trace=trace)
    for _ in range(3000):
        if eng.done():
            break
        eng.step()
        _assert_no_desync(eng)
    assert eng.done()
    assert eng.metrics.preemptions >= 1, \
        "scenario never triggered the victim-inside-batch preemption"
    assert eng.metrics.total_tokens == 100


def test_alloc_failure_without_victim_skips_decode():
    """OutOfBlocksError with no preemptable victim: the old code's bare
    ``continue`` left the request in the decode set, advancing its
    context past its block table; it must sit the iteration out."""
    convs = [
        Conversation(conv_id=0, arrival_s=0.0, turns=[Turn(8, 20)],
                     think_time_s=0.1),
        Conversation(conv_id=1, arrival_s=0.0, turns=[Turn(8, 20)],
                     think_time_s=0.1),
    ]
    cfg = EngineConfig(mode="sim", num_gpu_blocks=4, num_cpu_blocks=256,
                       block_size=16).with_policy("vllm")
    trace = PriorityTrace("random", update_freq=1e-9, seed=0)
    trace._prio = {0: 0.9, 1: 0.5}
    eng = FastSwitchEngine(cfg, convs, trace=trace)
    eng.core._find_victim = lambda exclude: None   # nobody to preempt
    for _ in range(3000):
        if eng.done():
            break
        eng.step()
        _assert_no_desync(eng)
    assert eng.done()
    assert eng.metrics.total_tokens == 40


def test_emit_first_token_full_pool_routes_through_preemption():
    """A rebalance-time admission can land ``_emit_first_token`` on a full
    pool; the old unguarded ``allocate_tokens`` raised OutOfBlocksError
    out of ``step()`` — it must preempt a victim instead."""
    convs = [
        Conversation(conv_id=0, arrival_s=0.0, turns=[Turn(4, 20)],
                     think_time_s=0.1),
        Conversation(conv_id=1, arrival_s=0.0, turns=[Turn(4, 20)],
                     think_time_s=0.1),
    ]
    cfg = EngineConfig(mode="sim", num_gpu_blocks=4, num_cpu_blocks=256,
                       block_size=8).with_policy("vllm")
    trace = PriorityTrace("random", update_freq=1e-9, seed=0)
    trace._prio = {0: 0.9, 1: 0.5}
    eng = FastSwitchEngine(cfg, convs, trace=trace)
    eng.step()
    assert sorted(eng.sched.running) == [0, 1]
    # exhaust the pool: hand the free block to rid 1, fill rid 0's block
    req0, req1 = eng.sched.requests[0], eng.sched.requests[1]
    eng.gpu_mgr.allocate_tokens(1, 8)
    eng.gpu_mgr.note_tokens(1, 8)
    req1.context_tokens += 8
    fill = 8 - (req0.context_tokens % 8)
    eng.gpu_mgr.allocate_tokens(0, fill)
    eng.gpu_mgr.note_tokens(0, fill)
    req0.context_tokens += fill
    assert eng.gpu_mgr.free_blocks() == 0
    eng._emit_first_token(0)                     # must not raise
    assert eng.metrics.preemptions == 1
    assert 1 not in eng.sched.running
    cap = len(eng.gpu_mgr.request_block_ids(0)) * 8
    assert req0.context_tokens <= cap


def test_swap_out_never_claims_unwritten_last_slot():
    """At swap-out, position context-1's KV has NOT been written yet (the
    next decode step writes its input's K/V before attending).  The old
    code marked it valid in the CPU reuse copy: the incremental copy
    never revisits slots behind its pointer, so a preemption at a
    block-aligned context froze garbage into the copy and a later
    swap-in restored it into attended positions (token corruption)."""
    convs = [Conversation(conv_id=0, arrival_s=0.0, turns=[Turn(8, 30)],
                          think_time_s=0.1)]
    cfg = EngineConfig(mode="sim", num_gpu_blocks=64, num_cpu_blocks=256,
                       block_size=16).with_policy("fastswitch")
    eng = FastSwitchEngine(cfg, convs,
                           trace=PriorityTrace("random", 1e-9, seed=0))
    for _ in range(5):
        eng.step()
    req = eng.sched.requests[0]
    assert 0 in eng.sched.running and req.context_tokens > 1
    eng._preempt(0)
    assert eng.reuse.valid_tokens(0) == req.context_tokens - 1, \
        "swap-out claimed the unwritten last KV slot as valid"


def test_swapping_in_promoted_after_conflict_sync():
    """A fine-grained conflict sync (resolve_conflicts) retires an async
    swap-in task between step-1 polls; the old engine never promoted the
    request out of SWAPPING_IN — it was stranded forever (livelock)."""
    convs = [Conversation(conv_id=0, arrival_s=0.0, turns=[Turn(8, 20)],
                          think_time_s=0.1)]
    cfg = EngineConfig(mode="sim", num_gpu_blocks=64, num_cpu_blocks=256,
                       block_size=16).with_policy("fastswitch")
    eng = FastSwitchEngine(cfg, convs,
                           trace=PriorityTrace("random", 1e-9, seed=0))
    eng.swap.adaptive = False     # force async: the cost model would pick
    eng.step()                    # sync for a 1-block swap on an idle batch
    assert 0 in eng.sched.running
    eng._preempt(0)
    assert 0 in eng.sched.swapped
    assert eng._swap_in(0) is False          # async: in flight
    assert 0 in eng.sched.swapping_in
    task = eng.swap.ongoing_swap_in[0]
    # conflict on a target block synchronizes the task away
    eng.swap.resolve_conflicts(eng.clock, list(task.gpu_blocks)[:1])
    assert eng.swap.ongoing_swap_in == []
    eng.step()
    assert 0 in eng.sched.running, "request stranded in SWAPPING_IN"


def test_emit_first_token_resolves_swap_conflicts_on_new_block():
    """The first-token block can be a just-freed block that an in-flight
    async swap-out is still reading; _emit_first_token must synchronize
    exactly like step 5 does for newly allocated decode blocks."""
    convs = [Conversation(conv_id=0, arrival_s=0.0, turns=[Turn(4, 20)],
                          think_time_s=0.1)]
    cfg = EngineConfig(mode="sim", num_gpu_blocks=8, num_cpu_blocks=256,
                       block_size=8).with_policy("fastswitch")
    eng = FastSwitchEngine(cfg, convs,
                           trace=PriorityTrace("random", 1e-9, seed=0))
    eng.step()
    req0 = eng.sched.requests[0]
    # advance to the block boundary so the next token needs a fresh block
    fill = 8 - (req0.context_tokens % 8)
    eng.gpu_mgr.allocate_tokens(0, fill)
    eng.gpu_mgr.note_tokens(0, fill)
    req0.context_tokens += fill
    # fabricate an in-flight swap-out reading every block
    now = eng.clock.now_us
    task = SwapTask(req_id=99, direction="out", n_ops=1, n_blocks=1,
                    bytes_total=1, issued_at=now, done_at=now + 5000.0,
                    gpu_blocks=set(range(cfg.num_gpu_blocks)))
    eng.swap.ongoing_swap_out.append(task)
    n0 = eng.swap.n_conflicts
    eng._emit_first_token(0)
    assert eng.swap.n_conflicts == n0 + 1, \
        "first-token block allocated without synchronizing the conflict"
    assert eng.clock.now_us >= task.done_at


# ---------------------------------------------------------------------------
# real mode: actual tokens through the paged pool
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return {"cfg": cfg, "params": params}


def test_real_mode_generates_exact_token_count(tiny_model):
    convs = [Conversation(conv_id=i, arrival_s=0.05 * i,
                          turns=[Turn(10, 6), Turn(8, 6)], think_time_s=0.3)
             for i in range(3)]
    eng = _engine("fastswitch", convs, mode="real", num_gpu_blocks=64,
                  num_cpu_blocks=256, max_running=4, max_batch=4,
                  model_bundle=tiny_model)
    m = eng.run(max_iterations=20_000)
    assert eng.done()
    assert m.total_tokens == 3 * 2 * 6


def test_real_mode_swap_preserves_tokens(tiny_model):
    """Same conversations, severe preemption (tiny pool, frequent priority
    updates) vs none: generated token streams must be IDENTICAL — context
    switching must not corrupt KV."""
    def mk():
        return [Conversation(conv_id=i, arrival_s=0.0,
                             turns=[Turn(16, 24)], think_time_s=0.2)
                for i in range(4)]

    def run(gpu_blocks, freq):
        cfg = EngineConfig(mode="real", num_gpu_blocks=gpu_blocks,
                           num_cpu_blocks=512, max_running=4,
                           max_batch=4).with_policy("fastswitch")
        eng = FastSwitchEngine(
            cfg, mk(), trace=PriorityTrace("random", freq, seed=11),
            model_bundle=tiny_model)
        eng.run(max_iterations=20_000)
        assert eng.done()
        hists = {}
        for c in eng.sleeping:
            pass
        return eng

    e_calm = run(gpu_blocks=256, freq=0.0001)      # virtually no preemption
    e_storm = run(gpu_blocks=8, freq=0.5)          # heavy context switching
    assert e_storm.metrics.preemptions > e_calm.metrics.preemptions
    # compare token histories recorded per conversation
    calm = e_calm._token_hist_by_conv
    storm = e_storm._token_hist_by_conv
    assert set(calm) == set(storm)
    for cid in calm:
        assert calm[cid] == storm[cid], f"conv {cid} tokens diverged"
