"""Serving engine end-to-end behaviour (sim + real modes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, FastSwitchEngine
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import Conversation, Turn, sample_conversations


def _engine(policy, convs, mode="sim", **kw):
    model_bundle = kw.pop("model_bundle", None)
    defaults = dict(mode=mode, num_gpu_blocks=512, num_cpu_blocks=4096,
                    max_running=16)
    defaults.update(kw)
    cfg = EngineConfig(**defaults).with_policy(policy)
    return FastSwitchEngine(
        cfg, [c for c in convs],
        trace=PriorityTrace("markov", update_freq=0.04, seed=7),
        model_bundle=model_bundle)


CONVS = sample_conversations(40, rate_req_s=2.0, seed=3)
TOTAL_RESP = sum(t.response_tokens for c in CONVS for t in c.turns)


@pytest.mark.parametrize("policy", ["vllm", "+dbg", "+dbg+reuse",
                                    "fastswitch"])
def test_all_tokens_served(policy):
    eng = _engine(policy, CONVS)
    m = eng.run(max_iterations=300_000)
    assert eng.done()
    assert m.total_tokens == TOTAL_RESP
    assert len(m.ttfts_us) == sum(len(c.turns) for c in CONVS)


def test_determinism():
    m1 = _engine("fastswitch", CONVS).run(max_iterations=300_000)
    m2 = _engine("fastswitch", CONVS).run(max_iterations=300_000)
    assert m1.total_time_us == m2.total_time_us
    assert m1.ttfts_us == m2.ttfts_us


def test_block_groups_reduce_ops():
    e1 = _engine("vllm", CONVS)
    e1.run(max_iterations=300_000)
    e2 = _engine("+dbg", CONVS)
    e2.run(max_iterations=300_000)
    s1, s2 = e1.swap.stats(), e2.swap.stats()
    assert s1["total_ops"] == s1["total_blocks"]       # per-block baseline
    assert s2["total_ops"] < s1["total_ops"] / 3       # coarse grouping
    gran = s2["total_blocks"] / max(s2["total_ops"], 1)
    assert gran > 4


def test_reuse_reduces_swap_out_volume():
    """Paper Table 1: the reuse mechanism cuts swap-out blocks (-53%)."""
    e1 = _engine("+dbg", CONVS)
    e1.run(max_iterations=300_000)
    e2 = _engine("+dbg+reuse", CONVS)
    e2.run(max_iterations=300_000)
    assert e2.swap.blocks_by_dir["out"] < 0.6 * e1.swap.blocks_by_dir["out"]


def test_async_reduces_stall():
    e1 = _engine("+dbg+reuse", CONVS)
    e1.run(max_iterations=300_000)
    e2 = _engine("fastswitch", CONVS)
    e2.run(max_iterations=300_000)
    assert e2.swap.total_stall_us < e1.swap.total_stall_us


def test_fastswitch_improves_tail_latency():
    m1 = _engine("vllm", CONVS).run(max_iterations=300_000)
    m2 = _engine("fastswitch", CONVS).run(max_iterations=300_000)
    s1, s2 = m1.summary(), m2.summary()
    assert s2["p999_tbt_ms"] < s1["p999_tbt_ms"]
    assert s2["throughput_tok_s"] > s1["throughput_tok_s"]


def test_gpu_blocks_never_leak():
    eng = _engine("fastswitch", CONVS)
    eng.run(max_iterations=300_000)
    assert eng.done()
    eng.gpu_mgr.check_invariants()
    assert eng.gpu_mgr.free_blocks() == eng.gpu_mgr.num_blocks


def test_conflict_free_decode_blocks():
    """While running, no in-flight swap-in targets a block owned by a
    *different* request (conflicts must have been resolved)."""
    eng = _engine("fastswitch", CONVS)
    for _ in range(3000):
        if eng.done():
            break
        eng.step()
        inflight = {}
        for t in eng.swap.ongoing_swap_in:
            for b in t.gpu_blocks:
                inflight[b] = t.req_id
        for rid in eng.sched.running:
            for b in eng.gpu_mgr.request_block_ids(rid):
                if b in inflight:
                    assert inflight[b] == rid or False, \
                        f"block {b} of running {rid} is swap-in target of {inflight[b]}"


# ---------------------------------------------------------------------------
# real mode: actual tokens through the paged pool
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return {"cfg": cfg, "params": params}


def test_real_mode_generates_exact_token_count(tiny_model):
    convs = [Conversation(conv_id=i, arrival_s=0.05 * i,
                          turns=[Turn(10, 6), Turn(8, 6)], think_time_s=0.3)
             for i in range(3)]
    eng = _engine("fastswitch", convs, mode="real", num_gpu_blocks=64,
                  num_cpu_blocks=256, max_running=4, max_batch=4,
                  model_bundle=tiny_model)
    m = eng.run(max_iterations=20_000)
    assert eng.done()
    assert m.total_tokens == 3 * 2 * 6


def test_real_mode_swap_preserves_tokens(tiny_model):
    """Same conversations, severe preemption (tiny pool, frequent priority
    updates) vs none: generated token streams must be IDENTICAL — context
    switching must not corrupt KV."""
    def mk():
        return [Conversation(conv_id=i, arrival_s=0.0,
                             turns=[Turn(16, 24)], think_time_s=0.2)
                for i in range(4)]

    def run(gpu_blocks, freq):
        cfg = EngineConfig(mode="real", num_gpu_blocks=gpu_blocks,
                           num_cpu_blocks=512, max_running=4,
                           max_batch=4).with_policy("fastswitch")
        eng = FastSwitchEngine(
            cfg, mk(), trace=PriorityTrace("random", freq, seed=11),
            model_bundle=tiny_model)
        eng.run(max_iterations=20_000)
        assert eng.done()
        hists = {}
        for c in eng.sleeping:
            pass
        return eng

    e_calm = run(gpu_blocks=256, freq=0.0001)      # virtually no preemption
    e_storm = run(gpu_blocks=8, freq=0.5)          # heavy context switching
    assert e_storm.metrics.preemptions > e_calm.metrics.preemptions
    # compare token histories recorded per conversation
    calm = e_calm._token_hist_by_conv
    storm = e_storm._token_hist_by_conv
    assert set(calm) == set(storm)
    for cid in calm:
        assert calm[cid] == storm[cid], f"conv {cid} tokens diverged"
