"""Per-architecture smoke tests (deliverable f): REDUCED variant of each
assigned architecture runs one forward/train step on CPU; output shapes +
no NaNs.  The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import steps, transformer as T
from repro.train.optimizer import adamw_init

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=32):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    b = {"tokens": tokens, "labels": tokens}
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        b["extra_embeds"] = 0.1 * jnp.ones(
            (B, cfg.frontend.n_tokens, cfg.frontend.d_embed), jnp.float32)
    if cfg.encoder_decoder:
        b["encoder_frames"] = 0.1 * jnp.ones(
            (B, cfg.n_encoder_tokens, cfg.d_model), jnp.float32)
    return b


def test_all_archs_registered():
    assert len(ARCHS) == 10
    kinds = {get_config(a).arch_type for a in ARCHS}
    assert kinds == {"dense", "ssm", "moe", "hybrid", "vlm", "audio"}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    assert cfg.source


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_config_is_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 3
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    batch = _batch(cfg, key)
    B, S = batch["tokens"].shape
    # forward
    logits, _, aux = T.forward_seq(params, cfg, batch["tokens"],
                                   extra_embeds=batch.get("extra_embeds"),
                                   encoder_frames=batch.get("encoder_frames"))
    T_eff = S + (cfg.frontend.n_tokens
                 if cfg.frontend and cfg.frontend.kind == "vision" else 0)
    assert logits.shape == (B, T_eff, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # one train step
    opt = adamw_init(params)
    p2, o2, loss = steps.train_step(params, opt, batch, cfg=cfg)
    assert jnp.isfinite(loss)
    assert float(loss) > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p2))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    B = 2
    caches = T.init_caches(cfg, B, 64)
    tok = jnp.array([1, 2], jnp.int32)
    for i in range(3):
        nxt, logits, caches = steps.serve_step(params, caches, tok,
                                               jnp.int32(i), cfg=cfg)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        assert nxt.shape == (B,)
        tok = nxt


def test_param_counts_full_configs():
    """Full-config analytic param counts are in the right ballpark."""
    expected_b = {
        "mistral-nemo-12b": (11, 14),
        "qwen2-1.5b": (1.2, 2.0),
        "llama3.2-3b": (3.0, 4.0),
        "gemma3-12b": (10, 14),
        "olmoe-1b-7b": (6, 8),
        "deepseek-v2-236b": (200, 260),
        "rwkv6-1.6b": (1.4, 2.2),
        "zamba2-7b": (5, 12),   # shared attention block => fewer params
        "llava-next-mistral-7b": (6.5, 8),
        "whisper-large-v3": (1.2, 2.0),
    }
    for arch, (lo, hi) in expected_b.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo}, {hi}]"
