"""Related-work baselines: recompute preemption and Llumnix buffering."""
from repro.core import EngineConfig, FastSwitchEngine
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import sample_conversations

CONVS = sample_conversations(30, rate_req_s=2.0, seed=13, max_context=3000)
TOTAL = sum(t.response_tokens for c in CONVS for t in c.turns)


def _run(policy):
    cfg = EngineConfig(mode="sim", num_gpu_blocks=384, num_cpu_blocks=4096,
                       max_running=8).with_policy(policy)
    eng = FastSwitchEngine(cfg, list(CONVS),
                           trace=PriorityTrace("random", 0.05, seed=3))
    m = eng.run(max_iterations=400_000)
    assert eng.done(), policy
    assert m.total_tokens == TOTAL
    return eng


def test_recompute_moves_no_bytes():
    eng = _run("vllm-recompute")
    assert eng.swap.total_ops == 0
    assert eng.swap.total_bytes == 0
    assert eng.metrics.preemptions > 0        # it did preempt — via compute


def test_recompute_pays_with_time():
    e_r = _run("vllm-recompute")
    e_s = _run("vllm")
    # recomputation burns more prefill work than swapping (paper §2.1)
    assert e_r.metrics.prefills > e_s.metrics.prefills
    assert (e_r.metrics.summary()["throughput_tok_s"]
            < e_s.metrics.summary()["throughput_tok_s"])


def test_llumnix_bounded_granularity():
    e_l = _run("llumnix")
    e_v = _run("vllm")
    e_f = _run("fastswitch")
    gran_l = e_l.swap.total_blocks / max(e_l.swap.total_ops, 1)
    gran_f = e_f.swap.total_blocks / max(e_f.swap.total_ops, 1)
    assert 1.0 < gran_l <= 2.0          # the 2-block buffer ceiling
    assert gran_f > gran_l               # block groups beat the buffer
    assert e_l.swap.total_ops < e_v.swap.total_ops
    assert e_f.swap.total_stall_us < e_l.swap.total_stall_us


def test_zip_halves_wire_bytes():
    """Wire compression halves bytes PER BLOCK (trajectories differ across
    policies, so compare the per-block ratio, not totals)."""
    e_f = _run("fastswitch")
    e_z = _run("fastswitch+zip")
    per_block_f = e_f.swap.total_bytes / max(e_f.swap.total_blocks, 1)
    per_block_z = e_z.swap.total_bytes / max(e_z.swap.total_blocks, 1)
    assert abs(per_block_z * 2 - per_block_f) <= 0.01 * per_block_f


def test_chunked_prefill_improves_tbt_tail():
    """BEYOND-PAPER: Sarathi-style chunked prefill cuts the TBT tail under
    prompt-heavy load (long prompts no longer stall the decode batch)."""
    convs = sample_conversations(40, rate_req_s=0.5, seed=5, prompt_mu=6.5,
                                 prompt_sigma=0.6, resp_mu=3.5,
                                 max_context=3000)

    def run(policy):
        cfg = EngineConfig(mode="sim", num_gpu_blocks=1024,
                           num_cpu_blocks=8192,
                           max_running=16).with_policy(policy)
        eng = FastSwitchEngine(cfg, list(convs),
                               trace=PriorityTrace("markov", 0.04, seed=2))
        m = eng.run(max_iterations=400_000)
        assert eng.done()
        return m.summary()

    s_full = run("fastswitch")
    s_chunk = run("fastswitch+chunked")
    assert s_chunk["total_tokens"] == s_full["total_tokens"]
    assert s_chunk["p999_tbt_ms"] < s_full["p999_tbt_ms"]
