"""Priority scheduler invariants."""
from repro.core.scheduler import PriorityScheduler, Request, ReqState
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import Conversation, Turn


def _req(rid, prompt=100, resp=50, turns=1):
    conv = Conversation(conv_id=rid, arrival_s=0.0,
                        turns=[Turn(prompt, resp)] * turns)
    r = Request(conv=conv)
    r.begin_turn(0.0)
    return r


def test_desired_running_priority_order():
    trace = PriorityTrace("random", update_freq=1.0, seed=0)
    s = PriorityScheduler(trace, max_running=8)
    for i in range(6):
        s.add_request(_req(i))
    # fix priorities directly
    trace._prio = {i: i / 10 for i in range(6)}
    desired = s.desired_running(block_budget_tokens=10_000, block_size=16)
    # highest priority first until budget; all fit here
    assert desired[0] == 5
    assert set(desired) == set(range(6))


def test_budget_limits_admission():
    trace = PriorityTrace("random", update_freq=1.0, seed=0)
    s = PriorityScheduler(trace, max_running=8)
    for i in range(6):
        s.add_request(_req(i, prompt=100))
    trace._prio = {i: i / 10 for i in range(6)}
    # each request needs ~116 tokens; budget of 250 fits exactly 2
    desired = s.desired_running(block_budget_tokens=250, block_size=16)
    assert desired == [5, 4]


def test_classify_rebalance():
    trace = PriorityTrace("random", update_freq=1.0, seed=0)
    s = PriorityScheduler(trace, max_running=8)
    for i in range(4):
        s.add_request(_req(i))
    s.move(0, ReqState.RUNNING)
    s.move(1, ReqState.RUNNING)
    s.move(2, ReqState.SWAPPED)
    # desired: 1 (keep), 2 (swap in), 3 (admit); 0 preempted
    pre, swin, adm = s.classify_rebalance([1, 2, 3])
    assert pre == [0] and swin == [2] and adm == [3]


def test_move_is_exclusive():
    trace = PriorityTrace("random", update_freq=1.0, seed=0)
    s = PriorityScheduler(trace, max_running=8)
    s.add_request(_req(1))
    for dst in (ReqState.RUNNING, ReqState.SWAPPED, ReqState.SWAPPING_IN,
                ReqState.WAITING, ReqState.RUNNING):
        s.move(1, dst)
        queues = [s.waiting, s.running, s.swapped, s.swapping_in]
        assert sum(q.count(1) for q in queues) == 1


def test_victims_lowest_priority_first():
    trace = PriorityTrace("random", update_freq=1.0, seed=0)
    s = PriorityScheduler(trace, max_running=8)
    for i in range(4):
        s.add_request(_req(i))
        s.move(i, ReqState.RUNNING)
    trace._prio = {0: 0.9, 1: 0.2, 2: 0.5, 3: 0.7}
    assert s.victims_for_space(exclude=set()) == [1, 2, 3, 0]
    assert s.victims_for_space(exclude={1}) == [2, 3, 0]


def test_markov_trace_stickiness():
    trace = PriorityTrace("markov", update_freq=1.0, seed=1, stickiness=1.0)
    ids = list(range(10))
    for rid in ids:
        trace.priority(rid)
    updated = trace.step(ids, running_ids=[0, 1])
    assert updated
    # running requests got boosted into [0.5, 1.0]
    assert trace.priority(0) >= 0.5
    assert trace.priority(1) >= 0.5


def test_update_period():
    trace = PriorityTrace("random", update_freq=0.25, seed=1)
    hits = sum(trace.step([1], []) for _ in range(100))
    assert hits == 25
