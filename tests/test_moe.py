"""MoE routing unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import init_moe, moe_forward


def _cfg(E=4, k=2, cap=8.0, shared=0):
    return ModelConfig(
        name="t", arch_type="moe", source="t", n_layers=1, d_model=32,
        n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=128,
        moe=MoEConfig(n_experts=E, top_k=k, d_expert_ff=64,
                      n_shared_experts=shared, capacity_factor=cap))


def test_moe_output_shape_and_aux():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    out, aux = moe_forward(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(aux))
    assert float(aux) >= 1.0 - 1e-6     # E * sum(f*p) >= 1 by Cauchy-Schwarz


def test_moe_matches_dense_reference_when_dropless():
    """Gather/scatter dispatch == explicit per-token dense reference."""
    cfg = _cfg(E=4, k=2, cap=16.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32), jnp.float32)
    out, _ = moe_forward(p, x, cfg)

    # reference: loop over tokens, run top-k experts densely
    xf = np.asarray(x.reshape(-1, 32))
    logits = xf @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    ref = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        top = np.argsort(-probs[t])[:2]
        gates = probs[t][top] / probs[t][top].sum()
        for e, g in zip(top, gates):
            h = xf[t] @ np.asarray(p["w_gate"][e])
            u = xf[t] @ np.asarray(p["w_up"][e])
            act = h / (1 + np.exp(-h)) * u
            ref[t] += g * (act @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 32)), ref,
                               atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg_tight = _cfg(E=4, k=2, cap=0.51)
    p = init_moe(jax.random.PRNGKey(0), cfg_tight)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 32), jnp.float32)
    out_tight, _ = moe_forward(p, x, cfg_tight)
    cfg_loose = _cfg(E=4, k=2, cap=16.0)
    out_loose, _ = moe_forward(p, x, cfg_loose)
    # tight capacity must change (drop) at least some token outputs
    assert float(jnp.max(jnp.abs(out_tight - out_loose))) > 1e-6


def test_shared_expert_always_applies():
    cfg = _cfg(shared=1)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((1, 4, 32), jnp.float32)
    out, _ = moe_forward(p, x, cfg)
    assert out.shape == x.shape
